"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod 16x16 mesh:
    compute term    = HLO_FLOPs/device / 197e12   (bf16 peak, TPU v5e)
    memory term     = HLO traffic bytes/device / 819e9 (HBM bw)
    collective term = collective bytes/device / 50e9   (ICI per link,
                      conservative single-link model — see note)
All three from the trip-count-aware HLO analysis of the compiled SPMD
module (launch/hlo_analysis.py). Also reports MODEL_FLOPS = 6*N_act*D
(train) or 2*N_act*D (inference) per device and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs.

roofline_fraction = ideal_compute_time / max(term) — i.e. what fraction of
the bound set by the dominant resource would be spent on model-essential
math. This is the score §Perf iterates on.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
       [--mesh sp] [--markdown out.md]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (conservative: 1 link per phase)

SHAPE_TOKENS = {
    "train_4k": (4096 * 256, 6.0),      # tokens, flops multiplier (fwd+bwd)
    "prefill_32k": (32768 * 32, 2.0),
    "decode_32k": (128, 2.0),           # one token per sequence
    "long_500k": (1, 2.0),
}


def load(dirpath: str, mesh: str) -> List[Dict]:
    rows = []
    for p in sorted(pathlib.Path(dirpath).glob(f"*__{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def analyze_cell(r: Dict) -> Dict:
    if r.get("status") != "ok":
        return {"arch": r["arch"], "shape": r["shape"],
                "status": r.get("status"), "reason": r.get("reason", "")}
    ndev = r["n_devices"]
    tokens, mult = SHAPE_TOKENS[r["shape"]]
    model_flops = mult * r["active_params"] * tokens / ndev
    # decode shapes re-read the whole KV cache + params per step: model
    # traffic floor = params + cache bytes (already counted in hlo traffic).
    compute_t = r["hlo_flops"] / PEAK_FLOPS
    memory_t = r["hlo_traffic_bytes"] / HBM_BW
    coll_t = r["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t,
             "collective": coll_t}
    dominant = max(terms, key=terms.get)
    ideal = model_flops / PEAK_FLOPS
    frac = ideal / max(max(terms.values()), 1e-30)
    return {
        "arch": r["arch"], "shape": r["shape"], "status": "ok",
        "compute_ms": compute_t * 1e3, "memory_ms": memory_t * 1e3,
        "collective_ms": coll_t * 1e3, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops": r["hlo_flops"],
        "useful_ratio": model_flops / max(r["hlo_flops"], 1.0),
        "roofline_fraction": frac,
        "peak_mb": r["memory"]["peak_mb"],
    }


def markdown_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
           " dominant | useful (6ND/HLO) | roofline frac | peak MiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in rows:
        if a.get("status") != "ok":
            out.append(f"| {a['arch']} | {a['shape']} | — | — | — | skipped |"
                       f" — | — | — |")
            continue
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['compute_ms']:.2f} | "
            f"{a['memory_ms']:.2f} | {a['collective_ms']:.2f} | "
            f"**{a['dominant']}** | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} | {a['peak_mb']:.0f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="sp", choices=["sp", "mp"])
    ap.add_argument("--markdown", default="")
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    rows = [analyze_cell(r) for r in load(args.dir, args.mesh)]
    ok = [r for r in rows if r.get("status") == "ok"]
    md = markdown_table(rows)
    print(md)
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["collective_ms"] /
                   max(r["compute_ms"] + r["memory_ms"], 1e-9))
        print(f"\nworst roofline fraction: {worst['arch']} x "
              f"{worst['shape']} ({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound:  {coll['arch']} x {coll['shape']}")
    if args.markdown:
        pathlib.Path(args.markdown).write_text(md + "\n")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()

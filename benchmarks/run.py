"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall times are CPU-host times
(the TPU perf story lives in the dry-run roofline, benchmarks/roofline.py);
the derived column carries the paper-comparable metric.

  table1    Table 1: accuracy + approx error, ours vs exact vs Nystrom vs
            plain K-means (blob+ring primary geometry, rings secondary)
  fig3      Fig. 3: error/accuracy vs sampled columns m (seg-proxy data)
  theorem1  Thm. 1 bound tightness over random PSD matrices
  memory    memory footprint: ours O(r'n) vs Nystrom O(mn) at matched error
  kernels   Pallas kernel microbench (interpret mode) vs jnp oracle
  backends  the estimator-API sweep: every --backends entry fitted through
            repro.api.KernelKMeans on the same data (accuracy, approx
            error, fit memory model)

Select sections with --sections (comma list; default: all); --backends
restricts the estimator sweep's backend list. The paper-table sections
run through the unified estimator API (`repro.api.KernelKMeans`) — the
historical free functions are deprecation shims over the same code paths.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, n=3):
    fn()  # compile
    t0 = time.perf_counter()
    out = None
    for _ in range(n):
        out = fn()
    if out is not None:
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _row(name, us, derived):
    print(f"{name},{us:.0f},{derived}", flush=True)


_POLY = {"gamma": 0.0, "degree": 2}


def _onepass_est(k, r, oversampling, block=512):
    from repro.api import KernelKMeans
    return KernelKMeans(k=k, r=r, kernel="polynomial", kernel_params=_POLY,
                        backend="onepass-srht",
                        backend_params={"oversampling": oversampling},
                        block=block)


def table1():
    from repro.core import (polynomial_kernel, gram_matrix, kmeans,
                            exact_eig_from_gram, nystrom,
                            linearized_kmeans_from_Y,
                            clustering_accuracy, kernel_approx_error)
    from repro.data import blob_ring, two_rings

    kern = polynomial_kernel(gamma=0.0, degree=2)
    for geom, maker in [("blobring", blob_ring), ("rings", two_rings)]:
        X, labels = maker(jax.random.PRNGKey(0), 4000)
        K = gram_matrix(kern, X)
        t0 = time.perf_counter()
        ex = exact_eig_from_gram(K, 2)
        t_ex = (time.perf_counter() - t0) * 1e6
        acc = clustering_accuracy(labels, linearized_kmeans_from_Y(
            jax.random.PRNGKey(3), ex.Y, 2).labels, 2)
        _row(f"table1.{geom}.exact", t_ex,
             f"err={kernel_approx_error(K, ex.Y):.2f};acc={acc:.2f}")
        errs, accs, t = [], [], 0.0
        for s in range(5):
            t0 = time.perf_counter()
            res = _onepass_est(2, 2, 10).fit(X, key=jax.random.PRNGKey(10 + s))
            t += (time.perf_counter() - t0) * 1e6
            errs.append(kernel_approx_error(K, res.embedding_))
            accs.append(clustering_accuracy(labels, res.labels_, 2))
        _row(f"table1.{geom}.ours", t / 5,
             f"err={np.mean(errs):.2f};acc={np.mean(accs):.2f}")
        for m in (20, 100):
            errs, accs, t = [], [], 0.0
            for s in range(5):
                t0 = time.perf_counter()
                ny = nystrom(jax.random.PRNGKey(50 + s), kern, X, m=m, r=2)
                km = linearized_kmeans_from_Y(jax.random.PRNGKey(3), ny.Y, 2)
                t += (time.perf_counter() - t0) * 1e6
                errs.append(kernel_approx_error(K, ny.Y))
                accs.append(clustering_accuracy(labels, km.labels, 2))
            _row(f"table1.{geom}.nystrom_m{m}", t / 5,
                 f"err={np.mean(errs):.2f};acc={np.mean(accs):.2f}")
        t0 = time.perf_counter()
        km = kmeans(jax.random.PRNGKey(5), X.T, 2)
        _row(f"table1.{geom}.plain_kmeans",
             (time.perf_counter() - t0) * 1e6,
             f"acc={clustering_accuracy(labels, km.labels, 2):.2f}")


def fig3():
    from repro.core import (polynomial_kernel, gram_matrix, nystrom,
                            linearized_kmeans_from_Y, clustering_accuracy,
                            kernel_approx_error)
    from repro.data import segmentation_proxy

    X, labels = segmentation_proxy(jax.random.PRNGKey(1))
    kern = polynomial_kernel(gamma=0.0, degree=2)
    K = gram_matrix(kern, X)
    errs, accs = [], []
    t0 = time.perf_counter()
    for s in range(5):
        res = _onepass_est(7, 2, 5).fit(X, key=jax.random.PRNGKey(20 + s))
        errs.append(kernel_approx_error(K, res.embedding_))
        accs.append(clustering_accuracy(labels, res.labels_, 7))
    _row("fig3.ours_rp7", (time.perf_counter() - t0) / 5 * 1e6,
         f"err={np.mean(errs):.3f};acc={np.mean(accs):.3f}")
    for m in (10, 20, 50):
        errs, accs = [], []
        t0 = time.perf_counter()
        for s in range(5):
            ny = nystrom(jax.random.PRNGKey(60 + s), kern, X, m=m, r=2)
            km = linearized_kmeans_from_Y(jax.random.PRNGKey(3), ny.Y, 7)
            errs.append(kernel_approx_error(K, ny.Y))
            accs.append(clustering_accuracy(labels, km.labels, 7))
        _row(f"fig3.nystrom_m{m}", (time.perf_counter() - t0) / 5 * 1e6,
             f"err={np.mean(errs):.3f};acc={np.mean(accs):.3f}")


def theorem1():
    from repro.core import theorem1_bounds, best_rank_r

    tight_any, tight_best = [], []
    t0 = time.perf_counter()
    for seed in range(15):
        rng = np.random.RandomState(seed)
        A = rng.randn(6, 4).astype(np.float32)
        K = jnp.asarray(A @ A.T)
        K_hat = best_rank_r(K, 2)
        excess, bound_any, bound_best = theorem1_bounds(K, K_hat, 2)
        tight_any.append(excess / max(bound_any, 1e-9))
        tight_best.append(excess / max(bound_best, 1e-9))
        assert excess <= bound_best + 1e-3
    _row("theorem1.tightness", (time.perf_counter() - t0) / 15 * 1e6,
         f"excess/tr(E)={np.mean(tight_best):.3f};"
         f"excess/2trnorm={np.mean(tight_any):.3f};violations=0")


def memory():
    """Memory to reach (near-)exact rank-2 error: ours vs Nystrom."""
    from repro.core import (polynomial_kernel, gram_matrix, nystrom,
                            exact_eig_from_gram, kernel_approx_error,
                            randomized_eig)
    from repro.data import blob_ring

    X, _ = blob_ring(jax.random.PRNGKey(0), 4000)
    n = 4000
    kern = polynomial_kernel(gamma=0.0, degree=2)
    K = gram_matrix(kern, X)
    eig = randomized_eig(jax.random.PRNGKey(1), kern, X, 2, oversampling=10)
    err_ours = kernel_approx_error(K, eig.Y)
    ours_bytes = n * 12 * 4            # W: n x r'
    m = 12
    while m <= 512:
        errs = [kernel_approx_error(K, nystrom(jax.random.PRNGKey(s), kern,
                                               X, m=m, r=2).Y)
                for s in range(3)]
        if np.mean(errs) <= 1.02 * err_ours:
            break
        m *= 2
    ny_bytes = n * m * 4               # C: n x m
    _row("memory.ours", 0, f"bytes={ours_bytes};err={err_ours:.3f}")
    _row("memory.nystrom_matched", 0,
         f"bytes={ny_bytes};m={m};ratio={ny_bytes/ours_bytes:.1f}x")


def kernels():
    from repro.kernels import fwht_pallas, gram_stripe_pallas, assign_pallas
    from repro.kernels.fwht.ref import fwht_ref
    from repro.kernels.gram.ref import gram_stripe_ref
    from repro.kernels.kmeans_assign.ref import assign_ref

    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 16))
    us_p = _timeit(lambda: fwht_pallas(x, interpret=True))
    us_r = _timeit(lambda: fwht_ref(x))
    err = float(jnp.max(jnp.abs(fwht_pallas(x, interpret=True) -
                                fwht_ref(x))))
    _row("kernels.fwht_4096x16", us_p, f"ref_us={us_r:.0f};maxerr={err:.1e}")

    X = jax.random.normal(jax.random.PRNGKey(1), (19, 2048))
    Xb = X[:, :256]
    us_p = _timeit(lambda: gram_stripe_pallas(X, Xb, interpret=True))
    err = float(jnp.max(jnp.abs(gram_stripe_pallas(X, Xb, interpret=True) -
                                gram_stripe_ref(X, Xb))))
    _row("kernels.gram_2048x256", us_p, f"maxerr={err:.1e}")

    Y = jax.random.normal(jax.random.PRNGKey(2), (4096, 16))
    C = jax.random.normal(jax.random.PRNGKey(3), (8, 16))
    us_p = _timeit(lambda: assign_pallas(Y, C, interpret=True))
    l1, _ = assign_pallas(Y, C, interpret=True)
    l2, _ = assign_ref(Y, C)
    _row("kernels.assign_4096x16x8", us_p,
         f"label_agreement={float(jnp.mean(l1 == l2)):.4f}")


def backends(names=None):
    """Estimator-API sweep: every backend on the same data + kernel.

    The unified-front-door version of Table 1's comparison: accuracy,
    approximation error, and the fit memory model per registered backend,
    all through repro.api.KernelKMeans.
    """
    from repro.api import KernelKMeans, available_backends, fit_memory_bytes
    from repro.core import clustering_accuracy, kernel_approx_error_streaming
    from repro.data import blob_ring

    X, labels = blob_ring(jax.random.PRNGKey(0), 4000)
    n = X.shape[1]
    for name in (names or available_backends()):
        est = KernelKMeans(k=2, r=2, kernel="polynomial",
                           kernel_params=_POLY, backend=name)
        t0 = time.perf_counter()
        est.fit(X, key=jax.random.PRNGKey(7))
        us = (time.perf_counter() - t0) * 1e6
        err = kernel_approx_error_streaming(est.model_.kernel_fn(), X,
                                            est.embedding_)
        acc = clustering_accuracy(labels, est.labels_, 2)
        mem = fit_memory_bytes(name, n, 2, **est.backend_params)
        _row(f"backends.{name}", us,
             f"err={err:.2f};acc={acc:.2f};fit_bytes={mem};"
             f"n_ref={est.model_.n_ref}")


_SECTIONS = {"table1": table1, "fig3": fig3, "theorem1": theorem1,
             "memory": memory, "kernels": kernels, "backends": backends}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sections", default=",".join(_SECTIONS),
                    help=f"comma list of {sorted(_SECTIONS)}")
    ap.add_argument("--backends", default=None,
                    help="comma list restricting the estimator sweep "
                         "(default: every registered backend)")
    args = ap.parse_args()
    sections = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = set(sections) - set(_SECTIONS)
    if unknown:
        ap.error(f"unknown section(s) {sorted(unknown)}; "
                 f"have {sorted(_SECTIONS)}")
    print("name,us_per_call,derived")
    for name in sections:
        if name == "backends" and args.backends:
            backends([b.strip() for b in args.backends.split(",")])
        else:
            _SECTIONS[name]()


if __name__ == "__main__":
    main()

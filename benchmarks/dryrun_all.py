"""Fan out the multi-pod dry-run over every (arch x shape x mesh) cell.

One subprocess per cell (jax locks device count at first init). Results are
cached as artifacts/dryrun/<arch>__<shape>__<sp|mp>.json; existing files are
skipped so the sweep is resumable.

Usage: PYTHONPATH=src python -m benchmarks.dryrun_all [--multipod-only]
       [--single-pod-only] [--timeout 3600]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

ARCHS = ["rwkv6-1.6b", "recurrentgemma-2b", "whisper-large-v3",
         "phi4-mini-3.8b", "qwen3-14b", "pixtral-12b", "mixtral-8x7b",
         "dbrx-132b", "command-r-plus-104b", "nemotron-4-340b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    cells = [(a, s, mp) for mp in meshes for a in ARCHS for s in SHAPES]
    done = fails = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        path = out / f"{tag}.json"
        if path.exists():
            done += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", str(out)]
        if mp:
            cmd.append("--multipod")
        t0 = time.time()
        print(f"[dryrun_all] {tag} ...", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
        except subprocess.TimeoutExpired:
            path.write_text(json.dumps({"arch": arch, "shape": shape,
                                        "mesh": "mp" if mp else "sp",
                                        "status": "timeout"}))
            print(f"[dryrun_all] {tag} TIMEOUT", flush=True)
            fails += 1
            continue
        if r.returncode != 0:
            err = (r.stderr or "")[-2000:]
            path.write_text(json.dumps({"arch": arch, "shape": shape,
                                        "mesh": "mp" if mp else "sp",
                                        "status": "error", "stderr": err}))
            print(f"[dryrun_all] {tag} FAILED\n{err}", flush=True)
            fails += 1
        else:
            done += 1
            print(f"[dryrun_all] {tag} ok ({time.time()-t0:.0f}s)",
                  flush=True)
    print(f"[dryrun_all] finished: {done} ok/skipped, {fails} failures")


if __name__ == "__main__":
    main()

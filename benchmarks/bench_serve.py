"""Serving throughput benchmark -> BENCH_serve.json.

Fits a model on synthetic blob+ring data, then measures bucketed
assignments/sec through repro.serve.bench at several query batch sizes.

  PYTHONPATH=src python benchmarks/bench_serve.py
  PYTHONPATH=src python benchmarks/bench_serve.py --n 8000 \
      --batch-sizes 64,512,4096 --out BENCH_serve.json
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--l", type=int, default=10)
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--batch-sizes", default="64,512")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.data import blob_ring
    from repro.serve import benchmark_assign, fit_model, write_bench

    key = jax.random.PRNGKey(args.seed)
    X, _ = blob_ring(key, n=args.n)
    model = fit_model(jax.random.PRNGKey(args.seed + 1), X, k=args.k,
                      r=args.r, oversampling=args.l, block=args.block)
    bench = benchmark_assign(
        model, batch_sizes=[int(b) for b in args.batch_sizes.split(",")],
        repeats=args.repeats, key=jax.random.PRNGKey(args.seed + 2))
    write_bench(args.out, bench)
    for row in bench["results"]:
        print(f"batch {row['batch_size']:>6d} (bucket {row['bucket']:>5d}): "
              f"{row['assignments_per_sec']:>12.0f} assignments/sec")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

"""Serving benchmark -> BENCH_serve.json: sync, async, and sharded modes.

Fits a model on synthetic blob+ring data through `repro.api.KernelKMeans`
(--backend picks the approximation backend), then measures:

  --mode sync     bucketed assignments/sec per batch size (MicroBatcher)
  --mode backends accuracy + fit memory + serving throughput for every
                  registered approximation backend (onepass-srht,
                  onepass-gaussian, nystrom, exact) fitted through the
                  unified KernelKMeans front door on the same data
  --mode async    request latency p50/p95/p99 + SLO accounting through
                  the deadline-driven AsyncBatcher
  --mode fused    fused gram->projection Pallas stripe vs the two-pass
                  gram+projection executables, plus the per-stripe HBM
                  delta from launch/hlo_analysis
  --mode swap     async traffic across a warm hot-swap: measured flip
                  duration + p95 before/after from the surviving
                  LatencyStats
  --mode all      all of the above (default)

--fused-embed on --interpret forces the Pallas stripe engine for the
sync/async modes even on CPU (interpret mode) — the CI hook.

Add --sharded to run the extension matmul mesh-sharded over all local
devices (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to fake a
CPU mesh).

  PYTHONPATH=src python benchmarks/bench_serve.py
  PYTHONPATH=src python benchmarks/bench_serve.py --n 8000 \
      --batch-sizes 64,512,4096 --mode all --slo-ms 100 --out BENCH_serve.json
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--l", type=int, default=10)
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--backend", default="onepass-srht",
                    choices=["onepass-srht", "onepass-gaussian", "nystrom",
                             "exact"],
                    help="approximation backend the served model is "
                         "fitted with")
    ap.add_argument("--nystrom-m", type=int, default=None,
                    help="landmark count for --backend nystrom")
    ap.add_argument("--batch-sizes", default="64,512")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--mode", default="all",
                    choices=["sync", "async", "fused", "swap", "backends",
                             "all"])
    ap.add_argument("--fused-embed", default="auto",
                    choices=["auto", "on", "off"],
                    help="extension stripe engine for sync/async modes: "
                         "fused Pallas (on), two-pass (off), or the "
                         "backend default (auto)")
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (forces "
                         "the Pallas path on CPU)")
    ap.add_argument("--async-requests", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--slo-ms", type=float, default=250.0)
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-shard the extension over all local devices")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.api import KernelKMeans
    from repro.data import blob_ring
    from repro.serve import write_bench
    from repro.serve.bench import format_bench, run_benches

    key = jax.random.PRNGKey(args.seed)
    X, labels = blob_ring(key, n=args.n)
    backend_params = ({"oversampling": args.l}
                      if args.backend.startswith("onepass-") else
                      {"m": args.nystrom_m}
                      if args.backend == "nystrom"
                      and args.nystrom_m is not None else {})
    est = KernelKMeans(k=args.k, r=args.r, backend=args.backend,
                       backend_params=backend_params, block=args.block)
    model = est.fit(X, key=jax.random.PRNGKey(args.seed + 1)).model_
    mesh = None
    if args.sharded:
        n_dev = len(jax.devices())
        if n_dev < 2:
            ap.error(f"--sharded needs >= 2 devices, have {n_dev}")
        mesh = jax.make_mesh((n_dev,), ("data",))

    modes = (("sync", "async", "fused", "swap", "backends")
             if args.mode == "all" else (args.mode,))
    embed_fused = {"auto": None, "on": True, "off": False}[args.fused_embed]
    bench = run_benches(
        model, modes=modes,
        batch_sizes=[int(b) for b in args.batch_sizes.split(",")],
        repeats=args.repeats, key=jax.random.PRNGKey(args.seed + 2),
        embed_fused=embed_fused,
        interpret=True if args.interpret else None,
        mesh=mesh, n_requests=args.async_requests,
        max_wait_ms=args.max_wait_ms, slo_ms=args.slo_ms,
        data=(X, labels))
    write_bench(args.out, bench)
    print(format_bench(bench))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

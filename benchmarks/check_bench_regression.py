"""Bench regression gate: diff fresh BENCH_serve.json vs the baseline.

CI's serve-smoke job runs `serve_cluster --smoke` (which writes
BENCH_serve.json) and then this script against the committed
BENCH_baseline.json. A metric regressing beyond --tolerance (default
0.25 = 25%) fails the job; the full delta table is printed and, when
$GITHUB_STEP_SUMMARY is set, appended to the job summary as markdown.

Gated metrics:

  sync    assignments_per_sec per batch size   (lower = regression)
  async   queries_per_sec                      (lower = regression)
          latency p95 ms                       (higher = regression)
  fused   fused + two_pass queries_per_sec     (lower = regression)
  swap    p95 before/after the hot-swap        (higher = regression)
  backends  per-backend clustering accuracy    (lower = regression;
            dimensionless — never speed-normalized)
            and assignments_per_sec            (lower = regression)
  stream  partial_fit cols/sec                 (lower = regression)
          re-eig wall seconds                  (higher = regression)
  fit_scaling  single-host + sharded one-pass fit cols/sec per n
                                               (lower = regression)
  fleet   queries_per_sec per worker count     (lower = regression)
          admitted p99 ms under overload       (higher = regression;
            this is THE shedding claim: the queue cap bounds the
            admitted tail even when 90% of offered load is refused)

Informational (reported, never gated): async queue-wait p95, the
swap flip duration — at ~1 ms / ~1 us scale they are OS-scheduler
jitter, not serving performance — per-backend fit wall time
(dominated by eigh/K-means restarts, too machine-noisy to gate), and
the stream rollout's detection-to-swap latency (it embeds a full
K-means refit, same noise class as fit_s).

The committed baseline and the CI runner are different (and
burstable-CPU) machines, so raw wall-clock numbers drift with hardware
state even when the serving code is unchanged. Every BENCH_serve.json
therefore carries a `calibration` section (best-call time of a fixed
jitted matmul, `serve.bench.machine_calibration`); the gate rescales
the fresh metrics by the baseline/fresh calibration ratio before
diffing, so the ±25% tolerance measures the serving CODE, not the
machine. The speed factor is printed with the table. If either file
lacks calibration, raw numbers are compared (factor 1.0).

A metric present in the baseline but missing from the fresh run counts
as a regression (a bench section silently vanished); metrics only in the
fresh run are reported as `new` and never fail. Refresh the baseline
with --update after an intentional perf change (run the bench on a quiet
machine; the 25% tolerance absorbs runner-to-runner noise, not a
different benchmark configuration).

  PYTHONPATH=src python benchmarks/check_bench_regression.py
  PYTHONPATH=src python benchmarks/check_bench_regression.py --update
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Optional, Tuple


def _dig(d: Dict, *path):
    for p in path:
        if not isinstance(d, dict) or p not in d:
            return None
        d = d[p]
    return d


# Reported in the table but never fail the gate (see module docstring).
# swap/flip_ms is microsecond-scale (two dict stores under a lock), so a
# relative tolerance on it would gate OS-scheduler jitter, not code; the
# swap p95s are gated like the async p95 they come from. Backend fit wall
# time includes K-means restarts and eigh — too machine-noisy to gate,
# unlike the same section's accuracy/throughput.
INFO_METRICS = {"async/queue_wait_p95_ms", "swap/flip_ms",
                "stream/detect_to_swap_s", "fleet/promote_s",
                "fleet/rollback_s", "fleet/overload_shed_rate"}
# fit_scaling_bytes/* is the analytic bytes-moved model (HLO traffic
# counts) — it moves only when the kernels change, so it is reported for
# the roofline story but never gated on a tolerance meant for timing.
INFO_PREFIXES = ("backends/fit_s/", "fit_scaling_bytes/")
# Dimensionless metrics: machine speed is irrelevant, never rescale.
# The fleet shed rate is a RATIO of offered load (a property of the
# admission caps, not of machine speed); the rollout walls embed compile
# warmup, same noise class as fit_s.
NO_NORMALIZE_PREFIXES = ("backends/accuracy/", "fit_scaling_bytes/",
                         "fleet/overload_shed_rate")


def collect_metrics(bench: Dict) -> Dict[str, Tuple[float, bool]]:
    """Flatten one BENCH_serve.json dict into {metric: (value, hib)}."""
    out: Dict[str, Tuple[float, bool]] = {}
    for row in bench.get("results", []):
        out[f"sync/batch={row['batch_size']}/assignments_per_sec"] = (
            float(row["assignments_per_sec"]), True)
    qps = _dig(bench, "async", "queries_per_sec")
    if qps is not None:
        out["async/queries_per_sec"] = (float(qps), True)
    p95 = _dig(bench, "async", "latency", "latency_ms", "p95")
    if p95 is not None:
        out["async/latency_p95_ms"] = (float(p95), False)
    qw95 = _dig(bench, "async", "latency", "queue_wait_ms", "p95")
    if qw95 is not None:
        out["async/queue_wait_p95_ms"] = (float(qw95), False)
    for engine in ("fused", "two_pass"):
        v = _dig(bench, "fused", engine, "queries_per_sec")
        if v is not None:
            out[f"fused/{engine}/queries_per_sec"] = (float(v), True)
    for metric in ("flip_ms", "p95_before_ms", "p95_after_ms"):
        v = _dig(bench, "swap", metric)
        if v is not None:
            out[f"swap/{metric}"] = (float(v), False)
    # Backend sweep: accuracy and serving throughput are gated per
    # backend (accuracy is dimensionless — diff() skips the machine-speed
    # normalization for it, see NO_NORMALIZE_PREFIXES).
    for name, row in (_dig(bench, "backends", "per_backend") or {}).items():
        if "accuracy" in row:
            out[f"backends/accuracy/{name}"] = (float(row["accuracy"]),
                                                True)
        if "assignments_per_sec" in row:
            out[f"backends/assignments_per_sec/{name}"] = (
                float(row["assignments_per_sec"]), True)
        if "fit_s" in row:
            out[f"backends/fit_s/{name}"] = (float(row["fit_s"]), False)
    # Streaming fit: ingest throughput and re-eig cost are gated; the
    # rollout's detection-to-swap latency is info-only (INFO_METRICS).
    cols = _dig(bench, "stream", "partial_fit_cols_per_sec")
    if cols is not None:
        out["stream/partial_fit_cols_per_sec"] = (float(cols), True)
    reeig = _dig(bench, "stream", "reeig_s")
    if reeig is not None:
        out["stream/reeig_s"] = (float(reeig), False)
    d2s = _dig(bench, "stream", "rollout", "detect_to_swap_s")
    if d2s is not None:
        out["stream/detect_to_swap_s"] = (float(d2s), False)
    # Fleet soak: tier throughput per worker count and the admitted-
    # request p99 under overload are gated (each worker-count row diffs
    # against its own baseline — no cross-N speedup assert, a 1-CPU
    # runner cannot promise one); shed rate and rollout walls are info.
    for row in (_dig(bench, "fleet", "sweep") or []):
        out[f"fleet/workers={row['workers']}/queries_per_sec"] = (
            float(row["queries_per_sec"]), True)
    op99 = _dig(bench, "fleet", "overload", "admitted_p99_ms")
    if op99 is not None:
        out["fleet/overload_admitted_p99_ms"] = (float(op99), False)
    orate = _dig(bench, "fleet", "overload", "shed_rate")
    if orate is not None:
        out["fleet/overload_shed_rate"] = (float(orate), False)
    for metric, path in (("promote_s", ("promote", "wall_s")),
                         ("rollback_s", ("rollback", "wall_s"))):
        v = _dig(bench, "fleet", "rollout", *path)
        if v is not None:
            out[f"fleet/{metric}"] = (float(v), False)
    # Sharded-fit scaling sweep: ingest throughput (single-host and
    # mesh-sharded) is gated per n; the bytes-moved model is analytic
    # (INFO_PREFIXES / NO_NORMALIZE_PREFIXES above).
    for row in (_dig(bench, "fit_scaling", "rows") or []):
        n = row["n"]
        for which in ("single", "sharded"):
            v = row.get(f"{which}_cols_per_sec")
            if v is not None:
                out[f"fit_scaling/n={n}/{which}_cols_per_sec"] = (
                    float(v), True)
        by = row.get("bytes") or {}
        for metric in ("two_pass_bytes", "fused_bytes"):
            if metric in by:
                out[f"fit_scaling_bytes/n={n}/{metric}"] = (
                    float(by[metric]), False)
    return out


def speed_factor(baseline: Dict, fresh: Dict) -> float:
    """fresh-machine speed relative to the baseline machine (>1 = fresh
    machine is slower); wall-clock metrics are normalized by this."""
    b = _dig(baseline, "calibration", "matmul512_ms")
    f = _dig(fresh, "calibration", "matmul512_ms")
    if not b or not f:
        return 1.0
    return float(f) / float(b)


def diff(baseline: Dict, fresh: Dict, tolerance: float
         ) -> Tuple[List[Dict], bool, float]:
    """Returns (table rows, any_regression, speed factor)."""
    base_m = collect_metrics(baseline)
    fresh_m = collect_metrics(fresh)
    factor = speed_factor(baseline, fresh)
    rows: List[Dict] = []
    failed = False
    for name in sorted(set(base_m) | set(fresh_m)):
        b = base_m.get(name)
        f = fresh_m.get(name)
        if f is not None and not name.startswith(NO_NORMALIZE_PREFIXES):
            # Normalize out machine speed: throughput (higher-better)
            # scales up on a slower machine, latency scales down.
            val, hib = f
            f = (val * factor if hib else val / factor), hib
        if b is None:
            rows.append({"metric": name, "baseline": None,
                         "fresh": f[0], "delta": None, "status": "new"})
            continue
        info = (name in INFO_METRICS
                or name.startswith(INFO_PREFIXES))
        if f is None:
            rows.append({"metric": name, "baseline": b[0], "fresh": None,
                         "delta": None,
                         "status": "info" if info else "MISSING"})
            failed = failed or not info
            continue
        bval, hib = b
        fval = f[0]
        delta = (fval - bval) / bval if bval else 0.0
        regressed = (not info and
                     ((delta < -tolerance) if hib else (delta > tolerance)))
        rows.append({"metric": name, "baseline": bval, "fresh": fval,
                     "delta": delta,
                     "status": ("info" if info else
                                "REGRESSION" if regressed else "ok")})
        failed = failed or regressed
    return rows, failed, factor


def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "—"
    return f"{v:,.2f}" if abs(v) < 1000 else f"{v:,.0f}"


def format_table(rows: List[Dict], tolerance: float,
                 factor: float = 1.0) -> str:
    lines = [f"### Serve bench regression gate (tolerance ±{tolerance:.0%})",
             "",
             f"machine speed factor {factor:.2f}x (fresh vs baseline "
             f"calibration matmul; fresh columns are speed-normalized)",
             "", "| metric | baseline | fresh | delta | status |",
             "|---|---:|---:|---:|---|"]
    for r in rows:
        delta = "—" if r["delta"] is None else f"{r['delta']:+.1%}"
        lines.append(f"| {r['metric']} | {_fmt(r['baseline'])} | "
                     f"{_fmt(r['fresh'])} | {delta} | {r['status']} |")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default="BENCH_serve.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression allowed before failing")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh file over the baseline and exit")
    args = ap.parse_args()

    if args.update:
        shutil.copyfile(args.fresh, args.baseline)
        print(f"baseline updated: {args.fresh} -> {args.baseline}")
        return 0

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    rows, failed, factor = diff(baseline, fresh, args.tolerance)
    table = format_table(rows, args.tolerance, factor)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(table + "\n")
    if failed:
        print(f"\nFAIL: regression beyond {args.tolerance:.0%} "
              f"(or a bench section vanished); see table above. "
              f"Intentional? refresh with --update.")
        return 1
    print("\nOK: all gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())

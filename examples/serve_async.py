"""Async serving walkthrough: fit -> save -> load -> async query -> SLO.

The production shape of this repo in ~60 lines (see docs/SERVING.md for
the semantics of every knob used here):

  1. fit the paper's Alg. 1 once on blob+ring data,
  2. persist the FittedModel artifact and load it back via the registry,
  3. serve concurrent ragged requests through the async, SLO-accounted
     path (futures + deadline-driven flushing),
  4. print the latency table and assert p99 under a generous bound.

Run: PYTHONPATH=src python examples/serve_async.py
"""
import jax
import numpy as np

from repro.api import KernelKMeans
from repro.data import blob_ring
from repro.serve import DEFAULT_REGISTRY

# --- 1. fit: one streaming pass over kernel stripes, then K-means -------
# (backend="nystrom" or "exact" here would change NOTHING below: the
# whole serving path is backend-agnostic.)
X, _ = blob_ring(jax.random.PRNGKey(0), n=2000)
est = KernelKMeans(k=2, r=2, kernel="polynomial",
                   kernel_params={"gamma": 0.0, "degree": 2}, block=512)
est.fit(X, key=jax.random.PRNGKey(1))

# --- 2. persist + load: what a deployment actually ships ----------------
path = est.save("serve_artifacts/async_demo")
served = DEFAULT_REGISTRY.load("demo", path, overwrite=True)
print(f"artifact: {path} (n={served.spec.n}, r={served.spec.r}, "
      f"backend={served.spec.backend})")

# --- 3. async serving: futures per request, deadline-driven flush -------
# max_wait_ms is the coalescing deadline (p99 knob); slo_ms the objective
# we account against. The registry caches the scheduler, so every later
# caller shares its latency accounting.
sched = DEFAULT_REGISTRY.scheduler("demo", max_wait_ms=5.0, slo_ms=2000.0,
                                   max_bucket=256)

# Warm the pow-2 buckets once so the table below shows steady-state
# latency, not first-call compile spikes (~seconds on CPU).
for b in (8, 16, 32, 64, 128, 256):
    sched.batcher.assign_batch(np.zeros((served.spec.p, b), np.float32))

rng = np.random.RandomState(0)
with sched:                         # starts the background pump thread
    futures = []
    for _ in range(100):            # 100 concurrent ragged requests
        width = rng.randint(1, 48)
        futures.append(sched.submit(rng.randn(served.spec.p, width)
                                    .astype(np.float32)))
    results = [f.result(timeout=60.0) for f in futures]
# leaving the context stops the pump and flushes anything still pending

labels = np.concatenate([lab for lab, _ in results])
print(f"served {len(futures)} requests / {labels.size} queries; "
      f"cluster sizes: {np.bincount(labels).tolist()}")

# --- 4. the SLO read-out ------------------------------------------------
print("\nlatency table")
print(sched.latency.format_table())

summary = DEFAULT_REGISTRY.latency_summary("demo")
p99 = summary["latency_ms"]["p99"]
assert p99 < 2000.0, f"p99 {p99:.1f} ms blew the (generous) 2 s bound"
assert summary["requests"] == 100
print(f"\nOK: p99 = {p99:.2f} ms < 2000 ms, "
      f"{summary['slo_violations']} SLO violations")

"""Quickstart: the paper's Table-1 experiment in ~20 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (polynomial_kernel, one_pass_kernel_kmeans, kmeans,
                        clustering_accuracy, kernel_approx_error_streaming)
from repro.data import blob_ring

# Fig. 1 data: a Gaussian blob enclosed by a ring — K-means cannot separate
# them, the degree-2 polynomial kernel can.
X, labels = blob_ring(jax.random.PRNGKey(0), n=4000)
kernel = polynomial_kernel(gamma=0.0, degree=2)

# Alg. 1: one streaming pass over kernel stripes (K never materialized),
# SRHT-preconditioned sketch, rank-2 linearization, standard K-means.
result = one_pass_kernel_kmeans(jax.random.PRNGKey(1), kernel, X,
                                k=2, r=2, oversampling=10)

acc = clustering_accuracy(labels, result.labels, 2)
err = kernel_approx_error_streaming(kernel, X, result.Y)
plain = clustering_accuracy(
    labels, kmeans(jax.random.PRNGKey(2), X.T, 2).labels, 2)
print(f"one-pass kernel K-means: accuracy {acc:.3f}, approx error {err:.3f}")
print(f"plain K-means baseline:  accuracy {plain:.3f}")
assert acc > 0.95 and plain < 0.9

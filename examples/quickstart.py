"""Quickstart: the paper's Table-1 experiment through the estimator API.

One front door (`repro.api.KernelKMeans`) over pluggable approximation
backends — the paper's one-pass method is the default; Nystrom and the
exact eigendecomposition are one keyword away, which is the whole
comparison the paper is about.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import KernelKMeans
from repro.core import (clustering_accuracy, kernel_approx_error_streaming,
                        kmeans)
from repro.data import blob_ring

# Fig. 1 data: a Gaussian blob enclosed by a ring — K-means cannot separate
# them, the degree-2 polynomial kernel can.
X, labels = blob_ring(jax.random.PRNGKey(0), n=4000)

# Alg. 1 via the front door: one streaming pass over kernel stripes (K
# never materialized), SRHT-preconditioned sketch, rank-2 linearization,
# standard K-means. backend="nystrom" / "exact" swaps the approximation;
# everything downstream (predict, save, the whole serving stack) is
# backend-agnostic.
est = KernelKMeans(k=2, r=2, kernel="polynomial",
                   kernel_params={"gamma": 0.0, "degree": 2},
                   backend="onepass-srht",
                   backend_params={"oversampling": 10})
est.fit(X, key=jax.random.PRNGKey(1))

acc = clustering_accuracy(labels, est.labels_, 2)
err = kernel_approx_error_streaming(est.model_.kernel_fn(), X,
                                    est.embedding_)
plain = clustering_accuracy(
    labels, kmeans(jax.random.PRNGKey(2), X.T, 2).labels, 2)
print(f"one-pass kernel K-means: accuracy {acc:.3f}, approx error {err:.3f}")
print(f"plain K-means baseline:  accuracy {plain:.3f}")
assert acc > 0.95 and plain < 0.9

# The same fit is immediately servable: out-of-sample points assign
# through the Nystrom-style extension (see docs/SERVING.md for the
# production path: artifact -> registry -> batched/async serving).
X_new = jax.random.normal(jax.random.PRNGKey(3), (2, 64))
print(f"assigned {est.predict(X_new).size} new points; "
      f"score {est.score(X_new):.2f}")

"""Streaming walkthrough: partial_fit -> serve -> drift -> refit -> swap.

Fit as a living service in ~70 lines (docs/SERVING.md "Streaming &
drift" has the semantics of every knob used here):

  1. stream the initial distribution in chunks through
     `KernelKMeans.partial_fit` (capacity leaves room to keep going),
  2. publish + register the model and serve it asynchronously,
  3. watch the served traffic with a DriftMonitor,
  4. when the distribution drifts, a RetrainWorker refits from the
     accumulated sketch, publishes the next version, and warm-swaps the
     live row — pending requests drain into the old model (zero
     stranded futures), the monitor rebinds to the new one.

Run: PYTHONPATH=src python examples/stream_refit.py
"""
import numpy as np

from repro.api import KernelKMeans
from repro.core.metrics import clustering_accuracy
from repro.serve import DEFAULT_REGISTRY, VersionStore
from repro.stream import DriftMonitor, RetrainWorker

rng = np.random.RandomState(0)


def blobs(xs, n_per=100):
    """Two-row blobs centered at the given x positions."""
    cols, labs = [], []
    for i, x0 in enumerate(xs):
        c = np.zeros((2, n_per), np.float32)
        c[0] = x0 + 0.25 * rng.randn(n_per)
        c[1] = 0.25 * rng.randn(n_per)
        cols.append(c)
        labs.append(np.full(n_per, i))
    return np.concatenate(cols, axis=1), np.concatenate(labs)


# --- 1. streaming fit: chunked ingest, re-eig at the end ----------------
# capacity sizes the sketch test matrix up front: 400 columns of room,
# 200 used now — the rest is headroom for the post-drift refit. Chunked
# ingest is bit-identical to a one-shot fit over the same columns.
X0, _ = blobs((-2.0, 2.0))
est = KernelKMeans(k=2, r=2, kernel="linear", backend="onepass-srht",
                   block=64)
for lo in range(0, 200, 50):
    est.partial_fit(X0[:, lo:lo + 50], key=0, capacity=400,
                    reeig=(lo == 150))           # cheap ingest, one re-eig
print(f"streamed fit: {est.stream_progress}")

# --- 2. publish + serve ------------------------------------------------
store = VersionStore("serve_artifacts/stream_demo_versions", keep=3)
DEFAULT_REGISTRY.register("stream-demo", est.model_, overwrite=True,
                          version=store.publish(est.model_))
sched = DEFAULT_REGISTRY.scheduler("stream-demo", max_wait_ms=5.0)

# --- 3. drift monitor + retrain worker ---------------------------------
monitor = DriftMonitor(est.model_, ref_labels=est.labels_,
                       chi2_threshold=30.0, min_queries=64)
worker = RetrainWorker(
    "stream-demo", DEFAULT_REGISTRY, store, monitor,
    refit_fn=lambda report: est.partial_fit(Xd).model_)

# Healthy traffic: observe what was served; the monitor stays quiet.
Xh = X0[:, rng.permutation(200)]
for lo in range(0, 200, 40):
    chunk = Xh[:, lo:lo + 40]
    fut = sched.submit(chunk)
    sched.flush()
    monitor.observe(chunk, fut.result()[0])
assert worker.step() is None, "no drift yet"

# --- 4. the distribution drifts ----------------------------------------
Xd, yd = blobs((3.0, 8.0))
stale_acc = clustering_accuracy(yd, est.predict(Xd), 2)
for lo in range(0, 200, 40):
    chunk = Xd[:, lo:lo + 40]
    fut = sched.submit(chunk)
    sched.flush()
    monitor.observe(chunk, fut.result()[0])

rollout = worker.step()                          # fires: refit+publish+swap
assert rollout is not None and worker.step() is None
new_est = KernelKMeans.from_model(DEFAULT_REGISTRY.get("stream-demo"))
new_acc = clustering_accuracy(yd, new_est.predict(Xd), 2)
print(f"drift: {rollout.drift.reason}")
print(f"rollout: v{rollout.version} in {rollout.detect_to_swap_s:.3f} s "
      f"(refit {rollout.refit_s:.3f} s, publish {rollout.publish_s:.3f} s, "
      f"swap {rollout.swap_s:.3f} s), drained "
      f"{rollout.swap.drained_requests} pending requests")
print(f"accuracy on the drifted distribution: stale {stale_acc:.2f} -> "
      f"refit {new_acc:.2f}")
assert new_acc > stale_acc

"""Integration point: cluster LM activations with the paper's method.

Runs a (reduced) qwen3 forward pass over synthetic prompts from two
distinct token distributions, harvests last-position hidden states, and
clusters them with one-pass randomized kernel K-means (RBF kernel). The
two prompt populations must be recovered.

Run: PYTHONPATH=src python examples/cluster_embeddings.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelKMeans
from repro.configs import get_config
from repro.models.registry import get_api
from repro.core import clustering_accuracy

cfg = get_config("qwen3-14b", smoke=True)
api = get_api(cfg)
params = api.init(jax.random.PRNGKey(0), cfg, tp=1)

# Two prompt populations: tokens drawn from two disjoint 32-token sets
# (distinct "topics" in an untrained model's embedding space).
n_per, S = 64, 64
k1, k2 = jax.random.split(jax.random.PRNGKey(1))
pop_a = jax.random.randint(k1, (n_per, S), 0, 32)
pop_b = jax.random.randint(k2, (n_per, S), 32, 64)
tokens = jnp.concatenate([pop_a, pop_b]).astype(jnp.int32)
labels = np.array([0] * n_per + [1] * n_per)

# Harvest mean-pooled final activations (projected to logits space) as the
# per-prompt embedding, unit-normalized.
logits = api.forward(params, cfg, {"tokens": tokens}, 1)   # (B, S, V)
emb = jnp.mean(logits, axis=1)                             # (B, V)
emb = emb / (jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-6)

est = KernelKMeans(k=2, r=4, kernel="rbf", kernel_params={"gamma": 1.0},
                   backend_params={"oversampling": 10}, block=64)
est.fit(emb.T, key=jax.random.PRNGKey(2))
acc = clustering_accuracy(labels, est.labels_, 2)
print(f"clustered {2 * n_per} activation vectors: accuracy {acc:.3f}")
assert acc > 0.9

"""The paper's pipeline distributed over a mesh (8 simulated devices).

Data columns sharded, kernel stripes computed shard-locally, SRHT
preconditioning via the ppermute-butterfly distributed FWHT, Cholesky-QR,
distributed Lloyd. See DESIGN.md §5 / distributed/cluster.py.

Run: PYTHONPATH=src python examples/distributed_clustering.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import polynomial_kernel, clustering_accuracy
from repro.data import blob_ring
from repro.distributed.cluster import distributed_one_pass_kernel_kmeans

mesh = jax.make_mesh((jax.device_count(),), ("data",))
n = 4096                                   # power of two (pre-padded)
X, labels = blob_ring(jax.random.PRNGKey(0), n=n)
X = jax.device_put(X, NamedSharding(mesh, P(None, "data")))

res = distributed_one_pass_kernel_kmeans(
    jax.random.PRNGKey(1), polynomial_kernel(degree=2), X, k=2, r=2,
    mesh=mesh, oversampling=10, block=512)

acc = clustering_accuracy(labels, np.asarray(res.labels), 2)
print(f"devices={jax.device_count()} n={n} accuracy={acc:.3f} "
      f"eigvals={np.asarray(res.eigvals).round(1)}")
assert acc > 0.95

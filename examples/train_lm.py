"""End-to-end driver: train a small LM for a few hundred steps on CPU.

Any of the 10 assigned architectures is selectable (reduced config); the
loss must fall. Uses the same train_step / sharding / checkpoint stack that
the production launcher lowers for the 512-chip mesh.

Run: PYTHONPATH=src python examples/train_lm.py [--arch mixtral-8x7b]
     [--steps 200]
"""
import sys

sys.argv = [sys.argv[0]] + sys.argv[1:]

from repro.launch.train import main

if __name__ == "__main__":
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "200"]
    sys.argv += ["--smoke", "--batch", "4", "--seq", "64",
                 "--ckpt-dir", "/tmp/repro_ckpt"]
    main()

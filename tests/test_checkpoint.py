"""Checkpoint save/restore: atomicity, retention, async, resharding API."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import (save_checkpoint, restore_checkpoint,
                                          latest_step, CheckpointManager,
                                          wait_for_async_saves)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 10, s)
    restored, step = restore_checkpoint(str(tmp_path), _state(1))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))
    assert int(restored["opt"]["step"]) == 3


def test_latest_and_multiple_steps(tmp_path):
    for step in (1, 5, 3):
        save_checkpoint(str(tmp_path), step, _state(step))
    assert latest_step(str(tmp_path)) == 5
    restored, step = restore_checkpoint(str(tmp_path), _state())
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_state(5)["w"]))


def test_async_save(tmp_path):
    save_checkpoint(str(tmp_path), 7, _state(), blocking=False)
    wait_for_async_saves()
    assert latest_step(str(tmp_path)) == 7


def test_tmp_dirs_invisible(tmp_path):
    """A stale .tmp dir (simulated crash mid-write) is never restored."""
    save_checkpoint(str(tmp_path), 2, _state())
    (tmp_path / "step_9.tmp").mkdir()
    assert latest_step(str(tmp_path)) == 2


def test_manager_interval_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=2, keep=2,
                            async_saves=False)
    for step in range(1, 11):
        mgr.maybe_save(step, _state(step))
    kept = sorted(int(p.name[5:]) for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert kept == [8, 10]


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"w": jnp.zeros((3, 3)),
                                           "opt": {"m": jnp.zeros((8, 4)),
                                                   "step": jnp.asarray(0)}})


def test_restore_with_mesh_resharding(tmp_path):
    """Restore onto a 1-device mesh with explicit pspecs (elastic path)."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = _state()
    save_checkpoint(str(tmp_path), 4, s)
    pspecs = {"w": P("data", "model"),
              "opt": {"m": P(None, None), "step": P()}}
    restored, _ = restore_checkpoint(str(tmp_path), _state(1), mesh=mesh,
                                     pspecs=pspecs)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))
    assert restored["w"].sharding.mesh.shape["data"] == 1

"""Shape/dtype sweep: fused assignment Pallas kernel vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import assign_pallas
from repro.kernels.kmeans_assign.ref import assign_ref

pytestmark = pytest.mark.kernels    # CI kernel-parity job runs -m kernels


@pytest.mark.parametrize("n,r,k", [(50, 2, 2), (1000, 2, 7), (513, 16, 100),
                                   (2048, 128, 8), (31, 5, 3)])
def test_assign_matches_ref(n, r, k):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + r + k))
    Y = jax.random.normal(k1, (n, r), jnp.float32)
    C = jax.random.normal(k2, (k, r), jnp.float32)
    labels, d2 = assign_pallas(Y, C, interpret=True)
    labels_ref, d2_ref = assign_ref(Y, C)
    # Distances must match tightly; labels can differ only on exact ties.
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-4)
    mism = np.asarray(labels) != np.asarray(labels_ref)
    assert mism.mean() < 0.01


def test_assign_padded_centroids_never_win():
    """k not a multiple of the pad: padded (zero) centroids are masked."""
    Y = jnp.ones((64, 4)) * 100.0   # far from origin
    C = jnp.ones((3, 4)) * 100.0    # 3 real centroids, 5 padded zeros
    labels, d2 = assign_pallas(Y, C, interpret=True)
    assert int(labels.max()) < 3
    np.testing.assert_allclose(np.asarray(d2), 0.0, atol=1e-5)


def test_assign_row_tiles():
    Y = jax.random.normal(jax.random.PRNGKey(1), (777, 9))
    C = jax.random.normal(jax.random.PRNGKey(2), (11, 9))
    want = assign_ref(Y, C)
    for rt in (64, 256, 1024):
        labels, d2 = assign_pallas(Y, C, row_tile=rt, interpret=True)
        np.testing.assert_allclose(np.asarray(d2), np.asarray(want[1]),
                                   rtol=1e-4, atol=1e-4)

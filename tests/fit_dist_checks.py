"""Multi-device sharded-fit checks (2 fake host devices), run in a
subprocess (see test_distributed.py) — jax locks the device count at
first init, so this cannot share the pytest process.

1-device BIT-identity with the canonical accumulator is pinned in
test_sharded_fit.py. Across real shards the engine's local-FWHT +
butterfly exchange and psum reductions re-associate floating point, so
vs single-host the contract is close agreement; what stays BITWISE on a
fixed mesh is chunk-size invariance (ragged partial_fit == one-shot
sharded fit) and artifact resume — both checked here on 2 devices.

Exit code 0 = all assertions passed.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile

import jax
import numpy as np
from jax.sharding import Mesh

_KW = dict(k=2, r=2, kernel="polynomial",
           kernel_params={"gamma": 0.0, "degree": 2}, block=32)
N = 96


def _models_equal(a, b):
    assert a.spec == b.spec
    for name, va in a._asdict().items():
        if name == "spec":
            continue
        vb = getattr(b, name)
        if va is None or vb is None:
            assert va is None and vb is None, name
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                          err_msg=name)


def check_two_device_fit_close_to_single_host():
    from repro.api import KernelKMeans
    from repro.core.metrics import clustering_accuracy
    from repro.data import blob_ring
    from repro.serve import ComputePolicy

    assert len(jax.devices()) == 2, jax.devices()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    X = np.asarray(X, np.float32)
    for backend in ("onepass-srht", "onepass-gaussian"):
        ref = KernelKMeans(backend=backend, **_KW).fit(X, key=7)
        sh = KernelKMeans(backend=backend, **_KW,
                          policy=ComputePolicy(mesh=mesh)).fit(X, key=7)
        np.testing.assert_allclose(np.asarray(sh.model_.stream_w),
                                   np.asarray(ref.model_.stream_w),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(sh.eigvals_),
                                   np.asarray(ref.eigvals_),
                                   rtol=2e-4, atol=2e-4)
        acc = clustering_accuracy(np.asarray(sh.labels_),
                                  np.asarray(ref.labels_), _KW["k"])
        assert acc == 1.0, f"{backend}: label agreement {acc}"
        print(f"2-device fit close to single-host ok ({backend})")


def check_chunk_invariance_bitwise_on_mesh():
    """On a FIXED mesh, ragged chunked ingest replays the identical
    per-block executables as one-shot — bitwise, 2 devices included."""
    from repro.api import KernelKMeans
    from repro.data import blob_ring
    from repro.serve import ComputePolicy

    mesh = Mesh(np.array(jax.devices()), ("data",))
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    X = np.asarray(X, np.float32)
    for backend in ("onepass-srht", "onepass-gaussian"):
        pol = ComputePolicy(mesh=mesh)
        one = KernelKMeans(backend=backend, **_KW, policy=pol).fit(X, key=7)
        est = KernelKMeans(backend=backend, **_KW, policy=pol)
        edges = [0, 40, 73, N]        # ragged: 40, 33, 23 columns
        for lo, hi in zip(edges[:-1], edges[1:]):
            est.partial_fit(X[:, lo:hi], key=7, capacity=N,
                            reeig=(hi == N))
        _models_equal(one.model_, est.model_)
        assert np.array_equal(np.asarray(one.labels_),
                              np.asarray(est.labels_))
        print(f"2-device ragged chunk invariance bitwise ok ({backend})")


def check_resume_from_artifact_bitwise_on_mesh():
    from repro.api import KernelKMeans
    from repro.data import blob_ring
    from repro.serve import ComputePolicy

    mesh = Mesh(np.array(jax.devices()), ("data",))
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    X = np.asarray(X, np.float32)
    pol = ComputePolicy(mesh=mesh)
    straight = KernelKMeans(**_KW, policy=pol)
    straight.partial_fit(X[:, :64], key=7, capacity=N)
    with tempfile.TemporaryDirectory() as tmp:
        path = straight.save(os.path.join(tmp, "art"))
        straight.partial_fit(X[:, 64:], key=7)
        resumed = KernelKMeans.load(path)
        resumed.policy = pol
        resumed.partial_fit(X[:, 64:], key=7)
    _models_equal(straight.model_, resumed.model_)
    print("2-device artifact resume bitwise ok")


if __name__ == "__main__":
    check_two_device_fit_close_to_single_host()
    check_chunk_invariance_bitwise_on_mesh()
    check_resume_from_artifact_bitwise_on_mesh()
    print("ALL FIT DIST CHECKS PASSED")

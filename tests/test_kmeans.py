"""Unit tests for the JAX Lloyd / k-means++ implementation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans, kmeans_plus_plus, clustering_accuracy
from repro.data import gaussian_blobs


def test_separated_blobs_recovered():
    X, labels = gaussian_blobs(jax.random.PRNGKey(0), n=600, p=5, k=4,
                               spread=0.05, center_scale=3.0)
    res = kmeans(jax.random.PRNGKey(1), X.T, 4)
    assert clustering_accuracy(labels, res.labels, 4) > 0.99


def test_objective_decreases_vs_random_assignment():
    X, _ = gaussian_blobs(jax.random.PRNGKey(0), n=300, p=4, k=3)
    Y = X.T
    res = kmeans(jax.random.PRNGKey(1), Y, 3)
    # Random centroids objective:
    C0 = Y[:3]
    d2 = jnp.sum((Y[:, None, :] - C0[None]) ** 2, axis=-1)
    rand_obj = float(jnp.sum(jnp.min(d2, axis=1)))
    assert float(res.objective) <= rand_obj


def test_kmeanspp_centroids_are_data_points():
    X, _ = gaussian_blobs(jax.random.PRNGKey(0), n=100, p=3, k=5)
    C = kmeans_plus_plus(jax.random.PRNGKey(1), X.T, 5)
    Y = np.asarray(X.T)
    for c in np.asarray(C):
        assert np.min(np.sum((Y - c) ** 2, axis=1)) < 1e-10


def test_restarts_never_hurt():
    X, _ = gaussian_blobs(jax.random.PRNGKey(3), n=200, p=2, k=6, spread=0.3)
    obj1 = float(kmeans(jax.random.PRNGKey(4), X.T, 6, n_restarts=1).objective)
    obj10 = float(kmeans(jax.random.PRNGKey(4), X.T, 6, n_restarts=10).objective)
    assert obj10 <= obj1 + 1e-6


def test_labels_shape_dtype_and_range():
    X, _ = gaussian_blobs(jax.random.PRNGKey(5), n=50, p=2, k=3)
    res = kmeans(jax.random.PRNGKey(6), X.T, 3)
    assert res.labels.shape == (50,)
    assert res.labels.dtype == jnp.int32
    assert int(res.labels.min()) >= 0 and int(res.labels.max()) < 3
    assert np.isfinite(float(res.objective))

"""Validate the trip-count-aware HLO analyzer on hand-computable programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    txt = _compile_text(lambda a, b: a @ b, x, w)
    res = analyze(txt)
    want = 2 * 64 * 128 * 256
    assert abs(res["flops"] - want) / want < 0.05, res["flops"]


def test_scan_multiplies_by_trip_count():
    L = 7

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    res = analyze(_compile_text(f, x, w))
    want = L * 2 * 32 * 64 * 64
    assert abs(res["flops"] - want) / want < 0.05, (res["flops"], want)


def test_nested_scans_multiply():
    Lo, Li = 3, 5

    def f(x, w):
        def outer(c, _):
            def inner(c2, wi):
                return jnp.tanh(c2 @ wi), None
            return jax.lax.scan(inner, c, w)[0], None
        return jax.lax.scan(outer, x, None, length=Lo)[0]

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((Li, 32, 32), jnp.float32)
    res = analyze(_compile_text(f, x, w))
    want = Lo * Li * 2 * 16 * 32 * 32
    assert abs(res["flops"] - want) / want < 0.05, (res["flops"], want)


def test_collectives_weighted_by_trips():
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (run under dryrun env)")


def test_grad_through_scan_counts_forward_and_backward():
    L = 4

    def loss(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out = jax.lax.scan(body, x, w)[0]
        return jnp.sum(out * out)

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((L, 16, 16), jnp.float32)
    res = analyze(_compile_text(lambda x, w: jax.grad(loss, 1)(x, w), x, w))
    # forward L dots + backward 2L dots = 3x forward FLOPs (within fusion
    # noise). Lower bound check: at least 2.5x single-pass.
    fwd = L * 2 * 8 * 16 * 16
    assert res["flops"] > 2.5 * fwd, (res["flops"], fwd)
    assert res["flops"] < 4.0 * fwd, (res["flops"], fwd)


def test_cond_branch_traffic_counted():
    """lax.cond branch bodies run at top level: their HBM traffic must be
    counted, not treated as fusion-internal (the pre-fix behaviour counted
    ~0 bytes for the branches)."""
    def f(pred, x):
        return jax.lax.cond(pred,
                            lambda v: jnp.tanh(v @ v) * 2.0,
                            lambda v: (v @ v) * 0.5 - 3.0, x)

    p = jax.ShapeDtypeStruct((), jnp.bool_)
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    txt = _compile_text(f, p, x)
    assert "conditional(" in txt, "cond not lowered to conditional; " \
        "pick a bigger body"
    res = analyze(txt)
    # Each branch holds one 512x512 dot (read 2 operands + write out =
    # 3 MB) plus an elementwise fusion; two branches >= ~6 MB of branch
    # traffic on top of the entry. The old analyzer reported < 1.1 MB
    # (entry-computation tuple plumbing only).
    mb = 512 * 512 * 4
    assert res["traffic_bytes"] >= 6 * mb, res["traffic_bytes"]
    # FLOPs of the two branch dots are counted too (weight 1 each).
    want_flops = 2 * 2 * 512 ** 3
    assert abs(res["flops"] - want_flops) / want_flops < 0.05, res["flops"]


def test_traffic_scales_with_trip_count():
    L = 9

    def f(x):
        def body(c, _):
            return c * 1.5 + 1.0, None
        return jax.lax.scan(body, x, None, length=L)[0]

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    res = analyze(_compile_text(f, x))
    # Each iteration reads+writes ~4MB x 2; total >= L * 8MB.
    assert res["traffic_bytes"] >= L * 8e6, res["traffic_bytes"]

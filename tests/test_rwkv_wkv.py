"""WKV chunked-parallel form vs naive recurrent reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv6 import _wkv_chunked


def wkv_recurrent_ref(r, k, v, logw, u, state0):
    """Naive per-step recurrence (the definition)."""
    B, S, H, dh = r.shape
    state = state0
    outs = []
    for t in range(S):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        out = jnp.einsum("bhd,bhde->bhe", rt, state + u[None, :, :, None] * kv)
        outs.append(out)
        state = state * wt[..., None] + kv
    return jnp.stack(outs, axis=1), state


@pytest.mark.parametrize("S,chunk", [(8, 4), (16, 16), (32, 8), (12, 4)])
def test_chunked_matches_recurrent(S, chunk):
    B, H, dh = 2, 3, 8
    key = jax.random.PRNGKey(S + chunk)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dh)))
    logw = jnp.maximum(logw, -60.0 / chunk)
    u = jax.random.normal(ks[4], (H, dh))
    state0 = jnp.zeros((B, H, dh, dh))
    got, gstate = _wkv_chunked(r, k, v, logw, u, state0, chunk=chunk)
    want, wstate = wkv_recurrent_ref(r, k, v, logw, u, state0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gstate), np.asarray(wstate),
                               rtol=2e-4, atol=2e-4)


def test_chunked_with_nonzero_initial_state():
    B, S, H, dh, chunk = 1, 16, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    logw = jnp.maximum(-jnp.exp(jax.random.normal(ks[3], (B, S, H, dh))),
                       -60.0 / chunk)
    u = jax.random.normal(ks[4], (H, dh))
    state0 = jax.random.normal(ks[5], (B, H, dh, dh))
    got, gs = _wkv_chunked(r, k, v, logw, u, state0, chunk=chunk)
    want, ws = wkv_recurrent_ref(r, k, v, logw, u, state0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

"""Multi-device distributed checks, run in a subprocess with
xla_force_host_platform_device_count=8 (see test_distributed.py).

Exit code 0 = all assertions passed.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def check_distributed_fwht():
    from repro.distributed.dfwht import distributed_fwht
    from repro.core.sketch import fwht

    mesh = jax.make_mesh((8,), ("data",))
    for n, c in [(64, 4), (512, 3), (8, 1)]:
        x = jax.random.normal(jax.random.PRNGKey(n), (n, c))
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        got = distributed_fwht(xs, mesh, "data")
        want = fwht(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    print("distributed_fwht ok")


def check_dfwht_on_2d_mesh():
    from repro.distributed.dfwht import distributed_fwht
    from repro.core.sketch import fwht

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 2))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    got = distributed_fwht(xs, mesh, "data")
    np.testing.assert_allclose(np.asarray(got), np.asarray(fwht(x)),
                               rtol=2e-4, atol=2e-4)
    print("dfwht 2d-mesh ok")


def check_sharded_train_step():
    """End-to-end: mixtral smoke config trains under a (2, 2) mesh with the
    production sharding rules; loss finite, params update."""
    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.train import steps as tsteps
    from repro.distributed import sharding as shd
    from repro.launch import specs
    from repro.launch.mesh import dp_axes

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = get_config("mixtral-8x7b", smoke=True)
    api = get_api(cfg)
    state = tsteps.init_train_state(jax.random.PRNGKey(0), cfg, api, tp=2)
    state_spec = shd.state_pspecs(
        jax.eval_shape(lambda: tsteps.init_train_state(
            jax.random.PRNGKey(0), cfg, api, tp=2)), mesh)
    batch = specs.train_inputs(cfg, 32, 4, concrete=True,
                               key=jax.random.PRNGKey(1))
    batch_spec = shd.batch_pspecs(jax.eval_shape(lambda: batch), mesh)

    def ns(spec):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                            is_leaf=lambda q: isinstance(q, P))
    state = jax.device_put(state, ns(state_spec))
    batch = jax.device_put(batch, ns(batch_spec))
    with mesh:
        with shd.activation_sharding(dp_axes(mesh)):
            step = jax.jit(tsteps.make_train_step(cfg, api, groups=2),
                           in_shardings=(ns(state_spec), ns(batch_spec)),
                           out_shardings=(ns(state_spec), None))
            state2, m1 = step(state, batch)
            state3, m2 = step(state2, batch)
    assert np.isfinite(float(m1["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])
    print("sharded_train_step ok", float(m1["loss"]), "->",
          float(m2["loss"]))


def check_sharded_vs_single_device_loss():
    """Same batch, same params: sharded loss == unsharded loss."""
    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.train import steps as tsteps
    from repro.launch import specs

    cfg = get_config("qwen3-14b", smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, tp=1)
    batch = specs.train_inputs(cfg, 32, 4, concrete=True,
                               key=jax.random.PRNGKey(1))
    logits_1dev = api.forward(params, cfg, batch, 1)
    loss_1dev = float(tsteps.cross_entropy(logits_1dev, batch["labels"]))

    from repro.distributed import sharding as shd
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ps = shd.param_pspecs(jax.eval_shape(lambda: params), mesh)
    bs = shd.batch_pspecs(jax.eval_shape(lambda: batch), mesh)

    def ns(spec):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                            is_leaf=lambda q: isinstance(q, P))
    params_s = jax.device_put(params, ns(ps))
    batch_s = jax.device_put(batch, ns(bs))
    with mesh:
        logits_s = jax.jit(lambda p, b: api.forward(p, cfg, b, 1),
                           in_shardings=(ns(ps), ns(bs)))(params_s, batch_s)
    loss_s = float(tsteps.cross_entropy(logits_s, batch["labels"]))
    assert abs(loss_s - loss_1dev) < 1e-2 * max(1.0, abs(loss_1dev)), (
        loss_s, loss_1dev)
    print("sharded_vs_single ok", loss_1dev, loss_s)


def check_sketched_allreduce_pmean():
    """Sketch all-reduce inside shard_map: mean of per-shard gradients
    (projected) equals projection of the mean."""
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import (sketch_params, compress,
                                               decompress)
    mesh = jax.make_mesh((8,), ("data",))
    n = 256
    g = jax.random.normal(jax.random.PRNGKey(0), (8, n))
    signs, rows = sketch_params(jax.random.PRNGKey(1), n, 32)

    def body(gl):
        s = compress(gl[0], signs, rows)
        s = jax.lax.pmean(s, "data")
        return decompress(s, signs, rows, n)[None]

    out = shard_map(body, mesh=mesh, in_specs=P("data", None),
                    out_specs=P("data", None), check_rep=False)(g)
    want = decompress(compress(jnp.mean(g, 0), signs, rows), signs, rows, n)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               rtol=1e-3, atol=1e-4)
    print("sketched_allreduce ok")


def check_distributed_clustering():
    """The distributed Alg. 1 matches the single-device pipeline: same
    kernel approx error regime and high clustering accuracy on blob+ring."""
    from repro.distributed.cluster import distributed_one_pass_kernel_kmeans
    from repro.core import (polynomial_kernel, gram_matrix,
                            exact_eig_from_gram, kernel_approx_error,
                            clustering_accuracy)
    from repro.data import blob_ring

    mesh = jax.make_mesh((8,), ("data",))
    n = 1024                                 # power of two (pre-padded)
    X, labels_true = blob_ring(jax.random.PRNGKey(0), n=n)
    kern = polynomial_kernel(gamma=0.0, degree=2)
    Xs = jax.device_put(X, NamedSharding(mesh, P(None, "data")))
    res = distributed_one_pass_kernel_kmeans(
        jax.random.PRNGKey(1), kern, Xs, k=2, r=2, mesh=mesh,
        oversampling=10, block=256)
    K = gram_matrix(kern, X)
    err = kernel_approx_error(K, np.asarray(res.Y))
    err_exact = kernel_approx_error(K, exact_eig_from_gram(K, 2).Y)
    assert err <= 1.05 * err_exact + 1e-6, (err, err_exact)
    acc = clustering_accuracy(labels_true, np.asarray(res.labels), 2)
    assert acc > 0.95, acc
    print(f"distributed_clustering ok err={err:.3f} "
          f"(exact {err_exact:.3f}) acc={acc:.3f}")


def check_sharded_extend():
    """Serving-side sharded extension (serve.extend.ShardedExtender)
    matches the single-device path to fp32 tolerance, end to end through
    MicroBatcher(mesh=) and AsyncBatcher, on ragged n (250 pads to 256
    over 8 shards) — on BOTH stripe engines: the two-pass gram+projection
    body and the fused extend_embed Pallas kernel (interpret mode) run
    per device inside the shard_map."""
    from repro.api import KernelKMeans
    from repro.data import blob_ring
    from repro.serve import (AsyncBatcher, MicroBatcher, ShardedExtender,
                             assign, embed)

    mesh = jax.make_mesh((8,), ("data",))
    X, _ = blob_ring(jax.random.PRNGKey(0), n=250)
    Xq = jax.random.normal(jax.random.PRNGKey(2), (2, 101)) * 1.5
    # rbf included: kappa(0, x) != 0, so this exercises the zero-column
    # projection-padding argument, not just harmless zero kernel columns.
    for kernel, params, r in (("polynomial", {"gamma": 0.0, "degree": 2}, 2),
                              ("rbf", {"gamma": 1.0}, 4)):
        m = KernelKMeans(k=2, r=r, kernel=kernel, kernel_params=params,
                         backend_params={"oversampling": 10},
                         block=64).fit(X, key=jax.random.PRNGKey(1)).model_
        ext = ShardedExtender(m, mesh)
        Ys, Y1 = ext.embed(Xq), embed(m, Xq)
        rel = (float(jnp.linalg.norm(Ys - Y1)) /
               max(float(jnp.linalg.norm(Y1)), 1e-30))
        assert rel <= 1e-5, (kernel, rel)
        # fused extend_embed Pallas stripe per device on the 8-way mesh.
        ext_f = ShardedExtender(m, mesh, fused=True, interpret=True)
        rel_f = (float(jnp.linalg.norm(ext_f.embed(Xq) - Y1)) /
                 max(float(jnp.linalg.norm(Y1)), 1e-30))
        assert rel_f <= 1e-5, (kernel, rel_f)
        lab1, _ = assign(m, Xq)
        labs, _ = ext.assign(Xq)
        assert np.array_equal(np.asarray(lab1), np.asarray(labs)), kernel
        lab_f, _ = ext_f.assign(Xq)
        assert np.array_equal(np.asarray(lab1), np.asarray(lab_f)), kernel
        # whole serving stack on the sharded path: bucketed sync + async,
        # two-pass and forced-fused.
        mb = MicroBatcher(m, max_bucket=64, mesh=mesh)
        lab_b, _ = mb.assign_batch(Xq)
        assert np.array_equal(lab_b, np.asarray(lab1)), kernel
        mb_f = MicroBatcher(m, max_bucket=64, mesh=mesh,
                            embed_fused=True, interpret=True)
        lab_bf, _ = mb_f.assign_batch(Xq)
        assert np.array_equal(lab_bf, np.asarray(lab1)), kernel
        ab = AsyncBatcher(m, max_wait_ms=5.0, max_bucket=64, mesh=mesh,
                          embed_fused=True, interpret=True)
        futs = [ab.submit(np.asarray(Xq[:, i:i + 25]))
                for i in range(0, 101, 25)]
        ab.flush()
        lab_a = np.concatenate([f.result()[0] for f in futs])
        assert np.array_equal(lab_a, np.asarray(lab1)), kernel
    print("sharded_extend ok (two-pass + fused)")


if __name__ == "__main__":
    check_distributed_clustering()
    check_sharded_extend()
    check_distributed_fwht()
    check_dfwht_on_2d_mesh()
    check_sketched_allreduce_pmean()
    check_sharded_vs_single_device_loss()
    check_sharded_train_step()
    print("ALL DIST CHECKS PASSED")

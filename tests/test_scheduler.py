"""AsyncBatcher + LatencyStats: deadline semantics, future resolution,
SLO accounting, async==sync bit-identity. All timing is driven by a fake
clock — no sleeps, no flakes."""
import jax
import numpy as np
import pytest

from repro.api import KernelKMeans
from repro.data import blob_ring
from repro.serve import (AsyncBatcher, LatencyStats, MicroBatcher,
                         ModelRegistry)

N, P, R, K, BLOCK = 250, 2, 2, 2, 64


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


@pytest.fixture(scope="module")
def model():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    return KernelKMeans(k=K, r=R, kernel="polynomial",
                        kernel_params={"gamma": 0.0, "degree": 2},
                        backend_params={"oversampling": 10},
                        block=BLOCK).fit(X, key=jax.random.PRNGKey(1)).model_


def _requests(widths, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randn(P, w).astype(np.float32) for w in widths]


# ---------------------------------------------------------------------------
# deadline / full-bucket flush triggers
# ---------------------------------------------------------------------------

def test_deadline_flush_fires_on_oldest_request(model):
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock, max_bucket=128)
    ab.submit(_requests([3])[0])
    clock.advance_ms(3.0)
    ab.submit(_requests([4])[0])          # younger request, 3 ms later
    assert not ab.due()
    assert ab.poll() == 0                 # nothing due yet
    clock.advance_ms(2.0)                 # oldest hits 5 ms; youngest at 2
    assert ab.due()
    assert ab.poll() == 2                 # deadline of the OLDEST flushes all
    assert ab.pending_requests == 0
    assert not ab.due()                   # empty queue is never due


def test_full_bucket_flushes_inline_without_deadline(model):
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=1e6, clock=clock, max_bucket=64)
    futs = [ab.submit(r) for r in _requests([30, 30])]
    assert ab.pending_requests == 2       # 60 < 64: still pending
    assert not futs[0].done()
    futs.append(ab.submit(_requests([10], seed=1)[0]))  # 70 >= 64: flush
    assert ab.pending_requests == 0
    assert all(f.done() for f in futs)


def test_flush_resolves_futures_in_submission_order(model):
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock, max_bucket=512)
    reqs = _requests([7, 33, 1, 49, 11])
    futs = [ab.submit(r) for r in reqs]
    assert ab.flush() == 5
    for r, f in zip(reqs, futs):
        labels, d2 = f.result(timeout=0)
        assert labels.shape == (r.shape[1],)
        assert d2.shape == (r.shape[1],)


def test_future_resolution_under_out_of_order_drains(model):
    """Requests flushed in separate rounds resolve to exactly their own
    slices, and reading futures in reverse order changes nothing."""
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock, max_bucket=512)
    reqs = _requests([5, 17, 9, 2])
    f0 = ab.submit(reqs[0])
    f1 = ab.submit(reqs[1])
    ab.flush()                            # round 1: reqs 0, 1
    f2 = ab.submit(reqs[2])
    f3 = ab.submit(reqs[3])
    ab.flush()                            # round 2: reqs 2, 3
    sync = MicroBatcher(model, max_bucket=512)
    for r in reqs:
        sync.submit(r)
    want = sync.drain()
    for f, (wl, wd) in zip([f3, f2, f1, f0], list(reversed(want))):
        labels, d2 = f.result(timeout=0)
        assert np.array_equal(labels, wl)
        assert np.array_equal(d2, wd)


# ---------------------------------------------------------------------------
# async == sync bit-identity
# ---------------------------------------------------------------------------

def test_async_bit_identical_to_sync_drain(model):
    reqs = _requests([7, 33, 1, 49, 11], seed=3)
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock, max_bucket=64)
    futs = [ab.submit(r) for r in reqs]
    ab.flush()
    sync = MicroBatcher(model, max_bucket=64)
    for r in reqs:
        sync.submit(r)
    want = sync.drain()
    for f, (wl, wd) in zip(futs, want):
        labels, d2 = f.result(timeout=0)
        assert np.array_equal(labels, wl), "async labels != sync drain"
        assert np.array_equal(d2, wd), "async distances != sync drain"


def test_interleaved_flushes_keep_labels(model):
    """Flush partitioning cannot change labels: one-flush-per-request
    equals one big drain."""
    reqs = _requests([9, 14, 3], seed=4)
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock, max_bucket=64)
    futs = []
    for r in reqs:
        futs.append(ab.submit(r))
        ab.flush()                        # worst case: no coalescing at all
    sync = MicroBatcher(model, max_bucket=64)
    for r in reqs:
        sync.submit(r)
    want = sync.drain()
    for f, (wl, _) in zip(futs, want):
        assert np.array_equal(f.result(timeout=0)[0], wl)


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------

def test_slo_counter_exact_with_fake_clock(model):
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=100.0, slo_ms=5.0, clock=clock,
                      max_bucket=512)
    ab.submit(_requests([4])[0])
    ab.flush()                            # waited 0 ms: inside SLO
    ab.submit(_requests([6])[0])
    clock.advance_ms(10.0)
    ab.flush()                            # waited 10 ms: violation
    ab.submit(_requests([2])[0])
    clock.advance_ms(4.0)
    ab.flush()                            # waited 4 ms: inside SLO
    lat = ab.latency
    assert lat.requests == 3
    assert lat.queries == 12
    assert lat.slo_violations == 1
    assert lat.slo_violation_rate == pytest.approx(1.0 / 3.0)
    s = lat.summary()
    assert s["slo_ms"] == 5.0
    assert s["latency_ms"]["max"] == pytest.approx(10.0)


def test_latency_timestamps_split_wait_and_total(model):
    """enqueue->flush lands in queue_wait; enqueue->complete in total."""
    class ComputeClock(FakeClock):
        """Advance 7 ms every read after the first, imitating compute."""
        def __init__(self):
            super().__init__()
            self.reads = 0

        def __call__(self):
            self.reads += 1
            if self.reads > 2:            # submit + flush_ts reads free
                self.t += 7e-3
            return self.t

    clock = ComputeClock()
    ab = AsyncBatcher(model, max_wait_ms=100.0, clock=clock, max_bucket=512)
    ab.submit(_requests([3])[0])
    ab.flush()
    assert ab.latency.total.max >= ab.latency.queue_wait.max


def test_registry_scheduler_cached_and_summarized(model):
    reg = ModelRegistry()
    reg.register("m", model)
    clock = FakeClock()
    s1 = reg.scheduler("m", max_wait_ms=2.0, slo_ms=50.0, clock=clock)
    s2 = reg.scheduler("m")                      # bare lookup: cache hit
    assert s1 is s2
    with pytest.raises(ValueError):              # conflicting override
        reg.scheduler("m", max_wait_ms=999.0)    # must not be swallowed
    with pytest.raises(KeyError):
        reg.latency_summary("other")
    f = s1.submit(_requests([5])[0])
    s1.flush()
    f.result(timeout=0)
    assert reg.latency_summary("m")["requests"] == 1
    reg.unregister("m")                   # stops + flushes the scheduler


def test_submit_validates_shape(model):
    ab = AsyncBatcher(model, clock=FakeClock())
    with pytest.raises(ValueError):
        ab.submit(np.zeros((P, 0), np.float32))
    with pytest.raises(ValueError):
        ab.submit(np.zeros((P + 1, 4), np.float32))


def test_flush_rejects_foreign_inner_requests(model):
    """Requests enqueued directly on the inner MicroBatcher must not be
    silently zipped onto the async futures."""
    ab = AsyncBatcher(model, clock=FakeClock(), max_bucket=512)
    ab.batcher.submit(_requests([3])[0])     # foreign: bypasses futures
    fut = ab.submit(_requests([5])[0])
    with pytest.raises(RuntimeError, match="foreign"):
        ab.flush()
    with pytest.raises(RuntimeError):
        fut.result(timeout=0)                # future carries the error


def test_cancelled_future_does_not_strand_the_batch(model):
    """A client cancelling its pending future must not break the flush:
    set_result on a cancelled future raises InvalidStateError, which
    would leave every LATER future in the batch unresolved forever."""
    ab = AsyncBatcher(model, max_wait_ms=1e9)
    reqs = _requests([3, 4, 5])
    futs = [ab.submit(r) for r in reqs]
    assert futs[1].cancel()              # pending -> cancellable
    assert ab.flush() == 3
    for i in (0, 2):
        labels, d2 = futs[i].result(timeout=5)
        assert labels.shape == (reqs[i].shape[1],)
    assert futs[1].cancelled()


def test_pump_thread_survives_flush_errors(model):
    """A poisoned batch must not kill the pump thread: its futures carry
    the exception and later requests still get served."""
    ab = AsyncBatcher(model, max_wait_ms=1.0, max_bucket=512)
    with ab:
        ab.batcher.submit(_requests([3])[0])       # poison: foreign req
        bad = ab.submit(_requests([5])[0])
        with pytest.raises(RuntimeError):
            bad.result(timeout=30.0)
        good = ab.submit(_requests([4])[0])        # pump must still run
        labels, _ = good.result(timeout=30.0)
    assert labels.shape == (4,)
    assert ab.pump_errors >= 1
    assert isinstance(ab.last_pump_error, RuntimeError)


def test_pump_thread_flushes_on_deadline(model):
    """Real-clock smoke of the background pump: a submitted request
    resolves without any explicit poll/flush."""
    with AsyncBatcher(model, max_wait_ms=1.0, max_bucket=512) as ab:
        fut = ab.submit(_requests([4])[0])
        labels, d2 = fut.result(timeout=30.0)
    assert labels.shape == (4,)
    assert ab.latency.requests == 1


# ---------------------------------------------------------------------------
# LatencyStats / Histogram unit behaviour
# ---------------------------------------------------------------------------

def test_histogram_percentiles_bracket_true_quantiles():
    stats = LatencyStats()
    vals = np.linspace(1.0, 100.0, 1000)          # ms
    for v in vals:
        stats.record(0.0, 0.0, v / 1e3, queries=1)
    for q, true in ((50.0, 50.5), (95.0, 95.05), (99.0, 99.01)):
        got = stats.total.percentile(q)
        assert true / 1.2 <= got <= true * 1.2, (q, got, true)
    assert stats.total.percentile(0.0) <= vals[0] * 1.2
    assert stats.total.percentile(100.0) == pytest.approx(100.0, rel=0.2)


def test_histogram_empty_and_clamped():
    stats = LatencyStats(slo_ms=1.0)
    assert stats.total.percentile(99.0) == 0.0
    assert stats.summary()["latency_ms"]["max"] == 0.0
    stats.record(0.0, 0.0, 1e9, queries=1)        # way past the last bucket
    assert stats.slo_violations == 1
    assert stats.total.percentile(50.0) >= 1e7    # clamps, does not crash


# ---------------------------------------------------------------------------
# per-bucket latency breakdown
# ---------------------------------------------------------------------------

def test_per_bucket_latency_breakdown(model):
    """Each flush lands its requests' total latency under the pow-2
    execution bucket of the coalesced batch; unbatched callers (no
    bucket) leave the breakdown untouched."""
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock,
                      min_bucket=8, max_bucket=128)
    # Flush 1: widths 3 + 4 = 7 -> bucket 8 (clamped to min_bucket).
    for req in _requests([3, 4]):
        ab.submit(req)
    ab.flush()
    # Flush 2: widths 40 + 30 = 70 -> bucket 128.
    clock.advance_ms(1.0)
    for req in _requests([40, 30], seed=1):
        ab.submit(req)
    ab.flush()
    assert sorted(ab.latency.by_bucket) == [8, 128]
    assert ab.latency.by_bucket[8].n == 2
    assert ab.latency.by_bucket[128].n == 2
    s = ab.latency.summary()
    assert set(s["per_bucket"]) == {"8", "128"}
    assert s["per_bucket"]["8"]["requests"] == 2
    # Aggregate count equals the per-bucket counts (every async request
    # is attributed to exactly one bucket).
    assert sum(row["requests"] for row in s["per_bucket"].values()) \
        == s["requests"]
    # Oversized coalesced batches clamp to max_bucket (they chunk into
    # max_bucket executables).
    for req in _requests([100, 100, 100], seed=2):
        ab.submit(req)
    ab.flush()
    assert ab.latency.by_bucket[128].n == 5
    # A bucket-less record only moves the aggregate histograms.
    stats = LatencyStats()
    stats.record(0.0, 0.1, 0.2)
    assert stats.by_bucket == {} and stats.summary()["per_bucket"] == {}
    # The breakdown shows up in the human-readable table too.
    assert "bucket 128" in ab.latency.format_table()

"""Shape sweep: fused gram->projection stripe kernel vs pure-jnp oracle.

The oracle IS the two-pass path (materialize the gram stripe, project),
so this sweep pins exactly the fusion's correctness claim: the VMEM-tiled
accumulation matches the HBM-round-trip computation on ragged n, r, w.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import extend_embed_pallas
from repro.kernels.extend_embed.ref import extend_embed_ref

pytestmark = pytest.mark.kernels    # CI kernel-parity job runs -m kernels


@pytest.mark.parametrize("p,n,r,w", [(2, 100, 2, 12), (19, 555, 3, 64),
                                     (7, 1024, 16, 128), (128, 256, 8, 256),
                                     (3, 97, 5, 1), (2, 250, 2, 23)])
@pytest.mark.parametrize("kind,gamma,degree", [("polynomial", 0.0, 2),
                                               ("polynomial", 1.0, 3),
                                               ("rbf", 0.5, 0),
                                               ("linear", 0.0, 0)])
def test_extend_embed_matches_ref(p, n, r, w, kind, gamma, degree):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(p * n + r * w), 3)
    X = jax.random.normal(k1, (p, n), jnp.float32)
    # Realistic projection scale: rows of Sigma^{-1/2} U^T are O(1/sqrt n).
    P = jax.random.normal(k2, (r, n), jnp.float32) / np.sqrt(n)
    Xb = jax.random.normal(k3, (p, w), jnp.float32)
    got = np.asarray(extend_embed_pallas(X, P, Xb, kind=kind, gamma=gamma,
                                         degree=degree, interpret=True))
    want = np.asarray(extend_embed_ref(X, P, Xb, kind=kind, gamma=gamma,
                                       degree=degree))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_extend_embed_row_tiles():
    """Row-tile choice changes the accumulation order, not the result."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (5, 700), jnp.float32)
    P = jax.random.normal(k2, (4, 700), jnp.float32) / np.sqrt(700)
    Xb = jax.random.normal(k3, (5, 33), jnp.float32)
    want = np.asarray(extend_embed_ref(X, P, Xb))
    for rt in (128, 256, 512):
        got = np.asarray(extend_embed_pallas(X, P, Xb, row_tile=rt,
                                             interpret=True))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_extend_embed_rbf_padding_annihilated():
    """Padded X columns give nonzero rbf gram rows (kappa(0, x) != 0);
    the zero-padded P columns must annihilate them exactly. n=130 pads
    to 256, so half the gram rows are padding garbage."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    X = jax.random.normal(k1, (3, 130), jnp.float32)
    P = jax.random.normal(k2, (2, 130), jnp.float32) / np.sqrt(130)
    Xb = jax.random.normal(k3, (3, 17), jnp.float32)
    got = np.asarray(extend_embed_pallas(X, P, Xb, kind="rbf", gamma=0.8,
                                         interpret=True))
    want = np.asarray(extend_embed_ref(X, P, Xb, kind="rbf", gamma=0.8))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

"""Shape/dtype sweep: FWHT Pallas kernel vs pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fwht_pallas
from repro.kernels.fwht.ref import fwht_ref

pytestmark = pytest.mark.kernels    # CI kernel-parity job runs -m kernels


@pytest.mark.parametrize("n", [8, 64, 512, 4096, 1 << 13, 1 << 14, 1 << 15])
@pytest.mark.parametrize("c", [1, 3, 128, 200])
def test_fwht_matches_ref(n, c):
    if n >= (1 << 14) and c > 3:
        pytest.skip("large-n sweep kept small for CI time")
    x = jax.random.normal(jax.random.PRNGKey(n + c), (n, c), jnp.float32)
    got = np.asarray(fwht_pallas(x, interpret=True))
    want = np.asarray(fwht_ref(x))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 16)).astype(dtype)
    got = np.asarray(fwht_pallas(x, interpret=True), np.float32)
    want = np.asarray(fwht_ref(x), np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_fwht_unnormalized():
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 4))
    got = np.asarray(fwht_pallas(x, normalize=False, interpret=True))
    want = np.asarray(fwht_ref(x, normalize=False))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fwht_two_level_equals_one_level():
    """The H_a (x) H_b factorization must agree with single-level exactly."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1 << 14, 2))
    got = np.asarray(fwht_pallas(x, interpret=True))
    want = np.asarray(fwht_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fwht_rejects_bad_n():
    with pytest.raises(ValueError):
        fwht_pallas(jnp.zeros((12, 2)), interpret=True)

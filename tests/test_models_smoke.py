"""Per-architecture smoke tests: reduced config, one forward / train /
prefill / decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_api
from repro.train.steps import make_train_step, init_train_state
from repro.launch import specs

SMOKE_SEQ = 32
SMOKE_BATCH = 4


def _batch(cfg):
    return specs.train_inputs(cfg, SMOKE_SEQ, SMOKE_BATCH, concrete=True,
                              key=jax.random.PRNGKey(1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, tp=1)
    batch = _batch(cfg)
    logits = api.forward(params, cfg, batch, 1)
    S = batch["labels"].shape[1] if "labels" in batch else SMOKE_SEQ
    assert logits.shape == (SMOKE_BATCH, S, cfg.vocab_padded(1))
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_decreases_grads_finite(arch):
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    state = init_train_state(jax.random.PRNGKey(0), cfg, api, tp=1)
    step = jax.jit(make_train_step(cfg, api, groups=1))
    batch = _batch(cfg)
    state1, m1 = step(state, batch)
    assert np.isfinite(float(m1["loss"])), arch
    assert np.isfinite(float(m1["grad_norm"])), arch
    assert float(m1["grad_norm"]) > 0
    # One more step on the same batch must reduce the loss (sanity of the
    # whole backward + AdamW path).
    _, m2 = step(state1, batch)
    assert float(m2["loss"]) < float(m1["loss"]), (
        arch, float(m1["loss"]), float(m2["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Prefill a prompt, decode one token; logits must match the
    teacher-forced forward at the same position (core KV-cache invariant)."""
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, tp=1)
    S = 16
    pb = specs.prefill_inputs(cfg, S, 2, concrete=True,
                              key=jax.random.PRNGKey(3))
    if cfg.family == "vlm":
        # Serving is text-only for the assigned decode cells: the vision
        # prefix enters at train time (see registry._vlm_api).
        pb = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, S), 0,
                                           cfg.vocab_size, jnp.int32)}
    cache = api.init_cache(cfg, 2, 64, jnp.float32)
    logits_pre, cache = api.prefill(params, cfg, pb, cache, 1)
    assert logits_pre.shape == (2, cfg.vocab_padded(1))
    assert np.isfinite(np.asarray(logits_pre)).all()
    assert int(cache["pos"]) == S
    # Teacher-forced forward over the same tokens: last-position logits
    # must agree with the prefill output.
    fb = dict(pb)
    fb["labels"] = jnp.zeros_like(pb["tokens"])
    logits_full = api.forward(params, cfg, fb, 1)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)
    # And decoding one more token runs and is finite.
    tok = jnp.zeros((2,), jnp.int32)
    logits_dec, cache = api.decode(params, cfg, tok, cache, 1)
    assert logits_dec.shape == (2, cfg.vocab_padded(1))
    assert np.isfinite(np.asarray(logits_dec)).all()
    assert int(cache["pos"]) == S + 1


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "recurrentgemma-2b"])
def test_sliding_window_ring_buffer(arch):
    """Decode past the window: ring cache keeps working (pos > window)."""
    cfg = get_config(arch, smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, tp=1)
    W = cfg.window
    cache = api.init_cache(cfg, 1, W, jnp.float32)
    tok = jnp.zeros((1,), jnp.int32)
    for _ in range(W + 3):
        logits, cache = api.decode(params, cfg, tok, cache, 1)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"]) == W + 3


def test_full_configs_param_counts():
    """Full configs build and report plausible parameter counts."""
    expected = {
        "mixtral-8x7b": (4.4e10, 5.0e10),       # ~46.7B
        "dbrx-132b": (1.2e11, 1.45e11),
        "phi4-mini-3.8b": (3.0e9, 5.0e9),
        "nemotron-4-340b": (3.0e11, 3.7e11),
        "qwen3-14b": (1.2e10, 1.7e10),
        "command-r-plus-104b": (0.9e11, 1.2e11),
        "rwkv6-1.6b": (1.2e9, 2.0e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
        "whisper-large-v3": (1.2e9, 2.0e9),
        "pixtral-12b": (1.0e10, 1.5e10),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_moe_capacity_drops_are_bounded():
    """Grouped capacity routing drops few tokens at capacity_factor 1.25."""
    from repro.models import layers as L
    cfg = get_config("mixtral-8x7b", smoke=True)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, tp=1)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, cfg.d_model))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    out = L.apply_moe(lp["mlp"], cfg, x, groups=1)
    # With random routing, >= 80% of tokens get a nonzero MLP output.
    nz = np.asarray(jnp.any(jnp.abs(out) > 0, axis=-1)).mean()
    assert nz > 0.8

"""Integration tests: the full Alg. 1 pipeline against paper-level claims."""
import jax
import numpy as np
import pytest

from repro.core import (one_pass_kernel_kmeans, linearized_kmeans_from_Y,
                        nystrom, exact_eig_from_gram, gram_matrix,
                        polynomial_kernel, clustering_accuracy,
                        kernel_approx_error, kernel_approx_error_streaming,
                        kmeans)
from repro.data import blob_ring, segmentation_proxy


@pytest.fixture(scope="module")
def rings():
    X, labels = blob_ring(jax.random.PRNGKey(0), n=1000)
    kern = polynomial_kernel(gamma=0.0, degree=2)
    K = gram_matrix(kern, X)
    return X, labels, kern, K


def test_ours_matches_exact_error(rings):
    X, labels, kern, K = rings
    exact = exact_eig_from_gram(K, 2)
    err_exact = kernel_approx_error(K, exact.Y)
    res = one_pass_kernel_kmeans(jax.random.PRNGKey(1), kern, X, k=2, r=2,
                                 oversampling=10, block=256)
    err_ours = kernel_approx_error(K, res.Y)
    # Table 1: both 0.40 — ours within 5% of the exact rank-2 optimum.
    assert err_ours <= 1.05 * err_exact + 1e-6


def test_ours_high_clustering_accuracy(rings):
    X, labels, kern, K = rings
    res = one_pass_kernel_kmeans(jax.random.PRNGKey(2), kern, X, k=2, r=2)
    assert clustering_accuracy(labels, res.labels, 2) > 0.95


def test_plain_kmeans_fails_nonlinear(rings):
    X, labels, _, _ = rings
    res = kmeans(jax.random.PRNGKey(3), X.T, 2)
    assert clustering_accuracy(labels, res.labels, 2) < 0.9


def test_ours_beats_nystrom_at_equal_memory(rings):
    """The paper's headline: at ~equal column budget (r'=12 vs m=12), the
    preconditioned sketch beats uniform-column Nystrom on approx error."""
    X, labels, kern, K = rings
    errs_ours, errs_ny = [], []
    for s in range(5):
        res = one_pass_kernel_kmeans(jax.random.PRNGKey(10 + s), kern, X,
                                     k=2, r=2, oversampling=10)
        errs_ours.append(kernel_approx_error(K, res.Y))
        ny = nystrom(jax.random.PRNGKey(100 + s), kern, X, m=12, r=2)
        errs_ny.append(kernel_approx_error(K, ny.Y))
    assert np.mean(errs_ours) < np.mean(errs_ny)


def test_streaming_error_matches_dense(rings):
    X, labels, kern, K = rings
    res = one_pass_kernel_kmeans(jax.random.PRNGKey(4), kern, X, k=2, r=2)
    dense = kernel_approx_error(K, res.Y)
    stream = kernel_approx_error_streaming(kern, X, res.Y, block=128)
    np.testing.assert_allclose(stream, dense, rtol=1e-4)


def test_segmentation_proxy_pipeline():
    """Fig. 3 shape: K=7 clusters, r=2, l=5 — ours close to exact, better
    than Nystrom at a comparable memory budget."""
    X, labels = segmentation_proxy(jax.random.PRNGKey(1), n=700)
    kern = polynomial_kernel(gamma=0.0, degree=2)
    K = gram_matrix(kern, X)
    res = one_pass_kernel_kmeans(jax.random.PRNGKey(2), kern, X, k=7, r=2,
                                 oversampling=5)
    acc_ours = clustering_accuracy(labels, res.labels, 7)
    ny = nystrom(jax.random.PRNGKey(3), kern, X, m=7, r=2)
    acc_ny = clustering_accuracy(
        labels, linearized_kmeans_from_Y(jax.random.PRNGKey(4), ny.Y, 7).labels, 7)
    assert acc_ours > 0.8
    assert acc_ours >= acc_ny - 0.05   # ours at least on par at equal memory


def test_gaussian_sketch_variant_also_works(rings):
    X, labels, kern, K = rings
    res = one_pass_kernel_kmeans(jax.random.PRNGKey(5), kern, X, k=2, r=2,
                                 sketch_type="gaussian")
    assert clustering_accuracy(labels, res.labels, 2) > 0.95

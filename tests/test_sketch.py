"""Unit tests for the SRHT / one-pass randomized eigendecomposition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (fwht, make_srht, srht_apply, srht_apply_t, next_pow2,
                        randomized_eig, sketch_stream, polynomial_kernel,
                        rbf_kernel, gram_matrix, exact_eig_from_gram)
from repro.data import gaussian_blobs


def hadamard_dense(n):
    H = np.array([[1.0]])
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H


@pytest.mark.parametrize("n", [1, 2, 8, 64, 256])
def test_fwht_matches_dense_hadamard(n):
    rng = np.random.RandomState(0)
    x = rng.randn(n, 3).astype(np.float32)
    want = hadamard_dense(n) @ x / np.sqrt(n)
    got = np.asarray(fwht(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_fwht_is_orthonormal_involution():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 5))
    np.testing.assert_allclose(np.asarray(fwht(fwht(x))), np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    # Orthonormal: preserves norms.
    np.testing.assert_allclose(float(jnp.linalg.norm(fwht(x))),
                               float(jnp.linalg.norm(x)), rtol=1e-5)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht(jnp.zeros((3, 2)))


@pytest.mark.parametrize("n,rp", [(100, 12), (128, 7), (777, 32)])
def test_srht_apply_consistency(n, rp):
    """srht_apply_t and srht_apply agree with a densified Omega."""
    srht = make_srht(jax.random.PRNGKey(1), n, rp)
    # Densify Omega = D H R restricted to first n rows.
    n_pad = srht.n_pad
    H = hadamard_dense(n_pad) / np.sqrt(n_pad)
    D = np.diag(np.asarray(srht.signs))
    R = np.zeros((n_pad, rp))
    R[np.asarray(srht.rows), np.arange(rp)] = 1.0
    omega = (D @ H @ R)[:n]
    M = np.random.RandomState(2).randn(n, 4).astype(np.float32)
    got_t = np.asarray(srht_apply_t(srht, jnp.asarray(M)))
    np.testing.assert_allclose(got_t, omega.T @ M, rtol=1e-3, atol=1e-4)
    V = np.random.RandomState(3).randn(rp, 4).astype(np.float32)
    got = np.asarray(srht_apply(srht, jnp.asarray(V)))
    np.testing.assert_allclose(got, omega @ V, rtol=1e-3, atol=1e-4)


def test_srht_rows_sampled_without_replacement():
    srht = make_srht(jax.random.PRNGKey(0), 200, 64)
    rows = np.asarray(srht.rows)
    assert len(np.unique(rows)) == 64
    assert next_pow2(200) == 256
    assert rows.max() < 256


@pytest.mark.parametrize("sketch_type", ["srht", "gaussian"])
def test_randomized_eig_recovers_lowrank_gram(sketch_type):
    """On an exactly rank-deficient K, the one-pass method is near-exact."""
    X, _ = gaussian_blobs(jax.random.PRNGKey(0), n=300, p=4, k=3)
    kern = polynomial_kernel(degree=2)          # rank <= 10 feature space
    K = gram_matrix(kern, X)
    r = 10
    eig = randomized_eig(jax.random.PRNGKey(1), kern, X, r=r, oversampling=10,
                         block=64, sketch_type=sketch_type)
    err = float(jnp.linalg.norm(K - eig.Y.T @ eig.Y) / jnp.linalg.norm(K))
    assert err < 1e-3, err


def test_randomized_eig_close_to_optimal_rank_r():
    """General (full-rank) RBF gram: error within a modest factor of optimal."""
    X, _ = gaussian_blobs(jax.random.PRNGKey(0), n=400, p=6, k=4, spread=0.3)
    kern = rbf_kernel(gamma=0.5)
    K = gram_matrix(kern, X)
    r = 8
    best = exact_eig_from_gram(K, r)
    opt = float(jnp.linalg.norm(K - best.Y.T @ best.Y))
    eig = randomized_eig(jax.random.PRNGKey(7), kern, X, r=r, oversampling=10,
                         block=128)
    got = float(jnp.linalg.norm(K - eig.Y.T @ eig.Y))
    assert got < 2.5 * opt + 1e-6, (got, opt)


def test_sketch_stream_matches_dense_product():
    """Streaming W == K @ Omega computed densely, for awkward n/block."""
    X, _ = gaussian_blobs(jax.random.PRNGKey(0), n=173, p=5, k=2)
    kern = rbf_kernel(gamma=1.0)
    K = gram_matrix(kern, X)
    srht = make_srht(jax.random.PRNGKey(1), 173, 9)
    W = sketch_stream(kern, X, srht, block=64)
    # Dense Omega via srht_apply on identity.
    omega = np.asarray(srht_apply(srht, jnp.eye(9)))
    np.testing.assert_allclose(np.asarray(W), np.asarray(K) @ omega,
                               rtol=1e-3, atol=1e-3)


def test_eigvals_nonnegative_descending():
    X, _ = gaussian_blobs(jax.random.PRNGKey(2), n=128, p=3, k=2)
    eig = randomized_eig(jax.random.PRNGKey(3), rbf_kernel(gamma=1.0), X, r=5)
    ev = np.asarray(eig.eigvals)
    assert (ev >= 0).all()
    assert (np.diff(ev) <= 1e-5).all()

"""repro.api: backend parity, the estimator front door, serve round-trip.

The ISSUE-5 acceptance surface:
  - all four registered backends within tolerance of each other (and of
    the exact ceiling) on clustering accuracy + kernel approx error,
    through the ONE KernelKMeans front door;
  - a Nystrom-fitted model flows through the ENTIRE serving stack
    (artifact -> VersionStore publish -> registry -> async traffic ->
    warm hot-swap) and assigns identically to a direct evaluation of the
    Nystrom extension formula;
  - the legacy entry points (fit_model, one_pass_kernel_kmeans) are
    deprecation shims that reproduce the new API bit-for-bit;
  - make_kernel rejects unknown kernel params loudly.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (KernelKMeans, available_backends, fit_memory_bytes,
                       get_backend)
from repro.api.estimator import spec_to_estimator
from repro.core import (clustering_accuracy, kernel_approx_error,
                        make_kernel)
from repro.core.kernels_fn import gram_matrix
from repro.data import gaussian_blobs
from repro.serve import (ClusteringSpec, ModelRegistry, ModelSpec,
                         VersionStore, assign, load_model)
from repro.serve.extend import _projection

BACKENDS = ("exact", "nystrom", "onepass-gaussian", "onepass-srht")


@pytest.fixture(scope="module")
def blobs():
    # Well-separated synthetic blobs: every backend must nail these.
    X, labels = gaussian_blobs(jax.random.PRNGKey(0), n=240, p=4, k=3)
    return X, labels


@pytest.fixture(scope="module")
def fits(blobs):
    X, _ = blobs
    out = {}
    for name in BACKENDS:
        est = KernelKMeans(k=3, r=4, kernel="rbf",
                           kernel_params={"gamma": 1.0}, backend=name,
                           backend_params=({"m": 120}
                                           if name == "nystrom" else {}),
                           block=64)
        out[name] = est.fit(X, key=2)
    return out


def test_registry_lists_all_four_backends():
    assert list(BACKENDS) == available_backends()
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("onepass-typo")
    with pytest.raises(ValueError, match="unknown backend"):
        KernelKMeans(backend="nope")


def test_backend_parity_accuracy_and_error(blobs, fits):
    """All four backends within tolerance on accuracy + approx error."""
    X, labels = blobs
    K = gram_matrix(make_kernel("rbf", gamma=1.0), X)
    err_exact = kernel_approx_error(K, fits["exact"].embedding_)
    for name, est in fits.items():
        acc = clustering_accuracy(labels, est.labels_, 3)
        err = kernel_approx_error(K, est.embedding_)
        assert acc >= 0.95, f"{name}: accuracy {acc}"
        # The exact eigendecomposition is the rank-r floor; every
        # approximation must land within a small additive margin of it.
        assert err <= err_exact + 0.15, \
            f"{name}: err {err} vs exact {err_exact}"
        assert err >= err_exact - 1e-5, \
            f"{name}: err {err} beats the exact rank-r floor {err_exact}"


def test_backend_parity_serving_assignment(blobs, fits):
    """Every backend's fit predicts through the same serving path, and
    held-out assignments agree with the exact backend's up to the label
    permutation (centroid order is seed/backend dependent)."""
    X, _ = blobs
    Xq = X[:, :60]
    ref = fits["exact"].predict(Xq)
    for name, est in fits.items():
        got = est.predict(Xq)
        agree = clustering_accuracy(ref, got, 3)
        assert agree >= 0.95, f"{name}: only {agree:.2f} label agreement"


def test_memory_model_ordering(blobs):
    """The paper's axis: one-pass O(r'n) < nystrom O(mn) < exact O(n^2)."""
    n, r = 4000, 2
    onepass = fit_memory_bytes("onepass-srht", n, r, oversampling=10)
    ny = fit_memory_bytes("nystrom", n, r)
    exact = fit_memory_bytes("exact", n, r)
    assert onepass == 4 * n * (r + 10)
    assert onepass < ny < exact
    assert exact == 4 * n * n


def test_nystrom_landmark_artifact_smaller_and_exact(blobs, fits):
    """Nystrom extension state: landmarks persisted, U spans them, and
    the training round-trip is exact BY CONSTRUCTION (any kernel)."""
    X, _ = blobs
    est = fits["nystrom"]
    model = est.model_
    assert model.landmarks is not None and model.landmarks.shape == (4, 120)
    assert model.landmark_idx is not None
    assert model.U.shape[0] == model.n_ref == 120
    Y_ext = est.embed(X)
    rel = (float(jnp.linalg.norm(Y_ext - est.embedding_)) /
           float(jnp.linalg.norm(est.embedding_)))
    assert rel <= 1e-5, rel
    # Y is undefined for landmark fits — loud error, not silent garbage.
    with pytest.raises(AttributeError, match="landmark"):
        model.Y


def test_nystrom_rank_deficient_fit_serves_consistently():
    """Fit and serve must make the SAME rank decision: when the landmark
    gram is rank-deficient, the fit zeroes the truncated eigenvalues, so
    the serving projection (absolute epsilon) cannot re-invert a
    direction the fit refused — which would amplify noise ~1/sqrt(eps)
    and break the exact train round-trip."""
    # 3 distinct points tiled: homogeneous quadratic kernel on p=2 data
    # has feature rank <= 3, so r=6 forces truncated directions.
    base = jnp.asarray([[0.3, -1.2, 2.0], [1.1, 0.4, -0.7]], jnp.float32)
    X = jnp.tile(base, (1, 16))                     # (2, 48)
    est = KernelKMeans(k=2, r=6, kernel="polynomial",
                       kernel_params={"gamma": 0.0, "degree": 2},
                       backend="nystrom", backend_params={"m": 24},
                       block=16).fit(X, key=0)
    evs = np.asarray(est.model_.eigvals)
    # Directions the fit truncated are exactly 0 (here the relative
    # threshold is ~1.6e-6, far above the serving epsilon 1e-7, so every
    # kept eigenvalue is served invertibly too): nothing may land in the
    # inconsistent band (0, 1e-7] where serving would zero what the fit
    # inverted — or worse, the fit zero what serving would invert.
    assert ((evs == 0.0) | (evs > 1e-7)).all(), evs
    assert (evs == 0.0).any(), f"expected truncated directions, got {evs}"
    Y_ext = est.embed(X)
    assert np.isfinite(np.asarray(Y_ext)).all()
    rel = (float(jnp.linalg.norm(Y_ext - est.embedding_)) /
           float(jnp.linalg.norm(est.embedding_)))
    assert rel <= 1e-4, rel


def test_nystrom_full_serve_roundtrip(tmp_path, blobs, fits):
    """Acceptance: backend="nystrom" through the FULL stack — fit ->
    VersionStore.publish -> registry -> async traffic across a warm
    swap -> assign parity with the direct Nystrom embedding."""
    X, _ = blobs
    est = fits["nystrom"]
    store = VersionStore(str(tmp_path / "versions"))
    v1 = store.publish(est.model_)
    reg = ModelRegistry()
    served = reg.load_version("ny", str(tmp_path / "versions"))
    assert reg.version("ny") == v1
    assert served.spec.backend == "nystrom"

    Xq = np.asarray(X[:, :40], np.float32)
    parts = np.split(Xq, [15, 16, 30], axis=1)
    sched = reg.scheduler("ny", max_wait_ms=5.0)
    pre = [sched.submit(p) for p in parts]
    sched.flush()
    labels_async = np.concatenate([f.result()[0] for f in pre])

    # Direct Nystrom embedding: y(x) = Lambda_r^{-1/2} U_r^T k(landmarks, x)
    P = _projection(served)
    Yq = P @ make_kernel("rbf", gamma=1.0)(served.landmarks, jnp.asarray(Xq))
    d2 = (jnp.sum(Yq.T ** 2, 1)[:, None]
          + jnp.sum(served.centroids ** 2, 1)[None, :]
          - 2.0 * Yq.T @ served.centroids.T)
    want = np.asarray(jnp.argmin(d2, axis=1), np.int32)
    assert np.array_equal(labels_async, want), \
        "served stack != direct Nystrom embedding assignment"

    # Warm hot-swap to a permuted-centroid v2 while requests are pending.
    model_b = served._replace(centroids=served.centroids[::-1])
    v2 = store.publish(model_b)
    pending = [sched.submit(p) for p in parts]
    reg.swap("ny", store.load(v2), version=v2)
    assert all(f.done() for f in pending), "swap stranded futures"
    old = np.concatenate([f.result()[0] for f in pending])
    assert np.array_equal(old, labels_async), \
        "pre-swap requests must resolve against the old version"
    sched2 = reg.scheduler("ny")
    post = [sched2.submit(p) for p in parts]
    sched2.flush()
    new = np.concatenate([f.result()[0] for f in post])
    k = served.spec.k
    assert np.array_equal(new, (k - 1) - labels_async), \
        "post-swap labels must come from the permuted v2 centroids"


def test_estimator_save_load_predict(tmp_path, fits, blobs):
    X, _ = blobs
    est = fits["nystrom"]
    path = est.save(str(tmp_path / "art"))
    est2 = KernelKMeans.load(path)
    assert est2.spec_ == est.spec_
    assert np.array_equal(est2.predict(X[:, :30]), est.predict(X[:, :30]))
    np.testing.assert_allclose(np.asarray(est2.embed(X[:, :30])),
                               np.asarray(est.embed(X[:, :30])),
                               rtol=1e-6, atol=1e-7)
    # And the plain serve-side loaders see the same model.
    m = load_model(path)
    lab, _ = assign(m, X[:, :30])
    assert np.array_equal(np.asarray(lab), est.predict(X[:, :30]))


def test_estimator_unfitted_raises_and_score(blobs, fits):
    X, _ = blobs
    with pytest.raises(RuntimeError, match="not fitted"):
        KernelKMeans().predict(X)
    est = fits["onepass-srht"]
    assert est.score() == -est.inertia_ < 0.0
    assert est.score(X) <= 0.0


def test_spec_roundtrip_and_legacy_schema():
    spec = ClusteringSpec(kernel="rbf", kernel_params={"gamma": 2.0},
                          k=3, r=4, backend="nystrom",
                          backend_params={"m": 99}, n=100, p=5)
    assert ClusteringSpec.from_json(spec.to_json()) == spec
    assert ModelSpec is ClusteringSpec           # legacy alias
    # Pre-estimator-API spec.json schema still loads.
    legacy = ('{"kernel": "polynomial", "kernel_params": {"degree": 2, '
              '"gamma": 0.0}, "n": 250, "p": 2, "r": 2, "k": 2, '
              '"oversampling": 7, "block": 64, "sketch_type": "gaussian"}')
    old = ClusteringSpec.from_json(legacy)
    assert old.backend == "onepass-gaussian"
    assert old.backend_params == {"oversampling": 7}
    assert old.sketch_type == "gaussian" and old.oversampling == 7
    assert (old.n, old.p, old.block) == (250, 2, 64)


def test_spec_to_estimator_refit(blobs):
    X, _ = blobs
    est = KernelKMeans(k=3, r=4, kernel="rbf",
                       kernel_params={"gamma": 1.0}).fit(X, key=5)
    est2 = spec_to_estimator(est.spec_).fit(X, key=5)
    assert np.array_equal(np.asarray(est.labels_), np.asarray(est2.labels_))


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_fit_model_shim_warns_and_matches(blobs):
    from repro.serve import fit_model
    X, _ = blobs
    with pytest.warns(DeprecationWarning, match="KernelKMeans"):
        old = fit_model(jax.random.PRNGKey(3), X, k=3, r=4, kernel="rbf",
                        kernel_params={"gamma": 1.0}, oversampling=6,
                        block=64)
    new = KernelKMeans(k=3, r=4, kernel="rbf",
                       kernel_params={"gamma": 1.0},
                       backend_params={"oversampling": 6},
                       block=64).fit(X, key=jax.random.PRNGKey(3)).model_
    assert old.spec == new.spec
    for field in ("U", "eigvals", "centroids", "sketch_signs",
                  "sketch_rows"):
        np.testing.assert_array_equal(np.asarray(getattr(old, field)),
                                      np.asarray(getattr(new, field)))


def test_one_pass_shim_warns_and_matches(blobs):
    from repro.core import one_pass_kernel_kmeans
    X, _ = blobs
    kern = make_kernel("rbf", gamma=1.0)
    with pytest.warns(DeprecationWarning, match="KernelKMeans"):
        old = one_pass_kernel_kmeans(jax.random.PRNGKey(4), kern, X,
                                     k=3, r=4, oversampling=6, block=64)
    new = KernelKMeans(k=3, r=4, kernel="rbf",
                       kernel_params={"gamma": 1.0},
                       backend_params={"oversampling": 6},
                       block=64).fit(X, key=jax.random.PRNGKey(4))
    assert np.array_equal(np.asarray(old.labels), np.asarray(new.labels_))
    np.testing.assert_array_equal(np.asarray(old.Y),
                                  np.asarray(new.embedding_))


def test_shims_do_not_warn_on_new_path(blobs):
    """The front door itself must be warning-free."""
    X, _ = blobs
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        KernelKMeans(k=3, r=4, kernel="rbf",
                     kernel_params={"gamma": 1.0}, block=64).fit(X, key=0)


# ---------------------------------------------------------------------------
# make_kernel param validation
# ---------------------------------------------------------------------------

def test_make_kernel_rejects_unknown_params():
    with pytest.raises(ValueError, match=r"gamm.*valid params.*gamma"):
        make_kernel("rbf", gamm=0.5)            # the classic typo
    with pytest.raises(ValueError, match="degree"):
        make_kernel("rbf", degree=2)            # poly-only param
    with pytest.raises(ValueError, match="no params"):
        make_kernel("linear", gamma=1.0)        # used to be swallowed
    with pytest.raises(ValueError, match="unknown kernel"):
        make_kernel("polynomail")
    # Valid calls still construct.
    make_kernel("polynomial", gamma=0.0, degree=3)
    make_kernel("rbf", gamma=0.5)
    make_kernel("linear")


def test_kernel_kmeans_validates_kernel_name_early():
    with pytest.raises(ValueError, match="unknown kernel"):
        KernelKMeans(kernel="polynomail")


# ---------------------------------------------------------------------------
# backend sweep bench section
# ---------------------------------------------------------------------------

def test_benchmark_backends_section(blobs):
    from repro.serve import benchmark_backends
    X, labels = blobs
    bench = benchmark_backends(X, labels, k=3, r=4, kernel="rbf",
                               kernel_params={"gamma": 1.0}, block=64,
                               batch_size=32, repeats=1,
                               backends=("onepass-srht", "nystrom"))
    per = bench["per_backend"]
    assert set(per) == {"onepass-srht", "nystrom"}
    for name, row in per.items():
        assert row["accuracy"] >= 0.95, name
        assert row["assignments_per_sec"] > 0
        assert row["fit_memory_bytes"] > 0
    # Nystrom's serving height is the landmark count, not n.
    assert per["nystrom"]["n_ref"] < bench["n"]

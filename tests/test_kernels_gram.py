"""Shape/dtype sweep: gram-stripe Pallas kernel vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gram_stripe_pallas
from repro.kernels.gram.ref import gram_stripe_ref

pytestmark = pytest.mark.kernels    # CI kernel-parity job runs -m kernels


@pytest.mark.parametrize("p,n,w", [(2, 100, 12), (19, 555, 64), (7, 1024, 128),
                                   (128, 256, 256), (3, 97, 1)])
@pytest.mark.parametrize("kind,gamma,degree", [("polynomial", 0.0, 2),
                                               ("polynomial", 1.0, 3),
                                               ("rbf", 0.5, 0),
                                               ("linear", 0.0, 0)])
def test_gram_matches_ref(p, n, w, kind, gamma, degree):
    k1, k2 = jax.random.split(jax.random.PRNGKey(p * n + w))
    X = jax.random.normal(k1, (p, n), jnp.float32)
    Xb = jax.random.normal(k2, (p, w), jnp.float32)
    got = np.asarray(gram_stripe_pallas(X, Xb, kind=kind, gamma=gamma,
                                        degree=degree, interpret=True))
    want = np.asarray(gram_stripe_ref(X, Xb, kind=kind, gamma=gamma,
                                      degree=degree))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_gram_row_tiles():
    X = jax.random.normal(jax.random.PRNGKey(0), (5, 700))
    Xb = X[:, 13:29]
    for rt in (128, 256, 512):
        got = np.asarray(gram_stripe_pallas(X, Xb, row_tile=rt,
                                            interpret=True))
        want = np.asarray(gram_stripe_ref(X, Xb))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_gram_psd_on_self_block():
    """Full gram assembled from Pallas stripes is symmetric PSD."""
    X = jax.random.normal(jax.random.PRNGKey(3), (4, 96))
    K = np.asarray(gram_stripe_pallas(X, X, kind="rbf", gamma=1.0,
                                      interpret=True))
    np.testing.assert_allclose(K, K.T, rtol=1e-5, atol=1e-5)
    evals = np.linalg.eigvalsh(K)
    assert evals.min() > -1e-3

import os

# Tests run on the single real CPU device; only launch/dryrun.py forces 512
# host devices (and is exercised via subprocess in tests to keep isolation).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)

"""Fleet tier: stat merging, pin-guarded GC, routing, admission control,
adaptive per-bucket waits, and canary-then-promote rollouts. All timing
is driven by fake clocks — no sleeps, no flakes."""
import jax
import numpy as np
import pytest

from repro.api import KernelKMeans
from repro.data import blob_ring
from repro.fleet import (AdaptiveWaitController, AdmissionController, Fleet,
                         FleetWorker, RolloutManager, Router, ShedError)
from repro.serve import AsyncBatcher, LatencyStats, VersionStore, assign
from repro.serve.latency import Histogram

N, P, R, K, BLOCK = 250, 2, 2, 2, 64


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


@pytest.fixture(scope="module")
def model():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    return KernelKMeans(k=K, r=R, kernel="polynomial",
                        kernel_params={"gamma": 0.0, "degree": 2},
                        backend_params={"oversampling": 10},
                        block=BLOCK).fit(X, key=jax.random.PRNGKey(1)).model_


@pytest.fixture(scope="module")
def model_b(model):
    # Permuted centroid rows: same geometry, permuted labels — which
    # version served a request is readable from its labels.
    return model._replace(centroids=model.centroids[::-1])


@pytest.fixture()
def store(tmp_path, model):
    s = VersionStore(str(tmp_path / "versions"))
    s.publish(model)
    return s


def _requests(widths, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randn(P, w).astype(np.float32) for w in widths]


# ---------------------------------------------------------------------------
# LatencyStats.merge: tier aggregation must equal a single stream
# ---------------------------------------------------------------------------

def _record(stats, t0, wait_ms, extra_ms, bucket):
    stats.record(t0, t0 + wait_ms / 1e3, t0 + (wait_ms + extra_ms) / 1e3,
                 queries=3, bucket=bucket)


def test_merge_equals_single_stream_on_interleaved_samples():
    rng = np.random.RandomState(7)
    workers = [LatencyStats(slo_ms=50.0) for _ in range(3)]
    single = LatencyStats(slo_ms=50.0)
    # Interleave 300 samples round-robin across three workers; the same
    # stream lands in `single` in arrival order.
    for i in range(300):
        wait, extra = rng.exponential(5.0), rng.exponential(30.0)
        bucket = int(2 ** rng.randint(3, 7))
        _record(workers[i % 3], float(i), wait, extra, bucket)
        _record(single, float(i), wait, extra, bucket)
    merged = LatencyStats.merged(workers)
    got, want = merged.summary(), single.summary()
    # Histogram counts share fixed edges, so percentiles/counters are
    # EXACTLY the single-stream values; the means fold float sums in a
    # different order and may differ in the last ulp.
    for d in (got, want):
        d["latency_ms"]["mean"] = round(d["latency_ms"]["mean"], 9)
        for row in d["per_bucket"].values():
            row["mean"] = round(row["mean"], 9)
    assert got == want
    assert merged.requests == 300 and merged.queries == 900
    # Non-mutating: the per-worker stats were not folded into each other.
    assert workers[0].requests == 100


def test_merge_is_exact_at_every_percentile():
    a, b = LatencyStats(), LatencyStats()
    single = LatencyStats()
    for i, ms in enumerate([0.1, 1.0, 5.0, 42.0, 999.0, 0.5, 7.0, 80.0]):
        target = a if i % 2 == 0 else b
        _record(target, 0.0, ms / 2, ms / 2, None)
        _record(single, 0.0, ms / 2, ms / 2, None)
    m = LatencyStats.merged([a, b])
    for q in (1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0):
        assert m.total.percentile(q) == single.total.percentile(q)
        assert m.queue_wait.percentile(q) == single.queue_wait.percentile(q)


def test_merge_rejects_mismatched_slo():
    a, b = LatencyStats(slo_ms=50.0), LatencyStats(slo_ms=100.0)
    with pytest.raises(ValueError, match="different SLO"):
        a.merge(b)
    # An EMPTY slo-less aggregate adopts the first real threshold...
    empty = LatencyStats()
    empty.merge(b)
    assert empty.slo_ms == 100.0
    # ...but one that already recorded against None must refuse.
    dirty = LatencyStats()
    _record(dirty, 0.0, 1.0, 1.0, None)
    with pytest.raises(ValueError, match="different SLO"):
        dirty.merge(b)


def test_histogram_merge_folds_counts_min_max():
    a, b = Histogram(), Histogram()
    for ms in (1.0, 2.0, 3.0):
        a.record(ms)
    for ms in (0.5, 10.0):
        b.record(ms)
    out = a.merge(b)
    assert out is a
    assert a.n == 5
    assert a.min == 0.5 and a.max == 10.0
    assert abs(a.total - 16.5) < 1e-9


# ---------------------------------------------------------------------------
# VersionStore pins: GC must never delete a version a worker holds
# ---------------------------------------------------------------------------

def test_gc_spares_pinned_versions(tmp_path, model):
    s = VersionStore(str(tmp_path / "v"))
    v1 = s.publish(model)
    v2 = s.publish(model)
    v3 = s.publish(model)
    s.pin(v1, "w0")
    s.pin(v1, "w1")
    assert s.pins(v1) == ["w0", "w1"]
    removed = s.gc(keep=1)
    # v2 is neither recent nor pinned -> gone; pinned v1 survives.
    assert removed == [v2]
    assert s.versions() == [v1, v3]
    s.load(v1)                            # still fully loadable
    # Dropping ONE of two pins is not enough...
    s.unpin(v1, "w0")
    assert s.gc(keep=1) == []
    assert v1 in s.versions()
    # ...dropping the last pin is.
    s.unpin(v1, "w1")
    assert s.gc(keep=1) == [v1]
    assert s.versions() == [v3]
    assert s.pins(v1) == []               # pin dir swept with the version


def test_pin_unpin_edge_cases(tmp_path, model):
    s = VersionStore(str(tmp_path / "v"))
    v1 = s.publish(model)
    with pytest.raises(FileNotFoundError):
        s.pin(v1 + 7, "w0")               # pinning a ghost raises loudly
    s.pin(v1, "w0")
    s.unpin(v1, "w0")
    s.unpin(v1, "w0")                     # idempotent
    s.unpin(v1 + 7, "w0")                 # unpinning a ghost is a no-op
    assert s.pins(v1) == []


def test_worker_pin_lifecycle_guards_gc(store, model_b):
    w = FleetWorker("w0", store, clock=FakeClock())
    v1 = w.version
    assert store.pins(v1) == ["w0"]
    v2 = store.publish(model_b)
    # The serving version is pinned: aggressive GC cannot take it.
    store.gc(keep=1)
    assert v1 in store.versions()
    w.swap_to(v2)                         # re-pin: new BEFORE old released
    assert store.pins(v2) == ["w0"] and store.pins(v1) == []
    assert store.gc(keep=1) == [v1]       # now v1 is fair game
    w.stop()
    assert store.pins(v2) == []           # retirement releases the pin


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------

class StubWorker:
    def __init__(self, worker_id, depth=0):
        self.worker_id = worker_id
        self._depth = depth

    def depth(self):
        return self._depth


def test_least_loaded_routes_to_smallest_queue():
    ws = [StubWorker("a", 5), StubWorker("b", 2), StubWorker("c", 9)]
    r = Router(ws)
    assert r.route().worker_id == "b"
    ws[1]._depth = 100
    assert r.route().worker_id == "a"     # load signal is live, not cached
    ws[0]._depth = ws[2]._depth = 100
    assert r.route().worker_id == "a"     # ties break by id: deterministic


def test_hash_routing_is_sticky_and_covers_the_fleet():
    r = Router([StubWorker(f"w{i}") for i in range(4)], policy="hash")
    keys = [f"session-{i}" for i in range(400)]
    first = {k: r.route(k).worker_id for k in keys}
    assert {r.route(k).worker_id for k in keys} == set(first.values())
    assert first == {k: r.route(k).worker_id for k in keys}  # sticky
    # 64 vnodes keep every worker in rotation for 400 keys.
    assert len(set(first.values())) == 4


def test_hash_routing_remaps_only_the_removed_workers_keys():
    r = Router([StubWorker(f"w{i}") for i in range(4)], policy="hash")
    keys = [f"k{i}" for i in range(500)]
    before = {k: r.route(k).worker_id for k in keys}
    r.remove("w2")
    after = {k: r.route(k).worker_id for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # The consistency property: ONLY keys that lived on w2 moved.
    assert all(before[k] == "w2" for k in moved)
    assert not any(after[k] == "w2" for k in keys)


def test_router_membership_errors():
    r = Router([StubWorker("a")])
    with pytest.raises(ValueError, match="duplicate"):
        r.add(StubWorker("a"))
    with pytest.raises(KeyError):
        r.remove("ghost")
    with pytest.raises(ValueError, match="routing key"):
        Router([StubWorker("a")], policy="hash").route()
    with pytest.raises(ValueError, match="policy"):
        Router([], policy="round-robin")
    with pytest.raises(RuntimeError, match="no workers"):
        Router([]).route()


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_static_cap_sheds_queue_full():
    ac = AdmissionController(max_queue_depth=10)
    assert ac.admit(StubWorker("a", depth=6), 4).worker_id == "a"
    with pytest.raises(ShedError) as ei:
        ac.admit(StubWorker("a", depth=7), 4)
    assert ei.value.reason == "queue-full"
    assert ei.value.depth == 7 and ei.value.limit == 10
    assert ac.admitted == 1 and ac.shed == 1 and ac.shed_rate == 0.5


def test_breaker_tightens_cap_until_p99_recovers():
    ac = AdmissionController(max_queue_depth=100, slo_ms=50.0,
                             shed_factor=0.5)
    assert ac.effective_depth() == 100
    assert ac.update(80.0) is True        # p99 over SLO: breaker opens
    assert ac.effective_depth() == 50
    with pytest.raises(ShedError) as ei:
        ac.admit(StubWorker("a", depth=60), 1)
    assert ei.value.reason == "slo-breach"
    assert ac.update(10.0) is False       # tail recovered: breaker closes
    assert ac.effective_depth() == 100
    ac.admit(StubWorker("a", depth=60), 1)
    assert ac.summary()["shed_by_reason"] == {"slo-breach": 1}


def test_admission_validates_construction():
    with pytest.raises(ValueError):
        AdmissionController(max_queue_depth=0)
    with pytest.raises(ValueError):
        AdmissionController(shed_factor=0.0)


# ---------------------------------------------------------------------------
# Per-bucket deadlines + the AIMD wait controller
# ---------------------------------------------------------------------------

def test_per_bucket_wait_overrides_the_flush_deadline(model):
    clock = FakeClock()
    ab = AsyncBatcher(model, max_wait_ms=5.0, clock=clock, max_bucket=128)
    ab.set_bucket_wait(8, 1.0)            # tiny requests flush fast
    ab.submit(_requests([3])[0])          # window coalesces to bucket 8
    clock.advance_ms(1.0)
    assert ab.due()                       # 1 ms: the OVERRIDE applies
    assert ab.poll() == 1
    # A window that grows into an un-overridden bucket keeps the default.
    ab.submit(_requests([3])[0])
    ab.submit(_requests([30])[0])         # now coalesces to bucket 64
    clock.advance_ms(2.0)
    assert not ab.due()                   # default 5 ms still governs
    clock.advance_ms(3.0)
    assert ab.poll() == 2
    assert ab.bucket_wait(8) == 1.0 and ab.bucket_wait(64) == 5.0
    with pytest.raises(ValueError):
        ab.set_bucket_wait(8, 0.0)


def test_controller_decreases_wait_on_breached_bucket(store):
    clock = FakeClock()
    w = FleetWorker("w0", store, max_wait_ms=8.0, slo_ms=200.0, clock=clock)
    ctl = AdaptiveWaitController(200.0, min_samples=1, min_wait_ms=0.25)
    # One slow request: 150 ms total >> budget (200 * 0.5 = 100 ms).
    w.submit(_requests([3])[0])
    clock.advance_ms(150.0)
    w.flush()
    (adj,) = ctl.step(w)
    assert adj["action"] == "decrease"
    assert adj["wait_after_ms"] == 4.0    # multiplicative: 8 -> 4
    assert w.scheduler().bucket_wait(adj["bucket"]) == 4.0
    # No fresh traffic since the decision: the controller holds.
    assert ctl.step(w) == []
    # Repeated breaches floor at min_wait_ms, never zero.
    for _ in range(12):
        w.submit(_requests([3])[0])
        clock.advance_ms(150.0)
        w.flush()
        ctl.step(w)
    assert w.scheduler().bucket_wait(adj["bucket"]) == 0.25
    w.stop()


def test_controller_increases_wait_on_comfortable_bucket(store):
    clock = FakeClock()
    w = FleetWorker("w0", store, max_wait_ms=2.0, slo_ms=200.0, clock=clock)
    ctl = AdaptiveWaitController(200.0, min_samples=8, increase_ms=0.5,
                                 max_wait_ms=3.0)
    for _ in range(8):                    # fast traffic: ~1 ms latencies
        w.submit(_requests([3])[0])
        clock.advance_ms(1.0)
        w.flush()
    (adj,) = ctl.step(w)
    assert adj["action"] == "increase"
    assert adj["wait_after_ms"] == 2.5    # additive: one step per period
    # Creep is capped at the controller's max.
    for _ in range(4):
        for _ in range(8):
            w.submit(_requests([3])[0])
            clock.advance_ms(1.0)
            w.flush()
        ctl.step(w)
    assert w.scheduler().bucket_wait(adj["bucket"]) == 3.0
    w.stop()


def test_controller_needs_min_samples_before_acting(store):
    clock = FakeClock()
    w = FleetWorker("w0", store, max_wait_ms=2.0, slo_ms=200.0, clock=clock)
    ctl = AdaptiveWaitController(200.0, min_samples=8)
    for _ in range(7):                    # one short of the window
        w.submit(_requests([3])[0])
        clock.advance_ms(1.0)
        w.flush()
    assert ctl.step(w) == []
    with pytest.raises(ValueError):
        AdaptiveWaitController(0.0)
    with pytest.raises(ValueError):
        AdaptiveWaitController(100.0, decrease_factor=1.0)
    w.stop()


# ---------------------------------------------------------------------------
# Rollouts: canary-then-promote, rollback on breach
# ---------------------------------------------------------------------------

def test_rollout_promotes_canary_first_then_fleet(store, model_b):
    clock = FakeClock()
    workers = [FleetWorker(f"w{i}", store, clock=clock) for i in range(3)]
    v1 = workers[0].version
    v2 = store.publish(model_b)
    seen = []
    mgr = RolloutManager(workers, store, budget_ms=100.0,
                         probe=lambda w: seen.append(
                             [x.version for x in workers]) or 0.0)
    rep = mgr.rollout()
    assert rep.promoted and rep.state == "done"
    assert [s for s, _ in rep.timeline] == \
        ["canary", "probing", "promoting", "done"]
    # At probe time ONLY the canary had swapped — the blast radius.
    assert seen == [[v2, v1, v1]]
    assert all(w.version == v2 for w in workers)
    assert rep.old_versions == {"w0": v1, "w1": v1, "w2": v1}
    assert set(rep.swaps) == {f"w{i}->v{v2}" for i in range(3)}
    # Idempotent: a second rollout to the same target is a no-op.
    assert mgr.rollout() is None
    for w in workers:
        w.stop()


def test_breached_probe_rolls_back_and_restores_version(store, model_b):
    clock = FakeClock()
    workers = [FleetWorker(f"w{i}", store, clock=clock) for i in range(2)]
    v1 = workers[0].version
    v2 = store.publish(model_b)
    mgr = RolloutManager(workers, store, budget_ms=100.0,
                         probe=lambda w: 350.0)   # injected breach
    # Pending traffic on BOTH workers across the failed rollout.
    pend = [w.submit(r) for w in workers for r in _requests([4])]
    rep = mgr.rollout(v2)
    for w in workers:
        w.flush()
    assert not rep.promoted and rep.state == "rolled-back"
    assert [s for s, _ in rep.timeline] == ["canary", "probing",
                                            "rolled-back"]
    assert all(w.version == v1 for w in workers)   # prior version restored
    assert rep.canary_p95_ms == 350.0
    # The canary swapped out AND back; the follower never moved.
    assert set(rep.swaps) == {f"w0->v{v2}", f"w0->v{v1}"}
    assert sum(not f.done() for f in pend) == 0    # zero stranded futures
    assert v2 in store.versions()                  # target intact for retry
    assert store.pins(v1) == ["w0", "w1"]          # guard pin released
    for w in workers:
        w.stop()


def test_single_worker_rollback_survives_concurrent_gc(store, model_b):
    # The canary's own swap releases its pin on the outgoing version; on
    # a 1-worker fleet the manager's guard pin is all that stops a GC
    # during probing from deleting the rollback target.
    clock = FakeClock()
    w = FleetWorker("w0", store, clock=clock)
    v1 = w.version
    v2 = store.publish(model_b)

    def probe_with_gc(worker):
        store.gc(keep=1)                  # hostile GC mid-decision
        return 999.0                      # then the probe breaches

    rep = RolloutManager([w], store, budget_ms=10.0).rollout(
        v2, probe=probe_with_gc)
    assert rep.state == "rolled-back" and w.version == v1
    np.testing.assert_array_equal(
        np.asarray(store.load(v1).centroids),
        np.asarray(store.load(w.version).centroids))
    w.stop()


# ---------------------------------------------------------------------------
# Fleet front door, end to end
# ---------------------------------------------------------------------------

def test_fleet_routed_labels_match_direct_assignment(store, model):
    clock = FakeClock()
    with Fleet(store, n_workers=3, clock=clock, max_wait_ms=2.0) as fleet:
        reqs = _requests([5, 17, 2, 31, 9, 24], seed=3)
        futs = [fleet.submit(r) for r in reqs]
        assert fleet.depth() == sum(r.shape[1] for r in reqs)
        fleet.flush()
        got = np.concatenate([f.result()[0] for f in futs])
        want, _ = assign(model, np.concatenate(reqs, axis=1))
        np.testing.assert_array_equal(got, np.asarray(want))
        assert fleet.latency().requests == len(reqs)


def test_fleet_overload_sheds_but_keeps_admitted_p99_in_slo(store):
    clock = FakeClock()
    fleet = Fleet(store, n_workers=2, max_queue_depth=8, slo_ms=250.0,
                  clock=clock, max_wait_ms=2.0)
    futs, shed = [], 0
    for r in _requests([4] * 32, seed=5):
        clock.advance_ms(1.0)             # queue wait accrues, bounded
        try:
            futs.append(fleet.submit(r))
        except ShedError as e:
            assert e.reason == "queue-full"
            shed += 1
    fleet.flush()
    assert shed > 0                              # the flood DID shed
    assert len(futs) == 4                        # 2 workers x depth 8 / 4
    assert sum(not f.done() for f in futs) == 0  # admitted all resolved
    stats = fleet.latency()
    assert stats.total.percentile(99.0) <= 250.0
    assert stats.slo_violations == 0
    assert fleet.admission.shed_rate == shed / 32
    assert fleet.stats()["admission"]["shed_by_reason"] == \
        {"queue-full": shed}
    fleet.stop()


def test_fleet_control_loop_closes_both_feedbacks(store):
    clock = FakeClock()
    fleet = Fleet(store, n_workers=2, slo_ms=100.0, max_queue_depth=100,
                  clock=clock, max_wait_ms=2.0)
    for r in _requests([3] * 4):
        fleet.submit(r)
        clock.advance_ms(3.0)             # past every deadline
    ctl = fleet.control()
    assert ctl["completed"] == 4          # poll flushed the due windows
    assert ctl["breaker_open"] is False   # fake-clock latencies are tiny
    assert ctl["p99_ms"] <= 100.0
    # Force a breach through the same path the tier p99 feeds.
    fleet.admission.update(500.0)
    with pytest.raises(ShedError) as ei:
        # Effective cap is 50; a 60-wide request cannot be admitted even
        # onto an empty worker.
        fleet.submit(_requests([60])[0])
    assert ei.value.reason == "slo-breach"
    fleet.stop()


def test_fleet_rollout_and_sync_follow_the_store(store, model, model_b):
    clock = FakeClock()
    fleet = Fleet(store, n_workers=2, clock=clock, rollout_budget_ms=100.0)
    assert fleet.sync() is None           # already at latest
    v2 = store.publish(model_b)
    rep = fleet.sync()                    # follower mode picks it up
    assert rep is not None and rep.promoted
    assert fleet.stats()["versions"] == {"w0": v2, "w1": v2}
    # Labels prove the new version serves: permuted centroids flip them.
    r = _requests([16], seed=9)[0]
    fut = fleet.submit(r)
    fleet.flush()
    want_new, _ = assign(model_b, r)
    want_old, _ = assign(model, r)
    np.testing.assert_array_equal(fut.result()[0], np.asarray(want_new))
    assert not np.array_equal(np.asarray(want_new), np.asarray(want_old))
    fleet.stop()
    assert all(store.pins(v) == [] for v in store.versions())

"""Model lifecycle: versioned artifact store, warm hot-swap, and the
serving-path regression sweep (latency bucket edges, registry kwargs
conflicts, artifact leaf names, post-stop submits). CI's serve-smoke job
runs this file on its own as the registry/lifecycle smoke."""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.api import KernelKMeans
from repro.data import blob_ring
from repro.serve import (AsyncBatcher, MicroBatcher, ModelRegistry,
                         VersionStore, latest_version, load_model,
                         load_version, publish_version, save_model)
from repro.serve import latency as lat

N, P, R, K, BLOCK = 250, 2, 2, 2, 64


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


@pytest.fixture(scope="module")
def model():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    return KernelKMeans(k=K, r=R, kernel="polynomial",
                        kernel_params={"gamma": 0.0, "degree": 2},
                        backend_params={"oversampling": 10},
                        block=BLOCK).fit(X, key=jax.random.PRNGKey(1)).model_


@pytest.fixture(scope="module")
def model_b(model):
    """Same fit, centroid rows flipped: labels permute 0<->1, so a test
    can tell which model version served a request."""
    return model._replace(centroids=model.centroids[::-1])


def _requests(widths, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randn(P, w).astype(np.float32) for w in widths]


# ---------------------------------------------------------------------------
# versioned artifact store
# ---------------------------------------------------------------------------

def test_version_store_publish_latest_pinned(model, model_b, tmp_path):
    store = VersionStore(str(tmp_path / "store"))
    assert store.versions() == [] and store.latest() is None
    with pytest.raises(FileNotFoundError):
        store.path()
    v1 = store.publish(model)
    v2 = store.publish(model_b)
    assert (v1, v2) == (1, 2)
    assert store.versions() == [1, 2] and store.latest() == 2
    # Pinned read of v1 vs latest: centroids differ by the row flip.
    np.testing.assert_array_equal(np.asarray(store.load(1).centroids),
                                  np.asarray(model.centroids))
    np.testing.assert_array_equal(np.asarray(store.load().centroids),
                                  np.asarray(model_b.centroids))


def test_version_store_gc_keeps_last_k(model, tmp_path):
    store = VersionStore(str(tmp_path / "store"))
    for _ in range(5):
        store.publish(model)
    removed = store.gc(keep=2)
    assert removed == [1, 2, 3]
    assert store.versions() == [4, 5]
    store.load(4)                              # survivors still load
    with pytest.raises(FileNotFoundError):
        store.load(2)                          # GC'ed pin fails loudly
    # Version numbers are never reused after GC.
    assert store.publish(model) == 6
    with pytest.raises(ValueError):
        store.gc(keep=0)


def test_version_store_publish_keep_inline(model, tmp_path):
    store = VersionStore(str(tmp_path / "store"), keep=2)
    for _ in range(4):
        store.publish(model)                   # constructor keep applies
    assert store.versions() == [3, 4]


def test_version_store_ignores_inflight_and_junk(model, tmp_path):
    import os
    import time as time_mod

    root = tmp_path / "store"
    store = VersionStore(str(root))
    store.publish(model)
    (root / "v_9.tmp").mkdir()                 # crashed publish (stale)
    old = time_mod.time() - 7200
    os.utime(root / "v_9.tmp", (old, old))
    (root / "v_8.tmp").mkdir()                 # in-flight publish (fresh)
    (root / "not_a_version").mkdir()
    (root / "v_7").mkdir()                     # no spec.json: incomplete
    assert store.versions() == [1]
    assert store.latest() == 1
    store.gc(keep=1)
    assert not (root / "v_9.tmp").exists()     # stale crash swept
    assert (root / "v_8.tmp").exists()         # live writer left alone


def test_version_store_publish_never_clobbers_existing_dir(model, tmp_path):
    """A publisher losing the allocation race (or hitting junk at its
    target number) must take the next free number, not replace the
    committed directory."""
    root = tmp_path / "store"
    store = VersionStore(str(root))
    store.publish(model)                       # v_1
    blocker = root / "v_2"                     # another writer's commit /
    blocker.mkdir()                            # junk: invisible to scan
    (blocker / "marker").write_text("keep me")
    v = store.publish(model)
    assert v == 3                              # bumped past the blocker
    assert (blocker / "marker").read_text() == "keep me"
    assert store.versions() == [1, 3]
    store.load(3)


# ---------------------------------------------------------------------------
# warm hot-swap
# ---------------------------------------------------------------------------

def test_swap_under_load_resolves_every_future(model, model_b):
    """Async traffic on a fake clock while swap() flips versions: every
    future resolves, labels match the version that served them, and no
    bucket executable recompiles after warm-up."""
    reg = ModelRegistry()
    reg.register("m", model, version=1)
    clock = FakeClock()
    sched = reg.scheduler("m", max_wait_ms=5.0, clock=clock, max_bucket=128)
    reqs = _requests([3, 17, 40, 9, 26], seed=7)

    # Expected labels per request through each version.
    want_old, want_new = [], []
    for engine, want in ((MicroBatcher(model, max_bucket=128), want_old),
                         (MicroBatcher(model_b, max_bucket=128), want_new)):
        for r in reqs:
            engine.submit(r)
        want.extend(lab for lab, _ in engine.drain())

    # Phase 1: deadline-driven traffic against v1, two flush rounds that
    # compile buckets 32 (20 cols) and 128 (75 cols).
    done = [sched.submit(r) for r in reqs[:2]]
    clock.advance_ms(6.0)
    assert sched.poll() == 2
    done += [sched.submit(r) for r in reqs[2:]]
    clock.advance_ms(6.0)
    assert sched.poll() == 3
    assert sched.batcher.executables == [32, 128]
    # Phase 2: requests still pending (same widths as round one — inside
    # the recorded bucket history) when the swap flips.
    pending = [sched.submit(r) for r in reqs[:2]]
    report = reg.swap("m", model_b, version=2)
    # The drain resolved the pending futures against the OLD model.
    assert report.drained_requests == 2
    assert all(f.done() for f in done + pending)
    for f, want in zip(done + pending, want_old + want_old[:2]):
        np.testing.assert_array_equal(f.result(timeout=0)[0], want)
    # The retired handle rejects submits instead of stranding futures.
    with pytest.raises(RuntimeError):
        sched.submit(reqs[0])

    # Phase 3: the swapped-in scheduler serves v2 — with the surviving
    # LatencyStats and the warmed executables.
    sched2 = reg.scheduler("m")
    assert sched2 is not sched
    assert sched2.latency is sched.latency
    assert sched2.latency.requests == 7
    execs_after_warmup = list(sched2.batcher.executables)
    assert execs_after_warmup == report.buckets_warmed
    futs = [sched2.submit(r) for r in reqs]
    clock.advance_ms(6.0)
    assert sched2.poll() == 5
    for f, want in zip(futs, want_new):
        np.testing.assert_array_equal(f.result(timeout=0)[0], want)
    # Post-warm-up traffic hit only pre-compiled buckets: no recompiles.
    assert list(sched2.batcher.executables) == execs_after_warmup
    assert reg.version("m") == 2
    assert report.flip_ms >= 0.0
    assert report.p95_before_ms >= 0.0


def test_swap_warms_sync_batcher_and_keeps_kwargs(model, model_b):
    reg = ModelRegistry()
    reg.register("m", model)
    b1 = reg.batcher("m", max_bucket=64, min_bucket=8)
    for w in (3, 30, 64):
        b1.assign_batch(np.asarray(_requests([w])[0]))
    assert b1.executables == [8, 32, 64]
    report = reg.swap("m", model_b)
    b2 = reg.batcher("m")
    assert b2 is not b1
    # Same construction kwargs carried over; all old buckets pre-warmed.
    assert b2.max_bucket == 64 and b2.min_bucket == 8
    assert b2.executables == [8, 32, 64] == report.buckets_warmed
    labels, _ = b2.assign_batch(np.asarray(_requests([30])[0]))
    assert labels.shape == (30,)
    assert b2.executables == [8, 32, 64]       # no new executable
    # A swap with conflicting kwargs later still raises on lookup.
    with pytest.raises(ValueError):
        reg.batcher("m", max_bucket=128)


def test_swap_restarts_running_pump(model, model_b):
    reg = ModelRegistry()
    reg.register("m", model)
    sched = reg.scheduler("m", max_wait_ms=1.0, max_bucket=128)
    sched.start()
    fut = sched.submit(_requests([4])[0])
    fut.result(timeout=30.0)
    reg.swap("m", model_b)
    assert not sched.running                   # old pump stopped
    sched2 = reg.scheduler("m")
    assert sched2.running                      # pump carried over
    fut2 = sched2.submit(_requests([6])[0])
    labels, _ = fut2.result(timeout=30.0)      # no poll: pump flushes
    assert labels.shape == (6,)
    reg.unregister("m")
    assert not sched2.running


def test_swap_missing_name_raises(model):
    with pytest.raises(KeyError):
        ModelRegistry().swap("ghost", model)


# ---------------------------------------------------------------------------
# [bugfix] serve/latency.py: bucket count + edge indexing
# ---------------------------------------------------------------------------

def test_latency_bucket_count_exact():
    # 1e-3 .. 1e5 ms is exactly 8 decades; int(log10(1e8)) could truncate
    # to 7 on libms where log10 lands at 7.999..., silently dropping a
    # decade of buckets.
    assert lat._N_BUCKETS == 8 * lat._PER_DECADE


def test_latency_bucket_edges_index_exactly():
    for i in range(lat._N_BUCKETS):
        edge = lat._LO_MS * 10.0 ** (i / lat._PER_DECADE)
        assert lat._bucket_index(edge) == i, f"edge {i} mis-bucketed"
        lo, hi = lat._bucket_edges(i)
        assert lo <= edge < hi
        # Just inside the bucket interior lands in the same bucket.
        assert lat._bucket_index(edge * 1.01) == i
    assert lat._bucket_index(0.0) == 0
    assert lat._bucket_index(lat._LO_MS) == 0
    assert lat._bucket_index(1e12) == lat._N_BUCKETS - 1


def test_latency_edge_sample_percentile_consistent():
    stats = lat.Histogram()
    edge = lat._LO_MS * 10.0 ** (32 / lat._PER_DECADE)   # an exact edge
    for _ in range(100):
        stats.record(edge)
    # All mass sits in one bucket whose clamped percentile is the sample.
    assert stats.percentile(50.0) == pytest.approx(edge)
    assert stats.percentile(99.0) == pytest.approx(edge)


# ---------------------------------------------------------------------------
# [bugfix] registry kwargs conflicts on cache hits
# ---------------------------------------------------------------------------

def test_registry_batcher_kwargs_conflict_raises(model):
    reg = ModelRegistry()
    reg.register("m", model)
    b = reg.batcher("m", max_bucket=64)
    assert reg.batcher("m") is b                        # bare hit: fine
    assert reg.batcher("m", max_bucket=64) is b         # same kwargs: fine
    with pytest.raises(ValueError, match="conflicting override"):
        reg.batcher("m", max_bucket=128)
    with pytest.raises(ValueError, match="conflicting override"):
        reg.batcher("m", interpret=True)                # not recorded


def test_registry_scheduler_kwargs_conflict_raises(model):
    reg = ModelRegistry()
    reg.register("m", model)
    clock = FakeClock()
    s = reg.scheduler("m", max_wait_ms=2.0, clock=clock)
    assert reg.scheduler("m") is s
    assert reg.scheduler("m", max_wait_ms=2.0, clock=clock) is s
    with pytest.raises(ValueError, match="conflicting override"):
        reg.scheduler("m", max_wait_ms=999.0)
    reg.unregister("m")


# ---------------------------------------------------------------------------
# [bugfix] artifact leaf names persisted explicitly
# ---------------------------------------------------------------------------

def test_artifact_persists_leaf_names(model, tmp_path):
    path = pathlib.Path(save_model(model, str(tmp_path / "a")))
    names = json.loads((path / "leaves.json").read_text())["names"]
    assert set(names) == {"X_train", "U", "eigvals", "centroids",
                          "sketch_signs", "sketch_rows",
                          "stream_w", "stream_row_norms2", "stream_counts"}
    loaded = load_model(str(path))
    np.testing.assert_array_equal(np.asarray(loaded.U),
                                  np.asarray(model.U))
    np.testing.assert_array_equal(np.asarray(loaded.X_train),
                                  np.asarray(model.X_train))


def test_artifact_legacy_without_leaves_json(model, tmp_path):
    """Artifacts written before leaves.json existed still load via the
    keystr-path fallback."""
    path = pathlib.Path(save_model(model, str(tmp_path / "a")))
    (path / "leaves.json").unlink()
    loaded = load_model(str(path))
    np.testing.assert_array_equal(np.asarray(loaded.centroids),
                                  np.asarray(model.centroids))
    np.testing.assert_array_equal(np.asarray(loaded.eigvals),
                                  np.asarray(model.eigvals))


# ---------------------------------------------------------------------------
# [bugfix] scheduler: post-stop submits rejected, stop idempotent
# ---------------------------------------------------------------------------

def test_submit_after_stop_rejected_not_stranded(model):
    ab = AsyncBatcher(model, clock=FakeClock(), max_bucket=128)
    fut = ab.submit(_requests([4])[0])
    assert ab.stop() == 1                      # stop flushes pending
    assert fut.done()
    with pytest.raises(RuntimeError, match="stopped"):
        ab.submit(_requests([4])[0])           # would never flush
    assert ab.stop() == 0                      # idempotent
    with pytest.raises(RuntimeError):
        ab.start()                             # a stopped batcher is dead


def test_context_manager_stop_is_terminal(model):
    with AsyncBatcher(model, max_wait_ms=1.0, max_bucket=128) as ab:
        ab.submit(_requests([3])[0]).result(timeout=30.0)
    assert ab.stopped and not ab.running
    with pytest.raises(RuntimeError):
        ab.submit(_requests([3])[0])


# ---------------------------------------------------------------------------
# registry versioned-store integration
# ---------------------------------------------------------------------------

def test_registry_publish_and_load_version(model, model_b, tmp_path):
    root = str(tmp_path / "store")
    reg = ModelRegistry()
    reg.register("m", model)
    assert reg.version("m") is None
    v1 = reg.publish("m", root)
    assert v1 == 1 and reg.version("m") == 1
    reg.register("m", model_b, overwrite=True)
    v2 = reg.publish("m", root, keep=2)
    assert v2 == 2
    # module-level conveniences agree with the store
    assert latest_version(root) == 2
    pinned = load_version(root, 1)
    np.testing.assert_array_equal(np.asarray(pinned.centroids),
                                  np.asarray(model.centroids))
    # load_version registers + tags the row
    reg2 = ModelRegistry()
    reg2.load_version("m", root)
    assert reg2.version("m") == 2
    reg2.load_version("pinned", root, version=1)
    np.testing.assert_array_equal(np.asarray(reg2.get("pinned").centroids),
                                  np.asarray(model.centroids))
    assert publish_version(root, model) == 3

"""Fused extend_embed serving stripe vs the two-pass path, end to end.

The fused engine (kernels/extend_embed through serve.extend.Extender)
must be indistinguishable from the two-pass gram+projection engine at
every serving surface: raw embed, training-point round-trip, bucketed
MicroBatcher, async futures — on rbf + linear + polynomial, ragged tail
stripes included. Also pins the explicit fused=/interpret= override
contract (the old code silently fell back to jnp on CPU).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelKMeans
from repro.data import blob_ring
from repro.serve import (AsyncBatcher, Extender, MicroBatcher, assign,
                         embed)
from repro.serve.extend import resolve_pallas_path

N, P, BLOCK = 250, 2, 64    # ragged: 250 = 3*64 + 58


def _fit(kernel, params, r=2, key=1):
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    return KernelKMeans(k=2, r=r, kernel=kernel, kernel_params=params,
                        backend_params={"oversampling": 10},
                        block=BLOCK).fit(X, key=jax.random.PRNGKey(key)).model_


@pytest.fixture(scope="module")
def models():
    return {
        "polynomial": _fit("polynomial", {"gamma": 0.0, "degree": 2}),
        "rbf": _fit("rbf", {"gamma": 1.0}, r=4),
        "linear": _fit("linear", {}),
    }


@pytest.mark.parametrize("kernel", ["polynomial", "rbf", "linear"])
@pytest.mark.parametrize("width", [1, 64, 101])   # < block, == block, ragged
def test_fused_embed_matches_two_pass(models, kernel, width):
    m = models[kernel]
    Xq = jax.random.normal(jax.random.PRNGKey(width), (P, width)) * 1.5
    Y_two = embed(m, Xq, fused=False)
    Y_fused = embed(m, Xq, fused=True, interpret=True)
    rel = (float(jnp.linalg.norm(Y_fused - Y_two)) /
           max(float(jnp.linalg.norm(Y_two)), 1e-30))
    assert rel <= 1e-5, (kernel, width, rel)


def test_fused_train_point_round_trip(models):
    """The extension identity y(x_j) == Y e_j through the FUSED stripe."""
    m = models["polynomial"]
    Y_ext = embed(m, m.X_train, fused=True, interpret=True)
    rel = (float(jnp.linalg.norm(Y_ext - m.Y)) /
           float(jnp.linalg.norm(m.Y)))
    assert rel <= 1e-4, rel


def test_fused_narrowed_stripe_matches(models):
    """Bucket-narrowed stripes (block < model block) stay exact."""
    m = models["rbf"]
    Xq = jax.random.normal(jax.random.PRNGKey(5), (P, 40)) * 1.5
    want = embed(m, Xq, fused=False)
    for blk in (8, 16, 40):
        got = Extender(m, blk, fused=True, interpret=True).embed(Xq)
        rel = (float(jnp.linalg.norm(got - want)) /
               float(jnp.linalg.norm(want)))
        assert rel <= 1e-5, (blk, rel)


@pytest.mark.parametrize("kernel", ["polynomial", "rbf"])
def test_fused_serving_stack_parity(models, kernel):
    """MicroBatcher + AsyncBatcher on the forced Pallas path give the
    same labels as the default two-pass stack, ragged requests and all."""
    m = models[kernel]
    Xq = jax.random.normal(jax.random.PRNGKey(11), (P, 101)) * 1.5
    want, _ = assign(m, Xq)
    mb = MicroBatcher(m, max_bucket=64, embed_fused=True, interpret=True)
    got, _ = mb.assign_batch(Xq)
    assert np.array_equal(got, np.asarray(want)), kernel
    ab = AsyncBatcher(m, max_wait_ms=5.0, max_bucket=64,
                      embed_fused=True, interpret=True)
    futs = [ab.submit(np.asarray(Xq[:, i:i + 25]))
            for i in range(0, 101, 25)]
    ab.flush()
    got_async = np.concatenate([f.result()[0] for f in futs])
    assert np.array_equal(got_async, np.asarray(want)), kernel


def test_assign_embed_fused_override(models):
    m = models["polynomial"]
    Xq = jax.random.normal(jax.random.PRNGKey(13), (P, 33)) * 1.5
    lab, d2 = assign(m, Xq)
    lab_f, d2_f = assign(m, Xq, embed_fused=True, interpret=True)
    assert np.array_equal(np.asarray(lab), np.asarray(lab_f))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_f),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# The explicit override contract (resolve_pallas_path) on the CPU backend
# ---------------------------------------------------------------------------

def test_cpu_default_is_two_pass():
    fused, interp = resolve_pallas_path(None, None, "x")
    assert fused is False and interp is False


def test_cpu_interpret_opts_into_pallas():
    fused, interp = resolve_pallas_path(None, True, "x")
    assert fused is True and interp is True


def test_cpu_fused_true_warns_then_interprets():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fused, interp = resolve_pallas_path(True, None, "x")
    assert fused is True and interp is True
    assert any("interpret mode" in str(x.message) for x in w)


def test_cpu_fused_true_interpret_true_is_silent():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fused, interp = resolve_pallas_path(True, True, "x")
    assert fused is True and interp is True and not w


def test_conflicting_settings_raise(models):
    m = models["polynomial"]
    Xq = jnp.zeros((P, 4), jnp.float32)
    # Pallas requested but interpret explicitly refused on CPU.
    with pytest.raises(ValueError, match="interpret=False"):
        embed(m, Xq, fused=True, interpret=False)
    # interpret set while the Pallas path is explicitly off.
    with pytest.raises(ValueError, match="fused=False conflicts"):
        embed(m, Xq, fused=False, interpret=True)
    with pytest.raises(ValueError, match="fused=False conflicts"):
        MicroBatcher(m, embed_fused=False, interpret=True)


def test_interpret_extender_allows_per_call_jnp_assign(models):
    """assign(fused=False) on a forced-Pallas extender (the CI config)
    must fall back to the jnp argmin, not raise a conflict — the
    constructor's interpret arg only applies to Pallas-path requests."""
    m = models["polynomial"]
    ext = Extender(m, fused=True, interpret=True)
    Xq = jax.random.normal(jax.random.PRNGKey(19), (P, 12)) * 1.5
    lab_pal, _ = ext.assign(Xq)                   # Pallas (constructor)
    lab_jnp, _ = ext.assign(Xq, fused=False)      # per-call jnp fallback
    assert np.array_equal(np.asarray(lab_pal), np.asarray(lab_jnp))


def test_extender_per_call_assign_override(models):
    m = models["polynomial"]
    ext = Extender(m)    # CPU defaults: two-pass embed, jnp assign
    assert ext.fused is False and ext.assign_fused is False
    Xq = jax.random.normal(jax.random.PRNGKey(17), (P, 20)) * 1.5
    lab_jnp, _ = ext.assign(Xq)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        lab_pal, _ = ext.assign(Xq, fused=True)     # per-call: warn + interp
    assert any("interpret mode" in str(x.message) for x in w)
    assert np.array_equal(np.asarray(lab_jnp), np.asarray(lab_pal))

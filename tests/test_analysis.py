"""repro.analysis test suite.

One seeded-violation + one clean fixture per rule (J001-J004, C001-C003,
L001-L003, X001), the scheduler lock-order regression (the checker must
flag inverted acquisition of the real serve/scheduler.py contract), the
kernel-contract verifier over every registered package at every parity
shape, baseline semantics, and the whole-repo clean gate CI runs.
"""
import io
import textwrap
from pathlib import Path

import pytest

from repro.analysis import jaxlint, locks, runner
from repro.analysis.baseline import (BaselineError, Suppression,
                                     apply_baseline, load_baseline)
from repro.analysis.findings import RULES, Finding

REPO = Path(__file__).resolve().parents[1]


def lint(src: str):
    return jaxlint.lint_source(textwrap.dedent(src), "fixture.py")


def lockcheck(src: str):
    return locks.check_source(textwrap.dedent(src), "fixture.py")


def rule_ids(findings):
    return sorted(f.rule for f in findings)


# -- J001: PRNG key reuse ---------------------------------------------------

def test_j001_fires_on_double_consumption():
    findings = lint("""
        import jax

        def draw(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """)
    assert rule_ids(findings) == ["J001"]


def test_j001_clean_after_split():
    findings = lint("""
        import jax

        def draw(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            return a + b
    """)
    assert findings == []


def test_j001_fires_on_loop_consuming_outer_key():
    findings = lint("""
        import jax

        def draw(key):
            outs = []
            for i in range(4):
                outs.append(jax.random.normal(key, (3,)))
            return outs
    """)
    assert rule_ids(findings) == ["J001"]
    assert "loop" in findings[0].message


def test_j001_clean_fold_in_per_iteration():
    # fold_in DERIVES a fresh stream per iteration — the canonical
    # pattern (cf. core/cluster.py) must not fire.
    findings = lint("""
        import jax

        def draw(key):
            outs = []
            for i in range(4):
                k = jax.random.fold_in(key, i)
                outs.append(jax.random.normal(k, (3,)))
            return outs
    """)
    assert findings == []


def test_j001_clean_branch_exclusive_uses():
    # Double use split across exclusive if/else branches is NOT reuse
    # (cf. launch/specs.py); the branches cannot both run.
    findings = lint("""
        import jax

        def draw(key, discrete):
            if discrete:
                return jax.random.randint(key, (3,), 0, 7)
            return jax.random.normal(key, (3,))
    """)
    assert findings == []


def test_j001_fires_when_branch_falls_through():
    findings = lint("""
        import jax

        def draw(key, noisy):
            if noisy:
                extra = jax.random.normal(key, (3,))
            return jax.random.uniform(key, (3,))
    """)
    assert rule_ids(findings) == ["J001"]


# -- J002: host sync inside traced scope ------------------------------------

def test_j002_fires_on_item_inside_jit():
    findings = lint("""
        import jax

        @jax.jit
        def f(x):
            return x.sum().item()
    """)
    assert rule_ids(findings) == ["J002"]


def test_j002_fires_on_float_over_tracer():
    findings = lint("""
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0
    """)
    assert rule_ids(findings) == ["J002"]


def test_j002_clean_outside_jit():
    findings = lint("""
        def f(x):
            return float(x.sum().item())
    """)
    assert findings == []


# -- J003: Python branch on a tracer ----------------------------------------

def test_j003_fires_on_tracer_branch():
    findings = lint("""
        import jax

        @jax.jit
        def f(x):
            if x.sum() > 0:
                return x
            return -x
    """)
    assert rule_ids(findings) == ["J003"]


def test_j003_clean_static_and_shape_branches():
    # static_argnums marks `normalize` concrete; .shape is concrete on
    # tracers. Neither branch may fire (regression: static_argnums was
    # once ignored and core/sketch.py false-positived).
    findings = lint("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, normalize):
            if normalize:
                x = x / 2.0
            if x.shape[0] > 2:
                return x
            return -x
    """)
    assert findings == []


# -- J004: mutable static jit args ------------------------------------------

def test_j004_fires_on_dict_static():
    findings = lint("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("opts",))
        def f(x, opts: dict):
            return x
    """)
    assert rule_ids(findings) == ["J004"]


def test_j004_fires_on_non_frozen_dataclass_static():
    findings = lint("""
        import dataclasses
        import functools
        import jax

        @dataclasses.dataclass
        class Cfg:
            n: int = 1

        @functools.partial(jax.jit, static_argnames=("cfg",))
        def f(x, cfg: Cfg):
            return x
    """)
    assert rule_ids(findings) == ["J004"]
    assert "frozen" in findings[0].message


def test_j004_clean_frozen_dataclass_static():
    # The ComputePolicy pattern: frozen dataclass statics hash by value.
    findings = lint("""
        import dataclasses
        import functools
        import jax

        @dataclasses.dataclass(frozen=True)
        class Policy:
            n: int = 1

        @functools.partial(jax.jit, static_argnames=("policy",))
        def f(x, policy: Policy):
            return x
    """)
    assert findings == []


# -- X001: unparseable file -------------------------------------------------

def test_x001_fires_on_syntax_error():
    findings = lint("def broken(:\n")
    assert rule_ids(findings) == ["X001"]


# -- L001: guarded-by discipline --------------------------------------------

_LOCKED_CLASS = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []   # guarded-by: _lock

        def add(self, x):
            {body}
"""


def test_l001_fires_on_unlocked_mutation():
    findings = lockcheck(
        _LOCKED_CLASS.format(body="self._items.append(x)"))
    assert rule_ids(findings) == ["L001"]


def test_l001_clean_under_lock():
    findings = lockcheck(_LOCKED_CLASS.format(
        body="with self._lock:\n                self._items.append(x)"))
    assert findings == []


def test_l001_fires_on_unlocked_rebind():
    # The pre-fix scheduler.stop() shape: rebinding the guarded handle
    # outside the lock.
    findings = lockcheck("""
        import threading

        class Sched:
            def __init__(self):
                self._lock = threading.Lock()
                self._thread = None   # guarded-by: _lock

            def stop(self):
                self._thread = None
    """)
    assert rule_ids(findings) == ["L001"]


def test_l001_clean_tuple_swap_then_join_outside():
    # The fixed scheduler.stop() shape: claim under the lock via tuple
    # swap, join the local handle after release.
    findings = lockcheck("""
        import threading

        class Sched:
            def __init__(self):
                self._lock = threading.Lock()
                self._thread = None   # guarded-by: _lock

            def stop(self):
                with self._lock:
                    thread, self._thread = self._thread, None
                if thread is not None:
                    thread.join()
    """)
    assert findings == []


# -- L002: lock-order contract ----------------------------------------------

_ORDERED_CLASS = """
    import threading

    # lock-order: _flush_lock -> _lock

    class Sched:
        def __init__(self):
            self._flush_lock = threading.Lock()
            self._lock = threading.Lock()

        def run(self):
            {body}
"""


def test_l002_fires_on_inverted_acquisition():
    findings = lockcheck(_ORDERED_CLASS.format(
        body="with self._lock:\n                "
             "with self._flush_lock:\n                    pass"))
    assert rule_ids(findings) == ["L002"]


def test_l002_clean_contract_order():
    findings = lockcheck(_ORDERED_CLASS.format(
        body="with self._flush_lock:\n                "
             "with self._lock:\n                    pass"))
    assert findings == []


# -- L003: annotation rot ---------------------------------------------------

def test_l003_fires_on_guard_naming_missing_lock():
    findings = lockcheck("""
        class Box:
            def __init__(self):
                self._items = []   # guarded-by: _lock
    """)
    assert rule_ids(findings) == ["L003"]


def test_l003_fires_on_lock_order_naming_missing_lock():
    findings = lockcheck("""
        import threading

        # lock-order: _flush_lock -> _lock

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
    """)
    assert rule_ids(findings) == ["L003"]


# -- the real serve tier ----------------------------------------------------

def test_scheduler_declares_and_passes_lock_contract():
    src = (REPO / "src/repro/serve/scheduler.py").read_text()
    assert "# lock-order: _flush_lock -> _lock" in src
    assert "# guarded-by: _lock" in src
    assert locks.check_source(src, "src/repro/serve/scheduler.py") == []


def test_scheduler_inverted_lock_order_is_flagged():
    """Regression for the documented acquisition order: flipping the
    real scheduler's nested acquisition must produce L002."""
    src = (REPO / "src/repro/serve/scheduler.py").read_text()
    inverted = textwrap.indent(textwrap.dedent("""
        def _inverted(self):
            with self._lock:
                with self._flush_lock:
                    return len(self._queue)
    """), "    ")
    findings = locks.check_source(src + inverted, "scheduler_inverted.py")
    assert [f.rule for f in findings] == ["L002"]
    assert "_flush_lock" in findings[0].message


def test_registry_passes_lock_contract():
    src = (REPO / "src/repro/serve/registry.py").read_text()
    assert "# guarded-by: _lock" in src
    assert locks.check_source(src, "src/repro/serve/registry.py") == []


# -- kernel memory contracts (C001/C002/C003) -------------------------------

def _kernel_names():
    import repro.kernels  # noqa: F401  -- populates the registry
    from repro.kernels.registry import registered_kernels
    return registered_kernels()


@pytest.mark.parametrize("name", _kernel_names())
def test_contract_matches_blockspecs_at_every_parity_shape(name):
    from repro.analysis.contracts import capture_case
    from repro.kernels.registry import get_contract, get_kernel

    entry = get_kernel(name)
    contract = get_contract(name)
    assert contract is not None, f"{name} has no memory contract (C003)"
    for case in entry.cases:
        reports = capture_case(entry, case)
        assert reports, f"{name} {case}: no pallas_call captured"
        derived = float(sum(r.hbm_bytes for r in reports))
        declared = float(contract.declared(case)["hbm_bytes"])
        assert abs(derived - declared) <= 0.5, (
            f"{name} {case}: declared {declared:.0f} B, "
            f"BlockSpecs imply {derived:.0f} B")
        for rep in reports:
            assert rep.vmem_bytes <= contract.vmem_budget


def _shrunk_registry(monkeypatch, name, contract):
    """Restrict the registry to one kernel with the given contract."""
    import repro.kernels  # noqa: F401
    from repro.kernels import registry

    entry = registry.get_kernel(name)
    monkeypatch.setattr(registry, "_REGISTRY", {name: entry})
    monkeypatch.setattr(
        registry, "_CONTRACTS", {} if contract is None
        else {name: contract})
    return entry


def test_c001_fires_on_seeded_divergent_contract(monkeypatch):
    from repro.analysis.contracts import verify_contracts
    from repro.kernels.registry import KernelContract

    _shrunk_registry(monkeypatch, "gram_stripe", KernelContract(
        name="gram_stripe", declared=lambda case: {"hbm_bytes": 1.0}))
    findings = verify_contracts()
    assert findings and all(f.rule == "C001" for f in findings)


def test_c002_fires_on_seeded_tiny_vmem_budget(monkeypatch):
    from repro.analysis.contracts import verify_contracts
    from repro.kernels.registry import KernelContract, get_contract

    good = get_contract("gram_stripe")
    _shrunk_registry(monkeypatch, "gram_stripe", KernelContract(
        name="gram_stripe", declared=good.declared, vmem_budget=1))
    findings = verify_contracts()
    assert findings and all(f.rule == "C002" for f in findings)


def test_c003_fires_on_missing_contract(monkeypatch):
    from repro.analysis.contracts import verify_contracts

    _shrunk_registry(monkeypatch, "gram_stripe", None)
    findings = verify_contracts()
    assert [f.rule for f in findings] == ["C003"]


# -- baseline semantics -----------------------------------------------------

def test_baseline_missing_file_means_no_suppressions(tmp_path):
    assert load_baseline(tmp_path / "nope.toml") == []


def test_baseline_rejects_missing_reason(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[suppress]]\nrule = "J001"\npath = "x.py"\n')
    with pytest.raises(BaselineError):
        load_baseline(p)


def test_baseline_rejects_unknown_rule(tmp_path):
    p = tmp_path / "b.toml"
    p.write_text('[[suppress]]\nrule = "Z999"\npath = "x.py"\n'
                 'reason = "nope"\n')
    with pytest.raises(BaselineError):
        load_baseline(p)


def test_apply_baseline_partitions_and_reports_stale():
    f1 = Finding("J001", "a.py", 3, "f", "reused")
    f2 = Finding("J001", "b.py", 9, "g", "reused")
    sup_hit = Suppression("J001", "a.py", "f", "intentional shared draw")
    sup_stale = Suppression("L001", "c.py", "", "gone")
    active, suppressed, stale = apply_baseline([f1, f2],
                                               [sup_hit, sup_stale])
    assert active == [f2]
    assert suppressed == [f1]
    assert stale == [sup_stale]


def test_repo_baseline_parses():
    # The checked-in baseline must never rot into a parse error.
    load_baseline(REPO / "analysis_baseline.toml")


# -- runner / CLI gate ------------------------------------------------------

def _write_fixture(tmp_path, source):
    p = tmp_path / "seeded.py"
    p.write_text(textwrap.dedent(source))
    return p


def test_runner_exits_nonzero_on_seeded_violation(tmp_path):
    p = _write_fixture(tmp_path, """
        import jax

        def draw(key):
            a = jax.random.normal(key, (3,))
            return a + jax.random.normal(key, (3,))
    """)
    buf = io.StringIO()
    rc = runner.run([str(p)], baseline=str(tmp_path / "none.toml"),
                    contracts=False, out=buf)
    assert rc == 1
    assert "J001" in buf.getvalue()


def test_runner_exits_zero_on_clean_file(tmp_path):
    p = _write_fixture(tmp_path, """
        def f(x):
            return x + 1
    """)
    buf = io.StringIO()
    rc = runner.run([str(p)], baseline=str(tmp_path / "none.toml"),
                    contracts=False, out=buf)
    assert rc == 0


def test_runner_suppression_downgrades_to_zero(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    p = _write_fixture(tmp_path, """
        import jax

        def draw(key):
            a = jax.random.normal(key, (3,))
            return a + jax.random.normal(key, (3,))
    """)
    (tmp_path / "b.toml").write_text(
        '[[suppress]]\nrule = "J001"\npath = "seeded.py"\n'
        'symbol = "draw"\nreason = "fixture: same draw on purpose"\n')
    buf = io.StringIO()
    rc = runner.run([str(p)], baseline="b.toml", contracts=False, out=buf)
    assert rc == 0
    assert "suppressed" in buf.getvalue()


def test_runner_writes_github_step_summary(tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    p = _write_fixture(tmp_path, """
        import jax

        def draw(key):
            a = jax.random.normal(key, (3,))
            return a + jax.random.normal(key, (3,))
    """)
    rc = runner.run([str(p)], baseline=str(tmp_path / "none.toml"),
                    contracts=False, out=io.StringIO())
    assert rc == 1
    text = summary.read_text()
    assert "repro.analysis findings" in text and "ACTIVE" in text


def test_runner_rejects_missing_path(tmp_path):
    rc = runner.run([str(tmp_path / "ghost")], contracts=False,
                    out=io.StringIO())
    assert rc == 2


def test_list_rules_covers_catalogue(capsys):
    assert runner.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


def test_whole_repo_is_clean(monkeypatch):
    """The CI gate: `python -m repro.analysis src tests` must exit 0 —
    zero unsuppressed findings across the repo, kernel contracts
    included."""
    monkeypatch.chdir(REPO)
    buf = io.StringIO()
    rc = runner.run(["src", "tests"], contracts=True, out=buf)
    assert rc == 0, buf.getvalue()

"""Fault-tolerance control flow: heartbeats, stragglers, elastic re-mesh,
checkpoint/restart supervision (process-level simulation)."""
import jax.numpy as jnp
import pytest

from repro.distributed.fault import (HeartbeatMonitor, StragglerTracker,
                                     elastic_mesh, TrainSupervisor,
                                     HostFailure)
from repro.distributed.checkpoint import CheckpointManager


def test_heartbeat_detection():
    clock = [0.0]
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10,
                           clock=lambda: clock[0])
    clock[0] = 5.0
    mon.beat("h0")
    mon.beat("h1")
    clock[0] = 12.0
    assert mon.dead_hosts() == ["h2"]
    assert set(mon.healthy_hosts()) == {"h0", "h1"}


def test_straggler_tracker():
    tr = StragglerTracker(factor=2.0)
    for _ in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            tr.record(h, 1.0)
        tr.record("slow", 5.0)
    assert tr.stragglers() == ["slow"]
    assert tr.action("slow") == "skip-last-microbatch"
    assert tr.action("h0") == "none"


def test_elastic_mesh_shrinks_data_axis():
    # 64 hosts x 8 chips = 512 -> (32, 16); lose 10 hosts -> 432 chips ->
    # data = 27 -> largest pow2 = 16.
    assert elastic_mesh(64, 8, 16) == ((32, 16), ("data", "model"))
    assert elastic_mesh(54, 8, 16) == ((16, 16), ("data", "model"))
    with pytest.raises(RuntimeError):
        elastic_mesh(1, 8, 16)


def test_supervisor_restart_from_checkpoint(tmp_path):
    """Failure mid-run: supervisor restores latest checkpoint and finishes;
    total completed steps equal the target with no state corruption."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5,
                            async_saves=False)

    def state_like():
        return {"x": jnp.zeros(())}

    failures = {"armed": True}

    def step_fn(state, step):
        if step == 5 and failures["armed"]:
            failures["armed"] = False
            raise HostFailure("preempted", healthy_hosts=30)
        return {"x": state["x"] + 1.0}

    sup = TrainSupervisor(mgr, state_like, max_restarts=3)
    final, report = sup.run({"x": jnp.zeros(())}, step_fn, n_steps=8)
    assert report.restarts == 1
    assert report.completed_steps == 8
    assert float(final["x"]) == 8.0
    assert report.remesh_events[0][1] == (8, 16)   # 30x8=240 chips -> data 8


def test_supervisor_budget_exhausted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2,
                            async_saves=False)

    def step_fn(state, step):
        raise HostFailure("flapping")

    sup = TrainSupervisor(mgr, lambda: {"x": jnp.zeros(())}, max_restarts=2)
    mgr.maybe_save(1, {"x": jnp.zeros(())})
    with pytest.raises(RuntimeError):
        sup.run({"x": jnp.zeros(())}, step_fn, n_steps=3)

"""Runs the multi-device checks (8 fake host devices) in a subprocess —
jax locks device count at first init, so this cannot share the pytest
process."""
import pathlib
import subprocess
import sys



def test_distributed_checks():
    root = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run(
        [sys.executable, str(root / "tests" / "dist_checks.py")],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ALL DIST CHECKS PASSED" in r.stdout


def test_sharded_fit_distributed_checks():
    """2-device sharded one-pass fit: close to single-host, chunk- and
    resume-invariant bitwise on the mesh (tests/fit_dist_checks.py)."""
    root = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run(
        [sys.executable, str(root / "tests" / "fit_dist_checks.py")],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ALL FIT DIST CHECKS PASSED" in r.stdout

"""Property-based validation of Theorem 1 (hypothesis).

For random small PSD kernel matrices and rank-r truncations we check, by
brute-force optimal clustering:
    L(C_hat) - L(C_star) <= tr(E)       (best rank-r approximation)
    L(C_hat) - L(C_star) <= 2 ||E||_*   (any PSD approximation)
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - hypothesis is installed
    HAVE_HYPOTHESIS = False

from repro.core import (objective_from_labels, brute_force_optimal,
                        theorem1_bounds, best_rank_r)


def random_psd(rng, n, rank):
    A = rng.randn(n, rank)
    return (A @ A.T).astype(np.float32)


def _check(seed, n, k, r, rank):
    rng = np.random.RandomState(seed)
    K = random_psd(rng, n, rank)
    K_hat = np.asarray(best_rank_r(jnp.asarray(K), r))
    excess, bound_any, bound_best = theorem1_bounds(
        jnp.asarray(K), jnp.asarray(K_hat), k)
    tol = 1e-3 * max(1.0, abs(bound_best))
    assert excess <= bound_best + tol, (excess, bound_best)
    assert excess <= bound_any + tol, (excess, bound_any)
    assert excess >= -1e-3  # C_star is optimal under true K


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(4, 7),
           k=st.integers(2, 3), r=st.integers(1, 3), rank=st.integers(2, 5))
    def test_theorem1_best_rank_r_hypothesis(seed, n, k, r, rank):
        _check(seed, n, k, r, rank)
else:                        # pragma: no cover
    @pytest.mark.parametrize("seed", range(20))
    def test_theorem1_best_rank_r_sweep(seed):
        _check(seed, n=6, k=2, r=2, rank=4)


def test_theorem1_general_psd_approximation():
    """K_hat not the best rank-r (a Nystrom-flavoured one): only the
    2||E||_* bound is claimed; verify it."""
    rng = np.random.RandomState(0)
    for seed in range(10):
        rng = np.random.RandomState(seed)
        K = random_psd(rng, 6, 4)
        idx = rng.choice(6, 3, replace=False)
        C = K[:, idx]
        W = K[np.ix_(idx, idx)]
        K_hat = (C @ np.linalg.pinv(W) @ C.T).astype(np.float32)
        excess, bound_any, _ = theorem1_bounds(jnp.asarray(K),
                                               jnp.asarray(K_hat), 2)
        assert excess <= bound_any + 1e-3 * max(1.0, bound_any)


def test_objective_matches_definition():
    """L from labels == ||Phi - Phi C^T C||_F^2 computed explicitly, using a
    linear kernel where Phi = X."""
    rng = np.random.RandomState(1)
    X = rng.randn(3, 8).astype(np.float32)
    K = X.T @ X
    labels = np.array([0, 1, 0, 1, 1, 0, 1, 0], np.int32)
    got = float(objective_from_labels(jnp.asarray(K), jnp.asarray(labels), 2))
    # Explicit: sum_i ||x_i - mu_{c(i)}||^2
    want = 0.0
    for c in range(2):
        pts = X[:, labels == c]
        mu = pts.mean(axis=1, keepdims=True)
        want += float(((pts - mu) ** 2).sum())
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_brute_force_is_minimum():
    rng = np.random.RandomState(2)
    K = random_psd(rng, 6, 3)
    labels, obj = brute_force_optimal(K, 2)
    # Any random labeling is no better.
    for seed in range(20):
        lab = np.random.RandomState(seed).randint(0, 2, 6)
        if len(set(lab)) < 2:
            continue
        other = float(objective_from_labels(jnp.asarray(K),
                                            jnp.asarray(lab, np.int32), 2))
        assert obj <= other + 1e-5

"""Mesh-sharded one-pass fit (repro.distributed.fit) vs single-host.

The engine's contract is BIT-identity on a 1-device mesh: fit and
partial_fit under `ComputePolicy(mesh=...)` must reproduce the canonical
SketchAccumulator path exactly — same W, same row norms, same eig, same
labels — for both one-pass backends, under ragged chunk schedules, and
when resuming from a published artifact. The multi-device variant of the
same checks runs via subprocess under XLA_FLAGS in test_distributed.py
(tests/fit_dist_checks.py).

Also here: the ComputePolicy legacy-kwarg shims (DeprecationWarning +
bit-identical behavior) and partial_fit's fail-fast chunk validation.
"""
import warnings

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.api import KernelKMeans
from repro.data import blob_ring
from repro.serve import ComputePolicy
from repro.serve.extend import Extender

N, BLOCK = 96, 32

_POLY = dict(k=2, r=2, kernel="polynomial",
             kernel_params={"gamma": 0.0, "degree": 2}, block=BLOCK)
BACKENDS = ["onepass-srht", "onepass-gaussian"]


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _assert_models_equal(a, b):
    """Every FittedModel leaf bit-identical (spec by equality)."""
    assert a.spec == b.spec
    for name, va in a._asdict().items():
        if name == "spec":
            continue
        vb = getattr(b, name)
        if va is None or vb is None:
            assert va is None and vb is None, name
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                          err_msg=name)


@pytest.fixture(scope="module")
def blobs():
    X, labels = blob_ring(jax.random.PRNGKey(0), n=N)
    return np.asarray(X, np.float32), labels


# ---------------------------------------------------------------------------
# bit-identity: sharded fit == single-host fit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_fit_bit_identical(blobs, backend):
    X, _ = blobs
    ref = KernelKMeans(backend=backend, **_POLY).fit(X, key=7)
    sh = KernelKMeans(backend=backend, **_POLY,
                      policy=ComputePolicy(mesh=_mesh1())).fit(X, key=7)
    _assert_models_equal(ref.model_, sh.model_)
    np.testing.assert_array_equal(np.asarray(ref.labels_),
                                  np.asarray(sh.labels_))
    np.testing.assert_array_equal(np.asarray(ref.embedding_),
                                  np.asarray(sh.embedding_))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_partial_fit_ragged_chunks(blobs, backend):
    """Chunked sharded ingest == one-shot single-host fit at the re-eig
    boundary, with chunk edges NOT aligned to the block size (the engine
    stages partial blocks exactly like the canonical accumulator)."""
    X, _ = blobs
    ref = KernelKMeans(backend=backend, **_POLY).fit(X, key=7)
    est = KernelKMeans(backend=backend, **_POLY,
                       policy=ComputePolicy(mesh=_mesh1()))
    edges = [0, 40, 73, N]           # ragged: 40, 33, 23 columns
    for lo, hi in zip(edges[:-1], edges[1:]):
        est.partial_fit(X[:, lo:hi], key=7, capacity=N,
                        reeig=(hi == N))
    _assert_models_equal(ref.model_, est.model_)
    np.testing.assert_array_equal(np.asarray(ref.labels_),
                                  np.asarray(est.labels_))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_resume_from_artifact(tmp_path, blobs, backend):
    """Publish mid-stream, resume under a mesh: identical to resuming
    single-host (the engine re-ingests the persisted columns)."""
    X, _ = blobs
    first, rest = X[:, :64], X[:, 64:]

    def start():
        est = KernelKMeans(backend=backend, **_POLY)
        est.partial_fit(first, key=7, capacity=N)
        return est

    path = str(tmp_path / f"art-{backend}")
    start().save(path)

    single = KernelKMeans.load(path)
    single.partial_fit(rest, key=7)
    sharded = KernelKMeans.load(path)
    sharded.policy = ComputePolicy(mesh=_mesh1())
    sharded.partial_fit(rest, key=7)
    _assert_models_equal(single.model_, sharded.model_)


# ---------------------------------------------------------------------------
# partial_fit fail-fast validation
# ---------------------------------------------------------------------------

def test_partial_fit_rejects_wrong_feature_dim(blobs):
    X, _ = blobs
    est = KernelKMeans(**_POLY)
    est.partial_fit(X[:, :BLOCK], key=0, capacity=N, reeig=False)
    with pytest.raises(ValueError, match="feature"):
        est.partial_fit(X[:1, BLOCK:2 * BLOCK], reeig=False)
    with pytest.raises(ValueError, match="2-D"):
        est.partial_fit(X[:, 0], reeig=False)


def test_partial_fit_rejects_policy_swap_mid_stream(blobs):
    X, _ = blobs
    est = KernelKMeans(**_POLY)
    est.partial_fit(X[:, :BLOCK], key=0, capacity=N, reeig=False)
    est.policy = ComputePolicy(mesh=_mesh1())
    with pytest.raises(ValueError, match="ComputePolicy"):
        est.partial_fit(X[:, BLOCK:2 * BLOCK], reeig=False)


def test_partial_fit_rejects_wrong_dim_against_loaded_model(tmp_path,
                                                           blobs):
    X, _ = blobs
    est = KernelKMeans(**_POLY)
    est.partial_fit(X[:, :64], key=0, capacity=N)
    path = str(tmp_path / "art")
    est.save(path)
    resumed = KernelKMeans.load(path)
    with pytest.raises(ValueError, match="feature"):
        resumed.partial_fit(X[:1, 64:], reeig=False)


# ---------------------------------------------------------------------------
# ComputePolicy legacy-kwarg shims
# ---------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_match_policy(blobs):
    X, _ = blobs
    est = KernelKMeans(**_POLY).fit(X, key=7)
    Xq = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 17)),
                    np.float32)
    with pytest.warns(DeprecationWarning, match="ComputePolicy"):
        legacy = Extender(est.model_, fused=True, interpret=True)
    policy = Extender(est.model_, policy=ComputePolicy(embed_fused=True,
                                                       interpret=True))
    np.testing.assert_array_equal(np.asarray(legacy.embed(Xq)),
                                  np.asarray(policy.embed(Xq)))


def test_legacy_kwargs_plus_policy_is_ambiguous(blobs):
    X, _ = blobs
    est = KernelKMeans(**_POLY).fit(X, key=7)
    with pytest.raises(ValueError, match="policy"):
        Extender(est.model_, fused=True, interpret=True,
                 policy=ComputePolicy())


def test_no_legacy_kwargs_no_warning(blobs):
    X, _ = blobs
    est = KernelKMeans(**_POLY).fit(X, key=7)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Extender(est.model_)
        Extender(est.model_, policy=ComputePolicy())


# ---------------------------------------------------------------------------
# fused fit path (fp tolerance, interpret mode)
# ---------------------------------------------------------------------------

def test_fit_fused_policy_close_to_canonical(blobs):
    X, _ = blobs
    ref = KernelKMeans(**_POLY).fit(X, key=7)
    fused = KernelKMeans(**_POLY, policy=ComputePolicy(
        fit_fused=True, interpret=True)).fit(X, key=7)
    np.testing.assert_allclose(np.asarray(ref.model_.stream_w),
                               np.asarray(fused.model_.stream_w),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(ref.eigvals_),
                               np.asarray(fused.eigvals_),
                               rtol=2e-3, atol=2e-3)


def test_fit_fused_requires_statics_through_accumulator():
    from repro.core.kernels_fn import make_kernel
    from repro.stream.accumulate import SketchAccumulator
    with pytest.raises(ValueError, match="kernel_statics"):
        SketchAccumulator(jax.random.PRNGKey(0),
                          make_kernel("polynomial", gamma=0.0, degree=2),
                          64, 2, policy=ComputePolicy(fit_fused=True,
                                                      interpret=True))

"""Sketched-gradient compression: algebra + convergence with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (sketch_params, compress,
                                           decompress, _flatten, _unflatten,
                                           make_sketched_grad_transform,
                                           compression_ratio)


def test_projection_is_orthogonal_pow2():
    """For power-of-two n (no padding) Omega's columns are exactly
    orthonormal: ĝ = Omega Omega^T g is idempotent and contractive."""
    n, rp = 256, 64
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    signs, rows = sketch_params(jax.random.PRNGKey(1), n, rp)
    s = compress(g, signs, rows)
    g_hat = decompress(s, signs, rows, n)
    np.testing.assert_allclose(np.asarray(compress(g_hat, signs, rows)),
                               np.asarray(s), rtol=1e-4, atol=1e-4)
    assert float(jnp.linalg.norm(g_hat)) <= float(jnp.linalg.norm(g)) + 1e-4


def test_padded_compression_contracts_in_expectation():
    """Non-pow2 n: truncation breaks exact idempotency, but the compressor
    still satisfies the EF-SGD contraction E||v - C(v)||^2 < ||v||^2."""
    n, rp = 300, 64
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    ratios = []
    for seed in range(20):
        signs, rows = sketch_params(jax.random.PRNGKey(seed), n, rp)
        g_hat = decompress(compress(g, signs, rows), signs, rows, n)
        ratios.append(float(jnp.linalg.norm(g - g_hat) /
                            jnp.linalg.norm(g)))
    assert np.mean(ratios) < 0.98, np.mean(ratios)


def test_flatten_roundtrip():
    tree = {"a": jnp.ones((3, 2)), "b": [jnp.zeros((5,)),
                                         jnp.full((2, 2), 2.0)]}
    vec, td, metas = _flatten(tree)
    back = _unflatten(vec, td, metas)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_error_feedback_accumulates_residual():
    params = {"w": jnp.zeros((64,))}
    transform, init_ef = make_sketched_grad_transform(params, r_prime=16)
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64,))}
    ef = init_ef()
    g1, ef1 = transform(g, ef, jax.random.PRNGKey(3))
    vec = g["w"]
    np.testing.assert_allclose(np.asarray(g1["w"] + ef1[:64]),
                               np.asarray(vec), rtol=1e-4, atol=1e-5)


def test_ef_sgd_converges_on_quadratic():
    """min ||Ax - b||^2 by sketched-gradient descent with EF reaches the
    same loss as exact GD (within 5%), at ~8x gradient compression."""
    n, d = 128, 96
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, d)) / np.sqrt(d)
    x_star = jax.random.normal(jax.random.PRNGKey(1), (d,))
    b = A @ x_star

    def loss(x):
        r = A @ x - b
        return 0.5 * jnp.sum(r * r)

    grad = jax.grad(loss)
    lr = 0.15
    # Exact GD.
    x = jnp.zeros((d,))
    for _ in range(400):
        x = x - lr * grad(x)
    exact_loss = float(loss(x))

    params = {"x": jnp.zeros((d,))}
    rp = 24                             # 4x gradient compression
    transform, init_ef = make_sketched_grad_transform(params, r_prime=rp)
    x = jnp.zeros((d,))
    ef = init_ef()
    for t in range(400):
        g = {"x": grad(x)}
        g_hat, ef = transform(g, ef, jax.random.PRNGKey(100 + t))
        x = x - lr * g_hat["x"]
    sketched_loss = float(loss(x))
    assert compression_ratio(params, rp) == pytest.approx(d / rp)
    assert sketched_loss < 2.0 * exact_loss + 1e-8, (sketched_loss,
                                                     exact_loss)
    assert sketched_loss < 1e-4 * float(loss(jnp.zeros((d,))))


# ---------------------------------------------------------------------------
# quantized-artifact codec (bf16 storage for serve/artifact.py)
# ---------------------------------------------------------------------------

def test_bf16_codec_roundtrip_is_exact_on_bf16_values():
    from repro.distributed.compression import bf16_decode, bf16_encode
    x = jax.random.normal(jax.random.PRNGKey(0), (37, 5)) * 100.0
    enc = bf16_encode(x)
    assert enc.dtype == jnp.uint16 and enc.shape == x.shape
    dec = bf16_decode(enc)
    assert dec.dtype == jnp.float32
    # decode(encode(x)) == the bf16 rounding of x, exactly.
    want = jnp.asarray(x, jnp.bfloat16).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(want))
    # And re-encoding is idempotent (bf16 values are fixed points).
    np.testing.assert_array_equal(np.asarray(bf16_encode(dec)),
                                  np.asarray(enc))


def test_quantize_state_skips_integer_leaves():
    from repro.distributed.compression import (dequantize_state,
                                               quantize_state)
    state = {"w": jnp.arange(6, dtype=jnp.float32) / 7.0,
             "idx": jnp.arange(4, dtype=jnp.int32)}
    enc, quantized = quantize_state(state)
    assert quantized == {"w": "bf16"}
    assert enc["w"].dtype == jnp.uint16
    assert enc["idx"].dtype == jnp.int32          # untouched
    dec = dequantize_state(enc, quantized)
    assert dec["w"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(dec["idx"]),
                                  np.asarray(state["idx"]))
    with pytest.raises(ValueError, match="unknown quantized dtype"):
        quantize_state(state, dtype="fp4")

"""repro.serve: out-of-sample consistency, batching exactness, artifacts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelKMeans
from repro.core.kernels_fn import polynomial_kernel, stripe_iterator
from repro.data import blob_ring
from repro.serve import (ModelRegistry, MicroBatcher, assign, bucket_size,
                         benchmark_assign, embed, load_model, save_model)

N, P, R, K, BLOCK = 250, 2, 2, 2, 64   # ragged: 250 = 3*64 + 58


@pytest.fixture(scope="module")
def model():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    return KernelKMeans(k=K, r=R, kernel="polynomial",
                        kernel_params={"gamma": 0.0, "degree": 2},
                        backend_params={"oversampling": 10},
                        block=BLOCK).fit(X, key=jax.random.PRNGKey(1)).model_


def test_train_points_reproduce_fitted_Y(model):
    """The extension identity: embed(X_train) == Y to ~1e-4 relative."""
    Y_ext = embed(model, model.X_train)
    rel = (float(jnp.linalg.norm(Y_ext - model.Y)) /
           float(jnp.linalg.norm(model.Y)))
    assert rel <= 1e-4, rel


def test_embedding_inner_products_match_kernel():
    """y(x)^T y(x') reproduces kappa(x, x') on held-out points when the fit
    rank covers the kernel's feature space (r=3 for homogeneous poly d=2,
    p=2)."""
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    m3 = KernelKMeans(k=K, r=3, kernel="polynomial",
                      kernel_params={"gamma": 0.0, "degree": 2},
                      backend_params={"oversampling": 10},
                      block=BLOCK).fit(X, key=jax.random.PRNGKey(1)).model_
    Xq = jax.random.normal(jax.random.PRNGKey(2), (P, 40)) * 1.5
    Yq = embed(m3, Xq)
    kern = polynomial_kernel(gamma=0.0, degree=2)
    Kq = np.asarray(kern(Xq, Xq))
    rel = (np.linalg.norm(np.asarray(Yq.T @ Yq) - Kq) /
           np.linalg.norm(Kq))
    assert rel < 1e-4, rel


def test_save_load_roundtrip(model, tmp_path):
    path = save_model(model, str(tmp_path / "artifact"))
    loaded = load_model(path)
    assert loaded.spec == model.spec
    for name in ("X_train", "U", "eigvals", "centroids", "sketch_signs",
                 "sketch_rows"):
        np.testing.assert_array_equal(np.asarray(getattr(loaded, name)),
                                      np.asarray(getattr(model, name)))
    assert loaded.sketch_omega is None
    Xq = jax.random.normal(jax.random.PRNGKey(3), (P, 33))
    np.testing.assert_array_equal(np.asarray(embed(loaded, Xq)),
                                  np.asarray(embed(model, Xq)))


def test_save_load_bf16_roundtrip(model, tmp_path):
    """dtype="bf16" halves the float payload (uint16 bit patterns via
    distributed/compression.py) and round-trips to float32 within bf16
    precision; assignments survive the quantization."""
    import pathlib

    f32_dir = save_model(model, str(tmp_path / "f32"))
    bf16_dir = save_model(model, str(tmp_path / "bf16"), dtype="bf16")

    def payload(d):
        return sum(p.stat().st_size
                   for p in (pathlib.Path(d) / "step_0").glob("leaf_*.npy"))

    # X_train/U/eigvals/centroids/sketch_signs halve; int leaves
    # (sketch_rows) don't — so strictly between 50% and 100%.
    assert payload(bf16_dir) < 0.6 * payload(f32_dir)

    loaded = load_model(bf16_dir)
    assert loaded.spec == model.spec
    for name in ("X_train", "U", "eigvals", "centroids"):
        got = np.asarray(getattr(loaded, name))
        want = np.asarray(getattr(model, name))
        assert got.dtype == np.float32
        # bf16 has an 8-bit mantissa: exact to ~3 decimal digits.
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-3)
    # Integer sketch rows must survive bit-exact (they index the FWHT).
    np.testing.assert_array_equal(np.asarray(loaded.sketch_rows),
                                  np.asarray(model.sketch_rows))

    Xq = jax.random.normal(jax.random.PRNGKey(7), (P, 128)) * 1.5
    lab_f32, _ = assign(model, Xq)
    lab_bf16, _ = assign(loaded, Xq)
    agree = float(np.mean(np.asarray(lab_f32) == np.asarray(lab_bf16)))
    assert agree >= 0.99, f"bf16 artifact changed {1 - agree:.1%} of labels"
    Y32 = embed(model, Xq)
    Y16 = embed(loaded, Xq)
    rel = (float(jnp.linalg.norm(Y16 - Y32)) /
           max(float(jnp.linalg.norm(Y32)), 1e-30))
    assert rel <= 2e-2, rel


def test_save_model_rejects_unknown_dtype(model, tmp_path):
    with pytest.raises(ValueError, match="unknown quantized dtype"):
        save_model(model, str(tmp_path / "x"), dtype="int3")


def test_save_load_gaussian_sketch(tmp_path):
    X, _ = blob_ring(jax.random.PRNGKey(4), n=128)
    m = KernelKMeans(k=2, r=2, backend="onepass-gaussian",
                     block=64).fit(X, key=jax.random.PRNGKey(5)).model_
    loaded = load_model(save_model(m, str(tmp_path / "g")))
    assert loaded.sketch_signs is None and loaded.sketch_rows is None
    np.testing.assert_array_equal(np.asarray(loaded.sketch_omega),
                                  np.asarray(m.sketch_omega))


def test_bucketed_equals_unbatched_exactly(model):
    for b in (5, 64, 300):   # < bucket, == bucket, ragged multi-stripe
        Xq = jax.random.normal(jax.random.PRNGKey(b), (P, b)) * 1.5
        labels_direct, d2_direct = assign(model, Xq)
        batcher = MicroBatcher(model, max_bucket=128)
        labels_bucket, d2_bucket = batcher.assign_batch(Xq)
        assert np.array_equal(np.asarray(labels_direct), labels_bucket)
        np.testing.assert_allclose(np.asarray(d2_direct), d2_bucket,
                                   rtol=1e-5, atol=1e-6)


def test_queue_drain_matches_unbatched(model):
    Xq = jax.random.normal(jax.random.PRNGKey(9), (P, 101)) * 1.5
    labels_direct, _ = assign(model, Xq)
    batcher = MicroBatcher(model, max_bucket=64)
    parts = np.split(np.asarray(Xq), [7, 40, 41, 90], axis=1)
    tickets = [batcher.submit(p) for p in parts]
    out = batcher.drain()
    assert len(out) == len(parts)
    got = np.concatenate([out[t][0] for t in tickets])
    assert np.array_equal(np.asarray(labels_direct), got)
    assert batcher.drain() == []     # queue empties


def test_bucketing_policy_bounds_executables(model):
    batcher = MicroBatcher(model, min_bucket=8, max_bucket=64)
    for b in (1, 3, 5, 7, 9, 17, 33, 60, 64, 100, 129):
        Xq = jax.random.normal(jax.random.PRNGKey(b), (P, b))
        labels, d2 = batcher.assign_batch(Xq)
        assert labels.shape == (b,) and d2.shape == (b,)
    # pow-2 buckets in [8, 64] only: at most 8,16,32,64 ever compiled.
    assert set(batcher.executables) <= {8, 16, 32, 64}


def test_fused_pallas_assign_matches_jnp(model):
    Xq = jax.random.normal(jax.random.PRNGKey(11), (P, 96)) * 1.5
    lab_jnp, d2_jnp = assign(model, Xq, fused=False)
    lab_pal, d2_pal = assign(model, Xq, fused=True)
    assert np.array_equal(np.asarray(lab_jnp), np.asarray(lab_pal))
    np.testing.assert_allclose(np.asarray(d2_jnp), np.asarray(d2_pal),
                               rtol=1e-4, atol=1e-5)


def test_zero_width_requests_rejected_cleanly(model):
    batcher = MicroBatcher(model)
    with pytest.raises(ValueError):
        batcher.submit(np.zeros((P, 0), np.float32))
    labels, d2 = batcher.assign_batch(np.zeros((P, 0), np.float32))
    assert labels.shape == (0,) and d2.shape == (0,)


def test_bucket_size():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(1000) == 1024
    assert bucket_size(5000, max_bucket=1024) == 1024
    with pytest.raises(ValueError):
        bucket_size(0)


def test_registry_multi_model(model, tmp_path):
    reg = ModelRegistry()
    reg.register("a", model)
    path = reg.save("a", str(tmp_path / "a"))
    reg.load("b", path)
    assert reg.names() == ["a", "b"]
    with pytest.raises(ValueError):
        reg.register("a", model)
    reg.register("a", model, overwrite=True)
    Xq = jax.random.normal(jax.random.PRNGKey(13), (P, 17))
    la, _ = reg.batcher("a").assign_batch(Xq)
    lb, _ = reg.batcher("b").assign_batch(Xq)
    assert np.array_equal(la, lb)
    with pytest.raises(KeyError):
        reg.get("missing")


def test_benchmark_assign_reports_throughput(model):
    bench = benchmark_assign(model, batch_sizes=(16, 32), repeats=2)
    assert [r["batch_size"] for r in bench["results"]] == [16, 32]
    for row in bench["results"]:
        assert row["assignments_per_sec"] > 0
    assert bench["backend"] == "cpu"


# ---------------------------------------------------------------------------
# stripe_iterator: tail path and out-of-sample (lhs=) stripes
# ---------------------------------------------------------------------------

def test_stripe_iterator_tail_matches_direct():
    kern = polynomial_kernel(gamma=0.0, degree=2)
    X = jax.random.normal(jax.random.PRNGKey(20), (3, 70))
    Kfull = np.asarray(kern(X, X))
    got = np.concatenate([np.asarray(s) for _, s in
                          stripe_iterator(kern, X, block=32)], axis=1)
    np.testing.assert_allclose(got, Kfull, rtol=1e-5, atol=1e-6)


def test_stripe_iterator_rectangular_lhs():
    kern = polynomial_kernel(gamma=0.0, degree=2)
    Xt = jax.random.normal(jax.random.PRNGKey(21), (3, 50))
    Xq = jax.random.normal(jax.random.PRNGKey(22), (3, 23))
    want = np.asarray(kern(Xt, Xq))
    got = np.concatenate([np.asarray(s) for _, s in
                          stripe_iterator(kern, Xq, block=16, lhs=Xt)],
                         axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # pad_tail=True keeps every stripe at full block width.
    widths = [s.shape[1] for _, s in
              stripe_iterator(kern, Xq, block=16, lhs=Xt, pad_tail=True)]
    assert widths == [16, 16]


def test_stripe_iterator_single_compiled_path():
    """The ragged tail must go through the one jitted gram_stripe: the
    kernel callable is traced exactly once across repeated passes."""
    traces = []

    def counting_kernel(X, Y):
        traces.append(1)
        return (X.T @ Y) ** 2

    X = jax.random.normal(jax.random.PRNGKey(23), (3, 70))  # 70 = 2*32 + 6
    for _ in range(3):
        for _start, _s in stripe_iterator(counting_kernel, X, block=32):
            pass
    assert len(traces) == 1, f"kernel traced {len(traces)}x; tail retracing"

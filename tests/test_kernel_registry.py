"""Registry-driven parity sweep: every registered kernel vs its oracle.

The per-kernel test files (test_kernels_*.py) pin each op's specific
edge cases; THIS file is the structural guarantee — it iterates
`repro.kernels.registry.kernel_entries()`, so a kernel package that
registers itself (as fit_sketch does) gets interpret-vs-oracle coverage
with zero test edits, and a package that forgets to register is caught
by the completeness check below.
"""
import jax
import numpy as np
import pytest

import repro.kernels  # noqa: F401  -- populates the registry
from repro.kernels.registry import (get_kernel, kernel_entries,
                                    registered_kernels)

pytestmark = pytest.mark.kernels    # CI kernel-parity job runs -m kernels


def _cases():
    for entry in kernel_entries():
        for i, case in enumerate(entry.cases):
            yield pytest.param(entry, i, id=f"{entry.name}-{i}")


@pytest.mark.parametrize("entry,i", _cases())
def test_registered_kernel_matches_oracle(entry, i):
    case = entry.cases[i]
    key = jax.random.PRNGKey(hash((entry.name, i)) % (2 ** 31))
    args, op_kwargs, ref_kwargs = entry.build(key, case)
    got = entry.op(*args, interpret=True, **op_kwargs)
    want = entry.ref(*args, **ref_kwargs)
    if entry.compare is not None:
        entry.compare(got, want, entry.rtol, entry.atol)
        return
    got_leaves = jax.tree_util.tree_leaves(got)
    want_leaves = jax.tree_util.tree_leaves(want)
    assert len(got_leaves) == len(want_leaves)
    for g, w in zip(got_leaves, want_leaves):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=entry.rtol, atol=entry.atol)


def test_every_kernel_package_registered():
    # One entry per Pallas package under src/repro/kernels/ — a new
    # package must register itself (see registry module docstring).
    assert set(registered_kernels()) >= {
        "fwht", "gram_stripe", "extend_embed", "kmeans_assign",
        "fit_sketch"}


def test_get_kernel_unknown_name():
    with pytest.raises(KeyError):
        get_kernel("no-such-kernel")

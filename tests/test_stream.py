"""Streaming subsystem (repro.stream): partial_fit/fit bit-parity at the
re-eig boundary, artifact resume, drift detection, minibatch K-means, the
int8 artifact codec, and the end-to-end drift -> refit -> publish -> swap
loop under async traffic. CI's stream-smoke job leans on the same pieces
via `serve_cluster --smoke --stream`."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelKMeans
from repro.core.kmeans import kmeans
from repro.core.metrics import clustering_accuracy
from repro.core.sketch import make_srht, srht_apply_t, srht_rows
from repro.data import blob_ring
from repro.distributed.compression import (dequantize_state, int8_decode,
                                           int8_encode, quantize_state)
from repro.serve import (MicroBatcher, ModelRegistry, VersionStore,
                         load_model, save_model)
from repro.stream import (DriftMonitor, RetrainWorker, SketchAccumulator,
                          minibatch_kmeans)

N, P, R, K, BLOCK = 250, 2, 2, 2, 64

_POLY = dict(k=K, r=R, kernel="polynomial",
             kernel_params={"gamma": 0.0, "degree": 2}, block=BLOCK)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


def _assert_models_equal(a, b):
    """Every FittedModel leaf bit-identical (spec by equality)."""
    assert a.spec == b.spec
    for name, va in a._asdict().items():
        if name == "spec":
            continue
        vb = getattr(b, name)
        if va is None or vb is None:
            assert va is None and vb is None, name
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                          err_msg=name)


def _blobs_1d(rng, xs, n_per, sigma=0.25):
    """1-d-separable 2-row blobs at the given x centers -> (X, labels)."""
    cols, labels = [], []
    for i, x0 in enumerate(xs):
        c = np.zeros((2, n_per), np.float32)
        c[0] = x0 + sigma * rng.standard_normal(n_per)
        c[1] = sigma * rng.standard_normal(n_per)
        cols.append(c)
        labels.append(np.full(n_per, i))
    return np.concatenate(cols, axis=1), np.concatenate(labels)


# ---------------------------------------------------------------------------
# partial_fit parity with one-shot fit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["onepass-srht", "onepass-gaussian"])
def test_partial_fit_bit_identical_to_fit(backend):
    """Chunked partial_fit over a full pass == fit at the re-eig boundary
    — bit-for-bit, including a ragged final chunk (N=250 is not a
    multiple of BLOCK=64, and the chunk edges are not block-aligned)."""
    X, _ = blob_ring(jax.random.PRNGKey(0), n=N)
    ref = KernelKMeans(backend=backend, **_POLY).fit(X, key=7)
    est = KernelKMeans(backend=backend, **_POLY)
    for lo, hi in [(0, 100), (100, 164), (164, N)]:
        est.partial_fit(X[:, lo:hi], key=7, capacity=N, reeig=(hi == N))
    _assert_models_equal(est.model_, ref.model_)
    np.testing.assert_array_equal(np.asarray(est.labels_),
                                  np.asarray(ref.labels_))
    assert est.inertia_ == ref.inertia_
    # The one-shot fit carries the same streaming slab (resumable too):
    # full blocks applied, the ragged tail staged, capacity recorded.
    assert ref.model_.stream_counts is not None
    np.testing.assert_array_equal(np.asarray(ref.model_.stream_counts),
                                  [(N // BLOCK) * BLOCK, N])


def test_partial_fit_chunking_invariant():
    """Two different chunkings of the same pass agree bit-for-bit."""
    X, _ = blob_ring(jax.random.PRNGKey(2), n=N)
    a = KernelKMeans(**_POLY)
    for lo, hi in [(0, 3), (3, 131), (131, N)]:
        a.partial_fit(X[:, lo:hi], key=11, capacity=N, reeig=(hi == N))
    b = KernelKMeans(**_POLY)
    b.partial_fit(X, key=11, capacity=N)
    _assert_models_equal(a.model_, b.model_)


def test_partial_fit_first_call_contract():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=64)
    with pytest.raises(ValueError, match="capacity"):
        KernelKMeans(**_POLY).partial_fit(X, key=0)
    with pytest.raises(ValueError, match="one-pass"):
        KernelKMeans(k=K, r=R, backend="nystrom",
                     backend_params={"m": 16}).partial_fit(
                         X, key=0, capacity=64)


def test_partial_fit_accumulates_without_reeig():
    X, _ = blob_ring(jax.random.PRNGKey(1), n=N)
    est = KernelKMeans(**_POLY)
    est.partial_fit(X[:, :100], key=4, capacity=N, reeig=False)
    assert est.model_ is None                      # cheap steady state
    prog = est.stream_progress
    assert prog["n_added"] == 100 and prog["capacity"] == N
    assert prog["n_applied"] == 64 and prog["n_pending"] == 36
    assert prog["reeigs"] == 0
    est.partial_fit(X[:, 100:], reeig=True)
    prog = est.stream_progress
    assert prog["n_added"] == N and prog["reeigs"] == 1
    assert 0.0 <= prog["approx_err_estimate"] <= 1.0
    assert est.model_ is not None and est.labels_.shape == (N,)


def test_accumulator_capacity_guard():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=64)
    est = KernelKMeans(**_POLY)
    est.partial_fit(X, key=0, capacity=64)
    with pytest.raises(ValueError, match="capacity"):
        est.partial_fit(X[:, :1])


# ---------------------------------------------------------------------------
# artifact round-trip and resume
# ---------------------------------------------------------------------------

def test_stream_state_roundtrips_and_resumes(tmp_path):
    """save -> load -> partial_fit continues bit-identically to the live
    estimator that never went through the artifact."""
    X, _ = blob_ring(jax.random.PRNGKey(3), n=N)
    live = KernelKMeans(**_POLY)
    live.partial_fit(X[:, :150], key=5, capacity=N)
    path = str(tmp_path / "ckpt")
    save_model(live.model_, path)
    meta = json.loads((pathlib.Path(path) / "leaves.json").read_text())
    for leaf in ("stream_w", "stream_row_norms2", "stream_counts"):
        assert leaf in meta["names"]

    resumed = KernelKMeans.load(path)
    live.partial_fit(X[:, 150:])
    resumed.partial_fit(X[:, 150:], key=5)
    _assert_models_equal(resumed.model_, live.model_)
    # And both equal the one-shot fit over all N columns.
    ref = KernelKMeans(**_POLY).fit(X, key=5)
    _assert_models_equal(resumed.model_, ref.model_)


def test_accumulator_from_model_requires_stream_state():
    X, _ = blob_ring(jax.random.PRNGKey(0), n=64)
    est = KernelKMeans(**_POLY).fit(X, key=0)
    stripped = est.model_._replace(stream_w=None, stream_row_norms2=None,
                                   stream_counts=None)
    with pytest.raises(ValueError, match="stream"):
        SketchAccumulator.from_model(stripped)


def test_srht_rows_matches_dense_apply():
    """Materialized Omega rows == the historical transform applied to the
    identity — the cross-term path reuses the exact same operator."""
    n = 37
    srht = make_srht(jax.random.PRNGKey(9), n, 16)
    dense = srht_apply_t(srht, jnp.eye(n, dtype=jnp.float32)).T  # (n, r')
    np.testing.assert_array_equal(np.asarray(srht_rows(srht, 0, n)),
                                  np.asarray(dense))
    np.testing.assert_array_equal(np.asarray(srht_rows(srht, 5, 21)),
                                  np.asarray(dense[5:21]))


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lin_est():
    rng = np.random.default_rng(0)
    X0, y0 = _blobs_1d(rng, (-2.0, 2.0), 100)
    est = KernelKMeans(k=2, r=2, kernel="linear", backend="onepass-srht",
                      block=BLOCK)
    est.partial_fit(X0, key=3, capacity=400)
    return est, X0, y0


def test_drift_monitor_quiet_on_reference_traffic(lin_est):
    est, X0, _ = lin_est
    mon = DriftMonitor(est.model_, ref_labels=est.labels_, min_queries=50)
    for lo in range(0, 200, 40):
        mon.observe(X0[:, lo:lo + 40])
    rep = mon.report()
    assert rep.queries == 200 and rep.samples == 200
    assert not rep.fired and rep.reason == "no drift"
    assert rep.chi2 < 10.0 and rep.max_frac_delta < 0.1


def test_drift_monitor_fires_on_assignment_shift(lin_est):
    est, X0, _ = lin_est
    mon = DriftMonitor(est.model_, ref_labels=est.labels_, min_queries=50)
    # All traffic served the same label: a total population collapse.
    for lo in range(0, 200, 40):
        mon.observe(X0[:, lo:lo + 40], labels=np.zeros(40, np.int32))
    rep = mon.report()
    assert rep.assign_fired and rep.fired
    assert "assignment shift" in rep.reason
    assert rep.chi2 > mon.chi2_threshold
    assert rep.live_fracs == [1.0, 0.0]
    # Below min_queries the same skew stays quiet.
    mon.reset_window()
    mon.observe(X0[:, :40], labels=np.zeros(40, np.int32))
    assert not mon.report().fired
    d = rep.to_dict()
    assert d["fired"] and isinstance(d["live_fracs"], list)


def test_drift_monitor_derives_ref_labels_and_samples_every(lin_est):
    est, X0, _ = lin_est
    mon = DriftMonitor(est.model_, min_queries=50, sample_every=2)
    assert abs(sum(mon.ref_fracs) - 1.0) < 1e-9
    np.testing.assert_allclose(mon.ref_fracs, [0.5, 0.5], atol=0.05)
    for lo in range(0, 160, 40):                  # 4 calls, 2 sampled
        mon.observe(X0[:, lo:lo + 40])
    rep = mon.report()
    assert rep.queries == 160 and rep.samples == 80


def test_drift_monitor_approx_error_trigger():
    """RBF model: on-support queries keep the kernel-column residual
    small; off-support queries land outside the rank-r eigenbasis and
    push p95 over the threshold."""
    rng = np.random.default_rng(1)
    X0, _ = _blobs_1d(rng, (-2.0, 2.0), 100, sigma=0.3)
    est = KernelKMeans(k=2, r=4, kernel="rbf", kernel_params={"gamma": 0.5},
                      backend="onepass-srht", block=BLOCK)
    est.fit(X0, key=2)
    mon = DriftMonitor(est.model_, ref_labels=est.labels_,
                       approx_err_threshold=0.5, min_queries=10 ** 9)
    Xq, _ = _blobs_1d(rng, (-2.0, 2.0), 64, sigma=0.3)
    mon.observe(Xq)
    quiet = mon.report()
    assert not quiet.fired and quiet.approx_err_p95 < 0.5
    mon.reset_window()
    Xfar = np.stack([rng.normal(0.0, 0.3, 64),
                     rng.normal(6.0, 0.3, 64)]).astype(np.float32)
    mon.observe(Xfar)
    rep = mon.report()
    assert rep.approx_fired and rep.fired and "approx-err" in rep.reason
    assert rep.approx_err_p95 > quiet.approx_err_p95


def test_sample_serving_stats_preserves_buckets(lin_est):
    est, X0, _ = lin_est
    mb = MicroBatcher(est.model_, min_bucket=8)
    mb.assign_batch(X0[:, :10])
    mon = DriftMonitor(est.model_, ref_labels=est.labels_)
    snap = mon.sample_serving_stats(mb)
    assert snap["queries"] == 10 and snap["bucket_hits"] == {16: 1}
    # Counters reset, but the executables view (what a warm hot-swap
    # replays) survives the sample.
    assert mb.stats["queries"] == 0 and mb.stats["bucket_hits"] == {16: 0}
    assert mb.executables == [16]
    mb.reset_stats()                              # full reset drops them
    assert mb.executables == []


# ---------------------------------------------------------------------------
# minibatch K-means
# ---------------------------------------------------------------------------

def test_minibatch_kmeans_tracks_full_quality():
    key = jax.random.PRNGKey(4)
    centers = jnp.array([[0.0, 0.0], [6.0, 6.0], [-6.0, 5.0]])
    idx = jax.random.randint(key, (600,), 0, 3)
    pts = centers[idx] + 0.4 * jax.random.normal(
        jax.random.PRNGKey(5), (600, 2))
    full = kmeans(jax.random.PRNGKey(6), pts, 3, n_restarts=5, max_iter=30)
    mb = minibatch_kmeans(jax.random.PRNGKey(6), pts, 3, 128, 80)
    assert mb.labels.shape == (600,) and mb.centroids.shape == (3, 2)
    assert int(mb.n_steps) == 80
    assert float(mb.objective) <= 1.5 * float(full.objective)
    # jit + explicit key: bit-deterministic across calls.
    mb2 = minibatch_kmeans(jax.random.PRNGKey(6), pts, 3, 128, 80)
    np.testing.assert_array_equal(np.asarray(mb.labels),
                                  np.asarray(mb2.labels))


def test_partial_fit_minibatch_mode():
    X, _ = blob_ring(jax.random.PRNGKey(7), n=N)
    est = KernelKMeans(**_POLY)
    est.partial_fit(X, key=8, capacity=N, kmeans_mode="minibatch",
                    minibatch_size=64, minibatch_steps=40)
    assert est.labels_.shape == (N,) and np.isfinite(est.inertia_)
    assert est.model_ is not None
    assert est.predict(X[:, :16]).shape == (16,)
    with pytest.raises(ValueError, match="kmeans_mode"):
        est.reeig_now(kmeans_mode="nope")


# ---------------------------------------------------------------------------
# int8 artifact codec
# ---------------------------------------------------------------------------

def test_int8_codec_roundtrip():
    x = jnp.asarray(np.linspace(-3.0, 5.0, 97, dtype=np.float32))
    q, scale = int8_encode(x)
    assert q.dtype == jnp.int8 and scale == pytest.approx(5.0 / 127.0)
    rt = int8_decode(q, scale)
    assert float(jnp.max(jnp.abs(rt - x))) <= scale / 2 + 1e-7
    qz, sz = int8_encode(jnp.zeros(5))            # all-zero leaf
    assert sz == 1.0 and not np.any(np.asarray(qz))

    state = {"w": x, "idx": jnp.arange(4, dtype=jnp.int32)}
    enc, quantized = quantize_state(state, dtype="int8")
    assert quantized["w"]["codec"] == "int8" and "idx" not in quantized
    assert enc["idx"].dtype == jnp.int32          # ints pass through
    dec = dequantize_state(enc, quantized)
    assert float(jnp.max(jnp.abs(dec["w"] - x))) <= scale / 2 + 1e-7
    # Legacy bare-string bf16 entries still decode.
    enc16, q16 = quantize_state({"w": x}, dtype="bf16")
    assert q16 == {"w": "bf16"}
    assert np.allclose(dequantize_state(enc16, q16)["w"], x, atol=0.05)
    with pytest.raises(ValueError, match="unknown quantized dtype"):
        quantize_state(state, dtype="fp4")


def test_int8_artifact_serves(tmp_path, lin_est):
    est, X0, y0 = lin_est
    path = save_model(est.model_, str(tmp_path / "int8"), dtype="int8")
    meta = json.loads((pathlib.Path(path) / "leaves.json").read_text())
    assert meta["quantized"]["U"]["codec"] == "int8"
    assert "stream_counts" not in meta["quantized"]
    m2 = load_model(path)
    assert m2.stream_counts.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(m2.stream_counts),
                                  np.asarray(est.model_.stream_counts))
    ref = est.predict(X0)
    got = KernelKMeans.from_model(m2).predict(X0)
    assert float(np.mean(ref == got)) >= 0.95


# ---------------------------------------------------------------------------
# end-to-end: drift -> refit -> publish -> swap under async traffic
# ---------------------------------------------------------------------------

def test_e2e_stream_drift_refit_swap(tmp_path):
    rng = np.random.default_rng(42)
    X0, _ = _blobs_1d(rng, (-2.0, 2.0), 100)      # initial distribution
    Xd, yd = _blobs_1d(rng, (3.0, 8.0), 100)      # drifted distribution

    est = KernelKMeans(k=2, r=2, kernel="linear", backend="onepass-srht",
                      block=BLOCK)
    est.partial_fit(X0, key=3, capacity=400)
    # The stale model collapses the drifted blobs onto one centroid.
    stale_acc = clustering_accuracy(yd, est.predict(Xd), 2)
    assert stale_acc <= 0.75

    store = VersionStore(str(tmp_path / "store"), keep=4)
    reg = ModelRegistry()
    reg.register("stream-demo", est.model_, version=store.publish(est.model_))
    clock = FakeClock()
    sched_kwargs = dict(max_wait_ms=5.0, clock=clock)
    sched = reg.scheduler("stream-demo", **sched_kwargs)
    mon = DriftMonitor(est.model_, ref_labels=est.labels_,
                       min_queries=50, chi2_threshold=30.0)

    def refit(report):
        assert report.fired
        est.partial_fit(Xd)                       # fold the drifted window
        return est.model_

    worker = RetrainWorker("stream-demo", reg, store, mon, refit)

    # Healthy traffic (shuffled, so each batch mixes both clusters): the
    # monitor observes the served labels, nothing fires.
    Xh = X0[:, rng.permutation(X0.shape[1])]
    healthy = [Xh[:, lo:lo + 20] for lo in range(0, 100, 20)]
    futs = [sched.submit(ch) for ch in healthy]
    sched.flush()
    for ch, f in zip(healthy, futs):
        mon.observe(ch, f.result(timeout=5)[0])
    assert worker.step() is None and worker.checks == 1

    # Drifted traffic through the same async front door.
    drifted = [Xd[:, lo:lo + 20] for lo in range(0, 200, 20)]
    futs = [sched.submit(ch) for ch in drifted]
    sched.flush()
    for ch, f in zip(drifted, futs):
        mon.observe(ch, f.result(timeout=5)[0])
    # One request still pending when the rollout begins: the swap must
    # drain it against the OLD model, never strand it.
    pending = sched.submit(Xd[:, :8])

    out = worker.step()
    assert out is not None and worker.retrains == 1
    assert out.version == 2 and out.drift.assign_fired
    assert out.swap.old_version == 1 and out.swap.new_version == 2
    assert out.swap.drained_requests == 1
    assert out.detect_to_swap_s >= 0.0
    assert pending.done() and pending.result()[0].shape == (8,)
    stranded = [f for f in futs + [pending] if not f.done()]
    assert stranded == []
    assert sched.stopped                          # old handle retired
    with pytest.raises(RuntimeError, match="stopped"):
        sched.submit(Xd[:, :4])

    # Window was rebound to the new model: no immediate re-fire.
    assert worker.step() is None

    # The registry now serves the refit version, warm.
    assert reg.version("stream-demo") == 2 and store.latest() == 2
    new_sched = reg.scheduler("stream-demo", **sched_kwargs)
    assert new_sched is not sched
    f = new_sched.submit(Xd[:, :16])
    new_sched.flush()
    assert f.result(timeout=5)[0].shape == (16,)
    new_acc = clustering_accuracy(yd, KernelKMeans.from_model(
        reg.get("stream-demo")).predict(Xd), 2)
    assert new_acc >= 0.95 and new_acc > stale_acc + 0.2
    d = out.to_dict()
    assert d["swap"]["drained_requests"] == 1 and d["drift"]["fired"]

"""Randomized sketching: SRHT test matrices and the one-pass eigendecomposition.

This is the computational heart of the paper (Alg. 1 lines 1-6):

    Omega = D H R            (n x r'), never materialized
    W     = K Omega          one streaming pass over column stripes of K
    Q     = r leading left singular vectors of W
    solve B (Q^T Omega) = Q^T W          <- the one-pass trick from [Halko et
                                            al. 2011, sec. 5.5]: no second
                                            pass over K to form Q^T K Q
    B     = V Sigma V^T  (eigh, PSD-projected)
    Y     = Sigma^{1/2} V^T Q^T  in R^{r x n}

`H` is the (normalized) Walsh-Hadamard transform, applied via FWHT in
O(n log n); on TPU the hot path is the Pallas kernel in
`repro.kernels.fwht` — this module's `fwht` is the pure-jnp oracle and the
CPU execution path. Cross-device FWHT lives in `repro.distributed.dfwht`.

Two call surfaces: `randomized_eig` returns the LowRankEig alone (Y, the
eigvals, and the orthonormal eigenvector basis U = Q V of K_hat);
`randomized_eig_with_state` additionally returns the sketch state (SRHT
signs/rows or the Gaussian Omega), which fully determines the fit given
(key, X) — repro.serve persists it inside the FittedModel artifact so a
deployment is reproducible from the artifact alone (ROADMAP "Serve
subsystem").
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn, stripe_iterator


# ---------------------------------------------------------------------------
# Walsh-Hadamard transform (pure-jnp reference / CPU path)
# ---------------------------------------------------------------------------

def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@functools.partial(jax.jit, static_argnums=(1,))
def fwht(x: jnp.ndarray, normalize: bool = True) -> jnp.ndarray:
    """Fast Walsh-Hadamard transform along axis 0. x: (n, ...), n = 2^m.

    Iterative radix-2 butterflies; `n` is static so the python loop unrolls
    into log2(n) fused stages under jit. normalize=True applies 1/sqrt(n) so
    H is orthonormal (scaling cancels in Alg. 1 but keeps conditioning sane).
    """
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"FWHT needs power-of-two length, got {n}")
    orig_shape = x.shape
    x = x.reshape(n, -1)
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, -1)
        a, b = x[:, 0], x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    x = x.reshape(orig_shape)
    if normalize:
        x = x / jnp.sqrt(jnp.asarray(n, x.dtype))
    return x


# ---------------------------------------------------------------------------
# SRHT: Omega = D H R, held implicitly
# ---------------------------------------------------------------------------

class SRHT(NamedTuple):
    """Implicit Omega = D H R in R^{n_pad x r'} restricted to the top n rows.

    signs: (n_pad,) +-1 diagonal of D
    rows:  (r',) row indices sampled uniformly WITHOUT replacement (R)
    n:     true (unpadded) dimension
    n_pad: power-of-two padded dimension
    """
    signs: jnp.ndarray
    rows: jnp.ndarray
    n: int
    n_pad: int

    @property
    def r_prime(self) -> int:
        return self.rows.shape[0]


def make_srht(key: jax.Array, n: int, r_prime: int) -> SRHT:
    n_pad = next_pow2(n)
    k1, k2 = jax.random.split(key)
    signs = jax.random.rademacher(k1, (n_pad,), dtype=jnp.float32)
    rows = jax.random.choice(k2, n_pad, (r_prime,), replace=False)
    return SRHT(signs=signs, rows=rows, n=n, n_pad=n_pad)


def srht_apply_t(srht: SRHT, M: jnp.ndarray,
                 fwht_fn: Optional[Callable] = None) -> jnp.ndarray:
    """Compute Omega^T M = R^T H (D M) for M of shape (n, b) -> (r', b).

    This is the ONLY way Omega touches data: scale rows by D, FWHT over the
    (zero-padded) row axis, gather the sampled rows. O(n_pad log n_pad * b).
    `fwht_fn` lets callers swap in the Pallas kernel or the distributed FWHT.
    """
    fwht_fn = fwht_fn or fwht
    n, b = M.shape
    if n != srht.n:
        raise ValueError(f"expected {srht.n} rows, got {n}")
    Mp = jnp.pad(M, ((0, srht.n_pad - n), (0, 0)))
    Mp = Mp * srht.signs[:, None]
    Mp = fwht_fn(Mp)
    return Mp[srht.rows]


def srht_apply(srht: SRHT, V: jnp.ndarray,
               fwht_fn: Optional[Callable] = None) -> jnp.ndarray:
    """Compute Omega V for V of shape (r', b) -> (n, b). (D H R V; H, D sym.)"""
    fwht_fn = fwht_fn or fwht
    scatter = jnp.zeros((srht.n_pad, V.shape[1]), V.dtype).at[srht.rows].set(V)
    out = fwht_fn(scatter)
    out = out * srht.signs[:, None]
    return out[:srht.n]


def srht_rows(srht: SRHT, start: int, stop: int) -> jnp.ndarray:
    """Materialize rows [start, stop) of the implicit Omega = D H R.

    Omega[i, c] = signs[i] * (-1)^popcount(i & rows[c]) / sqrt(n_pad) —
    the Sylvester/Hadamard entry formula, i.e. exactly the value
    srht_apply_t would produce from the one-hot e_i column. O(b * r')
    time and memory for a b-row slice, so the streaming accumulator
    (repro.stream.accumulate) can apply the symmetric cross-term
    K_block @ Omega[rows] without a full FWHT over dead rows.
    """
    if not (0 <= start <= stop <= srht.n):
        raise ValueError(f"row slice [{start}, {stop}) outside [0, {srht.n})")
    idx = jnp.arange(start, stop, dtype=jnp.int32)
    bits = jnp.bitwise_and(idx[:, None], srht.rows.astype(jnp.int32)[None, :])
    parity = jax.lax.population_count(bits) & 1
    scale = 1.0 / jnp.sqrt(jnp.asarray(srht.n_pad, jnp.float32))
    vals = jnp.where(parity == 1, -scale, scale)
    return srht.signs[start:stop, None] * vals


class GaussianSketch(NamedTuple):
    """Dense Gaussian Omega — the memory-hungry baseline Alg. 1 replaces."""
    omega: jnp.ndarray  # (n, r')


def make_gaussian(key: jax.Array, n: int, r_prime: int) -> GaussianSketch:
    return GaussianSketch(jax.random.normal(key, (n, r_prime)) /
                          jnp.sqrt(jnp.asarray(r_prime, jnp.float32)))


# ---------------------------------------------------------------------------
# One-pass randomized eigendecomposition (Alg. 1 lines 2-6)
# ---------------------------------------------------------------------------

class LowRankEig(NamedTuple):
    Y: jnp.ndarray        # (r, n) linearized samples: K_hat = Y^T Y
    Q: jnp.ndarray        # (n, r)
    eigvals: jnp.ndarray  # (r,) eigenvalues of B (>= 0)
    U: jnp.ndarray        # (n, r) orthonormal eigenvector basis Q V of K_hat


class SketchedEig(NamedTuple):
    """randomized_eig result WITH the sketch state Alg. 1 consumed.

    The sketch (SRHT signs/rows or the dense Gaussian Omega) fully
    determines the fit given (key, X); exposing it makes a fit
    reproducible and serializable — repro.serve persists it inside the
    FittedModel artifact.
    """
    eig: LowRankEig
    sketch: Tuple        # SRHT or GaussianSketch NamedTuple


def sketch_stream(kernel: KernelFn, X: jnp.ndarray, srht: SRHT,
                  block: int = 512,
                  fwht_fn: Optional[Callable] = None) -> jnp.ndarray:
    """W = K Omega in ONE streaming pass over column stripes of K.

    W^T = Omega^T K; stripe j of K contributes columns j of Omega^T K, i.e.
    rows j of W. Peak memory O(n * block + n * r') — K never materialized.
    """
    n = srht.n
    W = jnp.zeros((n, srht.r_prime), jnp.float32)
    for start, stripe in stripe_iterator(kernel, X, block):
        wt_block = srht_apply_t(srht, stripe, fwht_fn)   # (r', width)
        W = jax.lax.dynamic_update_slice(W, wt_block.T, (start, 0))
    return W


def one_pass_core(W: jnp.ndarray, omega_t_q_fn, r: int) -> LowRankEig:
    """Lines 3-6 of Alg. 1 given the sketch W = K Omega.

    omega_t_q_fn: callable Q -> Omega^T Q (n x r' -> r' x r'), so the core
    solve never revisits K and never materializes Omega.

    Note on Alg. 1 line 3: the paper writes "Q in R^{n x r}", but truncating
    the basis to r columns BEFORE the core solve throws away the
    oversampling benefit (the residual Q^T K (I - QQ^T) Omega pollutes the
    lstsq solve whenever the rank-r basis is inexact). Halko et al. (sec.
    5.5), which the paper cites for this step, keep the full r' = r + l
    columns of Q and truncate at the final eigendecomposition — that is what
    reproduces the paper's own Table 1 accuracy (err 0.40 == exact), so we
    follow Halko. The truncated variant is available for ablation via
    truncate_basis=True in randomized_eig.
    """
    # Line 3: orthonormal basis for range(W), r' columns (see note above).
    Q, _ = jnp.linalg.qr(W)                       # (n, r')
    # Line 4: solve B (Q^T Omega) = (Q^T W).
    QtO = omega_t_q_fn(Q).T                       # (r', r')
    QtW = Q.T @ W                                 # (r', r')
    # B QtO = QtW  =>  QtO^T B^T = QtW^T ; B symmetric in exact arithmetic.
    Bt, *_ = jnp.linalg.lstsq(QtO.T, QtW.T)
    B = 0.5 * (Bt + Bt.T)
    # Line 5: eigendecomposition, projected to PSD, truncated to rank r.
    evals, V = jnp.linalg.eigh(B)
    evals = jnp.maximum(evals[::-1], 0.0)         # descending, clipped
    V = V[:, ::-1]
    # Line 6: Y = Sigma^{1/2} V^T Q^T = Sigma^{1/2} U^T  in R^{r x n},
    # where U = Q V is the (orthonormal) eigenvector basis of
    # K_hat = U Sigma U^T — the out-of-sample extension operator
    # (repro.serve) is Sigma^{-1/2} U^T.
    U = Q @ V[:, :r]
    Y = jnp.sqrt(evals[:r])[:, None] * U.T
    return LowRankEig(Y=Y, Q=Q[:, :r], eigvals=evals[:r], U=U)


def randomized_eig_with_state(key: jax.Array, kernel: KernelFn,
                              X: jnp.ndarray, r: int,
                              oversampling: int = 10, block: int = 512,
                              sketch_type: str = "srht",
                              fwht_fn: Optional[Callable] = None,
                              truncate_basis: bool = False) -> SketchedEig:
    """randomized_eig that also returns the sketch state (SRHT / Gaussian).

    repro.serve persists the sketch in the fitted artifact so a
    deployment is reproducible from the artifact alone.
    """
    n = X.shape[1]
    r_prime = r + oversampling
    if sketch_type == "srht":
        sketch = make_srht(key, n, r_prime)
        W = sketch_stream(kernel, X, sketch, block, fwht_fn)

        def omega_t_q(Q):
            return srht_apply_t(sketch, Q, fwht_fn)
    elif sketch_type == "gaussian":
        sketch = make_gaussian(key, n, r_prime)
        W = jnp.zeros((n, r_prime), jnp.float32)
        for start, stripe in stripe_iterator(kernel, X, block):
            W = jax.lax.dynamic_update_slice(
                W, stripe.T @ sketch.omega, (start, 0))  # rows = stripe^T Om

        def omega_t_q(Q):
            return sketch.omega.T @ Q
    else:
        raise ValueError(f"unknown sketch_type {sketch_type!r}")
    if truncate_basis:
        # Literal Alg. 1 line 3: project the sketch onto its r leading left
        # singular vectors before the core solve (ablation; loses the
        # oversampling benefit — see one_pass_core docstring).
        U, S, Vt = jnp.linalg.svd(W, full_matrices=False)
        W = (U[:, :r] * S[None, :r]) @ Vt[:r]
    return SketchedEig(eig=one_pass_core(W, omega_t_q, r), sketch=sketch)


def randomized_eig(key: jax.Array, kernel: KernelFn, X: jnp.ndarray, r: int,
                   oversampling: int = 10, block: int = 512,
                   sketch_type: str = "srht",
                   fwht_fn: Optional[Callable] = None,
                   truncate_basis: bool = False) -> LowRankEig:
    """End-to-end one-pass randomized eigendecomposition of K = kappa(X, X).

    sketch_type: 'srht' (the paper's structured Omega = D H R) or 'gaussian'
    (the dense baseline whose memory/time cost motivates SRHT).
    truncate_basis: ablation flag — truncate Q to r columns BEFORE the core
    solve (Alg. 1 line 3 read literally; see one_pass_core docstring).
    """
    return randomized_eig_with_state(key, kernel, X, r, oversampling, block,
                                     sketch_type, fwht_fn,
                                     truncate_basis).eig

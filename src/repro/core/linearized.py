"""Linearized kernel K-means theory (Sec. 3): objective, Theorem 1 machinery.

L(C) = tr((I - C^T C) K (I - C^T C)) with C the normalized cluster-indicator
matrix (C C^T = I_K). Since P = C^T C is an orthogonal projection,
L(C) = tr(K) - tr(C K C^T), which is what we compute.

Includes a brute-force optimal-partition search (tiny n only) used by the
hypothesis-based property tests of Theorem 1:
    L(C_hat) - L(C_star) <= 2 ||E||_*          (any PSD K_hat = K - E)
    L(C_hat) - L(C_star) <= tr(E)              (K_hat = best rank-r approx)
"""
from __future__ import annotations

import itertools
from typing import Tuple

import jax.numpy as jnp
import numpy as np


def objective_from_labels(K: jnp.ndarray, labels: jnp.ndarray,
                          k: int) -> jnp.ndarray:
    """L(C) = tr(K) - sum_k (1/|S_k|) sum_{i,j in S_k} K_ij."""
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(K.dtype)
    counts = jnp.sum(onehot, axis=0)
    # C = diag(1/sqrt(counts)) @ onehot^T ; tr(C K C^T) = sum_k s_k / |S_k|
    per_cluster = jnp.einsum("ik,ij,jk->k", onehot, K, onehot)
    safe = jnp.where(counts > 0, per_cluster / jnp.maximum(counts, 1.0), 0.0)
    return jnp.trace(K) - jnp.sum(safe)


def brute_force_optimal(K: np.ndarray, k: int) -> Tuple[np.ndarray, float]:
    """Exact argmin over all surjective k-labelings. n <= ~10 only."""
    n = K.shape[0]
    best_labels, best_obj = None, np.inf
    for labels in itertools.product(range(k), repeat=n):
        if len(set(labels)) < k:   # every cluster non-empty (paper's C in C)
            continue
        obj = float(objective_from_labels(jnp.asarray(K),
                                          jnp.asarray(labels, jnp.int32), k))
        if obj < best_obj:
            best_obj, best_labels = obj, np.asarray(labels)
    return best_labels, best_obj


def trace_norm(E: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.linalg.svd(E, compute_uv=False))


def best_rank_r(K: jnp.ndarray, r: int) -> jnp.ndarray:
    """Best rank-r PSD approximation of PSD K (truncated eigendecomposition)."""
    evals, U = jnp.linalg.eigh(K)
    evals = jnp.maximum(evals[::-1], 0.0)
    U = U[:, ::-1]
    return (U[:, :r] * evals[:r][None, :]) @ U[:, :r].T


def theorem1_bounds(K: jnp.ndarray, K_hat: jnp.ndarray,
                    k: int) -> Tuple[float, float, float]:
    """Return (L(C_hat) - L(C_star), 2||E||_*, tr(E)) via brute force.

    Small-n validation of Theorem 1. C_hat optimizes under K_hat; its excess
    objective is evaluated under the TRUE K.
    """
    Kn = np.asarray(K)
    _, l_star = brute_force_optimal(Kn, k)
    labels_hat, _ = brute_force_optimal(np.asarray(K_hat), k)
    l_hat = float(objective_from_labels(jnp.asarray(Kn),
                                        jnp.asarray(labels_hat, jnp.int32), k))
    E = K - K_hat
    return l_hat - l_star, float(2.0 * trace_norm(E)), float(jnp.trace(E))

"""Clustering/approximation metrics used throughout the paper's experiments."""
from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np


def clustering_accuracy(labels_true, labels_pred, k: int) -> float:
    """Best-permutation matching accuracy (the paper's 'clustering accuracy').

    Exact Hungarian-equivalent: for k <= 8 we search permutations directly
    (7! = 5040 — trivial); beyond that we fall back to a greedy matching
    which is exact for near-diagonal confusion matrices.
    """
    lt = np.asarray(labels_true).ravel()
    lp = np.asarray(labels_pred).ravel()
    n = lt.shape[0]
    conf = np.zeros((k, k), dtype=np.int64)
    np.add.at(conf, (lp, lt), 1)
    if k <= 8:
        best = 0
        for perm in itertools.permutations(range(k)):
            hits = sum(conf[i, perm[i]] for i in range(k))
            best = max(best, hits)
        return best / n
    # Greedy fallback.
    conf = conf.copy()
    total = 0
    for _ in range(k):
        i, j = np.unravel_index(np.argmax(conf), conf.shape)
        total += conf[i, j]
        conf[i, :] = -1
        conf[:, j] = -1
    return total / n


def nmi(labels_true, labels_pred) -> float:
    """Normalized mutual information (arithmetic normalization)."""
    lt = np.asarray(labels_true).ravel()
    lp = np.asarray(labels_pred).ravel()
    n = lt.size
    ct = np.unique(lt, return_inverse=True)[1]
    cp = np.unique(lp, return_inverse=True)[1]
    kt, kp = ct.max() + 1, cp.max() + 1
    joint = np.zeros((kt, kp))
    np.add.at(joint, (ct, cp), 1.0)
    joint /= n
    pt = joint.sum(axis=1, keepdims=True)
    pp = joint.sum(axis=0, keepdims=True)
    nz = joint > 0
    mi = np.sum(joint[nz] * np.log(joint[nz] / (pt @ pp)[nz]))
    ht = -np.sum(pt[pt > 0] * np.log(pt[pt > 0]))
    hp = -np.sum(pp[pp > 0] * np.log(pp[pp > 0]))
    denom = 0.5 * (ht + hp)
    return float(mi / denom) if denom > 0 else 1.0


def kernel_approx_error(K: jnp.ndarray, Y: jnp.ndarray) -> float:
    """Normalized approximation error ||K - Y^T Y||_F / ||K||_F (paper Fig. 3a).

    Materializes K — validation-scale only (that is how the paper reports it
    too; the production pipeline never computes this).
    """
    K_hat = Y.T @ Y
    return float(jnp.linalg.norm(K - K_hat) / jnp.linalg.norm(K))


def kernel_approx_error_streaming(kernel, X, Y, block: int = 1024) -> float:
    """Same metric without materializing K: stream ||K - Y^T Y||_F^2 stripes."""
    from repro.core.kernels_fn import stripe_iterator
    num = 0.0
    den = 0.0
    for start, stripe in stripe_iterator(kernel, X, block):
        width = stripe.shape[1]
        approx = Y.T @ Y[:, start:start + width]
        num += float(jnp.sum((stripe - approx) ** 2))
        den += float(jnp.sum(stripe ** 2))
    return float(np.sqrt(num / den))

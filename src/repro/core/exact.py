"""Exact rank-r eigendecomposition baseline (eq. 5): the accuracy ceiling.

O(n^2) memory, O(n^3) time — only feasible for validation-scale n; the whole
point of the paper is avoiding this.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn, gram_matrix


class ExactEig(NamedTuple):
    Y: jnp.ndarray        # (r, n)
    eigvals: jnp.ndarray  # (r,) top-r eigenvalues, descending
    U: jnp.ndarray        # (n, r) orthonormal eigenvector basis: K_r = U S U^T


def exact_eig_from_gram(K: jnp.ndarray, r: int) -> ExactEig:
    K = 0.5 * (K + K.T)
    evals, U = jnp.linalg.eigh(K)
    evals = evals[::-1]
    U = U[:, ::-1]
    top = jnp.maximum(evals[:r], 0.0)
    Y = jnp.sqrt(top)[:, None] * U[:, :r].T
    return ExactEig(Y=Y, eigvals=top, U=U[:, :r])


def exact_eig(kernel: KernelFn, X: jnp.ndarray, r: int) -> ExactEig:
    return exact_eig_from_gram(gram_matrix(kernel, X), r)

"""Standard K-means (Lloyd) in pure JAX with k-means++ seeding.

The paper's Alg. 1 ends with "perform standard K-means on Y in R^r"; the
MATLAB reference used `kmeans(..., 'Replicates', 10)`. We provide the same
semantics: k-means++ init, Lloyd iterations under `lax.while_loop` with a
relative-tolerance stop, vmapped restarts, best-objective selection.

All shapes are static so every piece jit-compiles once and is reused across
restarts and benchmark trials.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    labels: jnp.ndarray      # (n,) int32
    centroids: jnp.ndarray   # (K, r)
    objective: jnp.ndarray   # () float32 — sum of squared distances
    n_iter: jnp.ndarray      # () int32


def _sq_dists(Y: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """(n, K) squared Euclidean distances. Y: (n, r), C: (K, r)."""
    yn = jnp.sum(Y * Y, axis=1)[:, None]
    cn = jnp.sum(C * C, axis=1)[None, :]
    return jnp.maximum(yn + cn - 2.0 * (Y @ C.T), 0.0)


def kmeans_plus_plus(key: jax.Array, Y: jnp.ndarray, k: int) -> jnp.ndarray:
    """k-means++ seeding [Arthur & Vassilvitskii 2007]. Y: (n, r) -> (k, r)."""
    n = Y.shape[0]
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n)
    centroids = jnp.zeros((k, Y.shape[1]), Y.dtype).at[0].set(Y[first])
    d2 = jnp.sum((Y - Y[first]) ** 2, axis=1)

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        # Sample proportional to current D^2 (guard the all-zero case).
        probs = jnp.where(jnp.sum(d2) > 0, d2 / jnp.sum(d2),
                          jnp.ones_like(d2) / n)
        idx = jax.random.choice(sub, n, p=probs)
        c = Y[idx]
        centroids = centroids.at[i].set(c)
        d2 = jnp.minimum(d2, jnp.sum((Y - c) ** 2, axis=1))
        return centroids, d2, key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, d2, key))
    return centroids


def _lloyd(Y: jnp.ndarray, init: jnp.ndarray, max_iter: int,
           tol: float) -> KMeansResult:
    k = init.shape[0]

    def assign(C):
        d2 = _sq_dists(Y, C)
        labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
        obj = jnp.sum(jnp.min(d2, axis=1))
        return labels, obj

    def update(C, labels):
        onehot = jax.nn.one_hot(labels, k, dtype=Y.dtype)       # (n, K)
        counts = jnp.sum(onehot, axis=0)                        # (K,)
        sums = onehot.T @ Y                                     # (K, r)
        # Empty clusters keep their previous centroid (MATLAB 'singleton'
        # semantics differ slightly; keeping the centroid is the standard
        # JAX-friendly choice and never increases the objective).
        return jnp.where(counts[:, None] > 0,
                         sums / jnp.maximum(counts[:, None], 1.0), C)

    def cond(state):
        _, _, prev_obj, obj, it = state
        rel = jnp.abs(prev_obj - obj) > tol * jnp.maximum(obj, 1e-30)
        return jnp.logical_and(it < max_iter, rel)

    def body(state):
        C, _, _, obj, it = state
        labels, _ = assign(C)
        C = update(C, labels)
        _, new_obj = assign(C)
        return C, labels, obj, new_obj, it + 1

    labels0, obj0 = assign(init)
    state = (init, labels0, jnp.inf, obj0, jnp.int32(0))
    C, labels, _, obj, it = jax.lax.while_loop(cond, body, state)
    labels, obj = assign(C)
    return KMeansResult(labels=labels, centroids=C, objective=obj, n_iter=it)


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5))
def kmeans(key: jax.Array, Y: jnp.ndarray, k: int, n_restarts: int = 10,
           max_iter: int = 20, tol: float = 1e-6) -> KMeansResult:
    """K-means with `n_restarts` k-means++ seeded Lloyd runs; best kept.

    Y: (n, r) data (rows = samples, matching the paper's Y^T usage).
    Defaults mirror the paper's experimental setup (10 inits, 20 iters).
    """

    def one(key):
        init = kmeans_plus_plus(key, Y, k)
        return _lloyd(Y, init, max_iter, tol)

    results = jax.vmap(one)(jax.random.split(key, n_restarts))
    best = jnp.argmin(results.objective)
    return KMeansResult(labels=results.labels[best],
                        centroids=results.centroids[best],
                        objective=results.objective[best],
                        n_iter=results.n_iter[best])

"""Algorithm 1: One-Pass Kernel K-means — the paper's end-to-end method.

A distinct preprocessing phase (one-pass randomized linearization of K)
followed by standard K-means on the transformed samples Y in R^r, exactly as
the paper advertises ("allows one to leverage existing algorithm libraries"):

    lines 1-6   K ~= U Sigma U^T  via the SRHT-sketched one-pass
                eigendecomposition (core/sketch.py::randomized_eig),
                yielding the linearization Y = Sigma^{1/2} U^T in R^{r x n}
    line 7      standard K-means on the columns of Y (core/kmeans.py)

so that  ||y_i - y_j||^2 = K̂_ii + K̂_jj - 2 K̂_ij  — Euclidean K-means on Y
is kernel K-means under the rank-r approximation. The equation -> function
map for every step lives in docs/ARCHITECTURE.md; the serving-time
consumer of the same linearization (the out-of-sample extension
y(x) = Sigma^{-1/2} U^T kappa(X_train, x)) is repro.serve.
"""
from __future__ import annotations

import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn
from repro.core.kmeans import KMeansResult, kmeans


class OnePassResult(NamedTuple):
    labels: jnp.ndarray
    Y: jnp.ndarray            # (r, n) linearized samples
    eigvals: jnp.ndarray      # (r,)
    kmeans: KMeansResult


def one_pass_kernel_kmeans(
    key: jax.Array,
    kernel: KernelFn,
    X: jnp.ndarray,                 # (p, n) data matrix
    k: int,                         # number of clusters
    r: int,                         # target rank
    oversampling: int = 10,         # l; r' = r + l
    block: int = 512,               # streaming stripe width
    n_restarts: int = 10,
    max_iter: int = 20,
    sketch_type: str = "srht",
    fwht_fn: Optional[Callable] = None,
) -> OnePassResult:
    """DEPRECATED shim for Alg. 1 — use `repro.api.KernelKMeans`.

    Delegates to the unified estimator API's one-pass backend (the exact
    same randomized_eig + K-means calls with the same key split, so
    results are bit-identical to the historical function). Kept so old
    call sites — including ones passing a raw kernel *callable*, which
    the spec-driven `KernelKMeans` does not accept — keep working.
    """
    warnings.warn(
        "one_pass_kernel_kmeans is deprecated; use repro.api.KernelKMeans("
        "k=..., r=..., backend='onepass-srht').fit(X, key) (or "
        "repro.api.get_backend(...) for a raw-callable kernel)",
        DeprecationWarning, stacklevel=2)
    from repro.api.backends import get_backend   # lazy: api builds on core
    k_sketch, k_km = jax.random.split(key)
    emb = get_backend(f"onepass-{sketch_type}").fit(
        k_sketch, kernel, X, r, block=block, oversampling=oversampling,
        fwht_fn=fwht_fn)
    km = kmeans(k_km, emb.Y.T, k, n_restarts=n_restarts, max_iter=max_iter)
    return OnePassResult(labels=km.labels, Y=emb.Y, eigvals=emb.eigvals,
                         kmeans=km)


def linearized_kmeans_from_Y(key: jax.Array, Y: jnp.ndarray, k: int,
                             n_restarts: int = 10,
                             max_iter: int = 20) -> KMeansResult:
    """Line 7 alone: K-means on any (r, n) linearization (exact / Nystrom)."""
    return kmeans(key, Y.T, k, n_restarts=n_restarts, max_iter=max_iter)

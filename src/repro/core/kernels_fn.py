"""Kernel functions and streaming block-gram construction.

The paper never materializes the full kernel matrix K: Alg. 1 consumes K in
column stripes built on-the-fly from the data matrix X (p x n). This module
provides the kernel registry and the stripe builders used by the streaming
sketch (core/sketch.py) and the distributed pipeline (distributed/).
"""
from __future__ import annotations

import functools
from typing import Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

KernelFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def polynomial_kernel(gamma: float = 0.0, degree: int = 2) -> KernelFn:
    """kappa(x, y) = (<x, y> + gamma)^degree. gamma=0 -> homogeneous."""

    def fn(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
        # X: (p, n1), Y: (p, n2) -> (n1, n2)
        z = X.T @ Y
        return (z + gamma) ** degree

    return fn


def rbf_kernel(gamma: float = 1.0) -> KernelFn:
    """kappa(x, y) = exp(-gamma * ||x - y||^2)."""

    def fn(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
        xn = jnp.sum(X * X, axis=0)[:, None]  # (n1, 1)
        yn = jnp.sum(Y * Y, axis=0)[None, :]  # (1, n2)
        z = X.T @ Y
        d2 = jnp.maximum(xn + yn - 2.0 * z, 0.0)
        return jnp.exp(-gamma * d2)

    return fn


def linear_kernel() -> KernelFn:
    return lambda X, Y: X.T @ Y


# name -> (factory, valid parameter names). The valid set is what
# make_kernel enforces: a typo like gamm= must raise, not be silently
# dropped (linear's old **kw swallowed anything) or die as an opaque
# TypeError inside the factory.
_REGISTRY = {
    "polynomial": (polynomial_kernel, frozenset({"gamma", "degree"})),
    "rbf": (rbf_kernel, frozenset({"gamma"})),
    "linear": (lambda: linear_kernel(), frozenset()),
}


def kernel_names() -> list:
    """Registered kernel names, sorted."""
    return sorted(_REGISTRY)


def kernel_params_for(name: str) -> frozenset:
    """Valid parameter names of a registered kernel."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {kernel_names()}")
    return _REGISTRY[name][1]


def make_kernel(name: str, **params) -> KernelFn:
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {kernel_names()}")
    factory, valid = _REGISTRY[name]
    unknown = set(params) - valid
    if unknown:
        accepted = (f"valid params: {sorted(valid)}" if valid
                    else "it takes no params")
        raise ValueError(f"unknown param(s) {sorted(unknown)} for kernel "
                         f"{name!r}; {accepted}")
    return factory(**params)


def gram_matrix(kernel: KernelFn, X: jnp.ndarray) -> jnp.ndarray:
    """Full n x n gram matrix — ONLY for small-n tests and exact baselines."""
    return kernel(X, X)


@functools.partial(jax.jit, static_argnums=(0, 4))
def gram_stripe(kernel: KernelFn, lhs: jnp.ndarray, X: jnp.ndarray,
                start: jnp.ndarray, block: int) -> jnp.ndarray:
    """Stripe kappa(lhs, X[:, start:start+block]) of the (rectangular) gram.

    jit-compiled once per (kernel, shapes, block) and reused across the
    streaming pass; `start` is a traced scalar so the loop does not
    recompile. Callers must pad X to a column multiple of `block`
    (stripe_iterator does) so the dynamic slice never clamps.
    """
    Xb = jax.lax.dynamic_slice_in_dim(X, start, block, axis=1)
    return kernel(lhs, Xb)


def stripe_iterator(kernel: KernelFn, X: jnp.ndarray, block: int,
                    lhs: Optional[jnp.ndarray] = None,
                    pad_tail: bool = False
                    ) -> Iterator[Tuple[int, jnp.ndarray]]:
    """Yield (start, kappa(lhs, X[:, start:start+width])) covering all n cols.

    lhs defaults to X (the paper's square gram stripes). Passing the
    training matrix as `lhs` with query columns in `X` yields the
    rectangular stripes of the out-of-sample extension path (repro.serve).

    Every stripe — including the ragged tail — goes through the ONE jitted
    `gram_stripe` executable: X is zero-padded to a column multiple of
    `block` up front and the tail stripe is sliced back to its true width.
    (Kernel values against padded zero columns land only in the sliced-off
    region; column j of kappa(lhs, X) depends only on column j of X.)
    With pad_tail=True the tail is yielded unsliced at full `block` width so
    downstream consumers can also keep a single compiled path; callers then
    slice using the yielded start and their own n.
    """
    n = X.shape[1]
    lhs = X if lhs is None else lhs
    n_pad = -(-n // block) * block
    Xp = X if n_pad == n else jnp.pad(X, ((0, 0), (0, n_pad - n)))
    for start in range(0, n, block):
        width = min(block, n - start)
        stripe = gram_stripe(kernel, lhs, Xp, jnp.asarray(start), block)
        yield start, (stripe if width == block or pad_tail
                      else stripe[:, :width])

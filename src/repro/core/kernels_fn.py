"""Kernel functions and streaming block-gram construction.

The paper never materializes the full kernel matrix K: Alg. 1 consumes K in
column stripes built on-the-fly from the data matrix X (p x n). This module
provides the kernel registry and the stripe builders used by the streaming
sketch (core/sketch.py) and the distributed pipeline (distributed/).
"""
from __future__ import annotations

import functools
from typing import Callable, Iterator, Tuple

import jax
import jax.numpy as jnp

KernelFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def polynomial_kernel(gamma: float = 0.0, degree: int = 2) -> KernelFn:
    """kappa(x, y) = (<x, y> + gamma)^degree. gamma=0 -> homogeneous."""

    def fn(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
        # X: (p, n1), Y: (p, n2) -> (n1, n2)
        z = X.T @ Y
        return (z + gamma) ** degree

    return fn


def rbf_kernel(gamma: float = 1.0) -> KernelFn:
    """kappa(x, y) = exp(-gamma * ||x - y||^2)."""

    def fn(X: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
        xn = jnp.sum(X * X, axis=0)[:, None]  # (n1, 1)
        yn = jnp.sum(Y * Y, axis=0)[None, :]  # (1, n2)
        z = X.T @ Y
        d2 = jnp.maximum(xn + yn - 2.0 * z, 0.0)
        return jnp.exp(-gamma * d2)

    return fn


def linear_kernel() -> KernelFn:
    return lambda X, Y: X.T @ Y


_REGISTRY = {
    "polynomial": polynomial_kernel,
    "rbf": rbf_kernel,
    "linear": lambda **kw: linear_kernel(),
}


def make_kernel(name: str, **params) -> KernelFn:
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**params)


def gram_matrix(kernel: KernelFn, X: jnp.ndarray) -> jnp.ndarray:
    """Full n x n gram matrix — ONLY for small-n tests and exact baselines."""
    return kernel(X, X)


@functools.partial(jax.jit, static_argnums=(0, 3))
def gram_stripe(kernel: KernelFn, X: jnp.ndarray, start: jnp.ndarray,
                block: int) -> jnp.ndarray:
    """Column stripe K[:, start:start+block] = kappa(X, X[:, start:start+block]).

    jit-compiled once per (kernel, block) and reused across the streaming
    pass; `start` is a traced scalar so the loop does not recompile.
    """
    Xb = jax.lax.dynamic_slice_in_dim(X, start, block, axis=1)
    return kernel(X, Xb)


def stripe_iterator(kernel: KernelFn, X: jnp.ndarray,
                    block: int) -> Iterator[Tuple[int, jnp.ndarray]]:
    """Yield (start, K[:, start:start+width]) stripes covering all n columns.

    The last stripe is truncated (not padded) so downstream accumulation
    indexes stay exact.
    """
    n = X.shape[1]
    for start in range(0, n, block):
        width = min(block, n - start)
        if width == block:
            yield start, gram_stripe(kernel, X, jnp.asarray(start), block)
        else:
            yield start, kernel(X, X[:, start:start + width])

"""Core of the paper: one-pass randomized kernel K-means (GlobalSIP 2016)."""
from repro.core.kernels_fn import (make_kernel, polynomial_kernel, rbf_kernel,
                                   gram_matrix, stripe_iterator)
from repro.core.kmeans import kmeans, kmeans_plus_plus, KMeansResult
from repro.core.sketch import (fwht, make_srht, srht_apply, srht_apply_t,
                               randomized_eig, randomized_eig_with_state,
                               one_pass_core, sketch_stream,
                               next_pow2, SRHT, LowRankEig, SketchedEig)
from repro.core.onepass import one_pass_kernel_kmeans, linearized_kmeans_from_Y
from repro.core.nystrom import nystrom, NystromResult
from repro.core.exact import exact_eig, exact_eig_from_gram, ExactEig
from repro.core.linearized import (objective_from_labels, brute_force_optimal,
                                   theorem1_bounds, best_rank_r, trace_norm)
from repro.core.metrics import (clustering_accuracy, nmi, kernel_approx_error,
                                kernel_approx_error_streaming)
__all__ = [
    "make_kernel", "polynomial_kernel", "rbf_kernel", "gram_matrix",
    "stripe_iterator",
    "kmeans", "kmeans_plus_plus", "KMeansResult",
    "fwht", "make_srht", "srht_apply", "srht_apply_t", "randomized_eig",
    "randomized_eig_with_state", "one_pass_core", "sketch_stream",
    "next_pow2", "SRHT", "LowRankEig", "SketchedEig",
    "one_pass_kernel_kmeans", "linearized_kmeans_from_Y",
    "nystrom", "NystromResult",
    "exact_eig", "exact_eig_from_gram", "ExactEig",
    "objective_from_labels", "brute_force_optimal", "theorem1_bounds",
    "best_rank_r", "trace_norm",
    "clustering_accuracy", "nmi", "kernel_approx_error",
    "kernel_approx_error_streaming",
]

"""Standard one-pass Nystrom approximation [Williams & Seeger 2001].

The paper's main baseline: sample m columns of K uniformly WITHOUT
replacement, K_hat = C W^+ C^T with C = K[:, idx] (n x m), W = K[idx, idx].
For the embedding comparison at fixed rank r we truncate K_hat to its best
rank-r part (both methods then feed r-dimensional samples to K-means).
Memory: O(nm) for C — the paper's point is that matching our accuracy needs
m >> r', hence ~10x the memory (Table 1, Fig. 3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn


class NystromResult(NamedTuple):
    Y: jnp.ndarray        # (r, n): K_hat_r = Y^T Y
    idx: jnp.ndarray      # (m,) sampled column indices
    eigvals: jnp.ndarray  # (r,) top eigenvalues of K_hat


def nystrom(key: jax.Array, kernel: KernelFn, X: jnp.ndarray, m: int, r: int,
            eps: float = 1e-8, optimal_truncation: bool = False
            ) -> NystromResult:
    """Classical rank-r Nystrom: Y = Lambda_r^{-1/2} U_r^T C^T with
    (Lambda_r, U_r) the top-r eigenpairs of W_m = K[idx, idx].

    optimal_truncation=True instead SVD-truncates the full rank-m Nystrom
    extension K_hat = C W_m^+ C^T to its best rank-r part (a strictly
    stronger variant we also benchmark; the paper's Table 1 numbers
    correspond to the classical form).
    """
    n = X.shape[1]
    idx = jax.random.choice(key, n, (m,), replace=False)
    Xs = X[:, idx]
    C = kernel(X, Xs)                 # (n, m) — one pass over m columns
    Wm = C[idx, :]                    # (m, m)
    Wm = 0.5 * (Wm + Wm.T)
    evals, U = jnp.linalg.eigh(Wm)
    evals = evals[::-1]
    U = U[:, ::-1]
    thresh = eps * jnp.maximum(jnp.max(jnp.abs(evals)), 1e-30)
    if optimal_truncation:
        inv_sqrt = jnp.where(evals > thresh,
                             1.0 / jnp.sqrt(jnp.maximum(evals, thresh)), 0.0)
        F = C @ (U * inv_sqrt[None, :])   # (n, m): K_hat = F F^T
        Uf, Sf, _ = jnp.linalg.svd(F, full_matrices=False)
        Y = Sf[:r, None] * Uf[:, :r].T    # (r, n)
        return NystromResult(Y=Y, idx=idx, eigvals=(Sf[:r] ** 2))
    inv_sqrt_r = jnp.where(evals[:r] > thresh,
                           1.0 / jnp.sqrt(jnp.maximum(evals[:r], thresh)), 0.0)
    Y = (inv_sqrt_r[:, None] * U[:, :r].T) @ C.T   # (r, n)
    return NystromResult(Y=Y, idx=idx, eigvals=evals[:r])

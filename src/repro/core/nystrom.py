"""Standard one-pass Nystrom approximation [Williams & Seeger 2001].

The paper's main baseline: sample m columns of K uniformly WITHOUT
replacement, K_hat = C W^+ C^T with C = K[:, idx] (n x m), W = K[idx, idx].
For the embedding comparison at fixed rank r we truncate K_hat to its best
rank-r part (both methods then feed r-dimensional samples to K-means).
Memory: O(nm) for C — the paper's point is that matching our accuracy needs
m >> r', hence ~10x the memory (Table 1, Fig. 3).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn


class NystromResult(NamedTuple):
    Y: jnp.ndarray        # (r, n): K_hat_r = Y^T Y
    idx: jnp.ndarray      # (m,) sampled column indices
    eigvals: jnp.ndarray  # (r,) top eigenvalues (of W_m, classical form)
    # (m, r) top-r eigenvectors of W_m = K[idx, idx] (classical form only;
    # None under optimal_truncation). Together with eigvals this is the
    # W^+ factor the out-of-sample extension needs: a new point embeds as
    # y(x) = Lambda_r^{-1/2} U_r^T kappa(X[:, idx], x) — the landmark-based
    # serving path of repro.serve/repro.api (O(m * block) per stripe
    # instead of O(n * block)).
    U: Optional[jnp.ndarray] = None


# Truncation floor of the classical path: matches the serving
# projection's epsilon (serve/extend._EIG_EPS), so fit and serve always
# make the SAME call on which eigen-directions are rank-deficient — in
# BOTH directions (the fit never inverts a direction serving would zero,
# and never zeroes one serving would invert; zeroed directions get an
# exactly-zero eigenvalue below).
_ABS_EIG_FLOOR = 1e-7


def nystrom(key: jax.Array, kernel: KernelFn, X: jnp.ndarray, m: int, r: int,
            eps: float = 1e-8, optimal_truncation: bool = False
            ) -> NystromResult:
    """Classical rank-r Nystrom: Y = Lambda_r^{-1/2} U_r^T C^T with
    (Lambda_r, U_r) the top-r eigenpairs of W_m = K[idx, idx].

    optimal_truncation=True instead SVD-truncates the full rank-m Nystrom
    extension K_hat = C W_m^+ C^T to its best rank-r part (a strictly
    stronger variant we also benchmark; the paper's Table 1 numbers
    correspond to the classical form).
    """
    n = X.shape[1]
    idx = jax.random.choice(key, n, (m,), replace=False)
    Xs = X[:, idx]
    C = kernel(X, Xs)                 # (n, m) — one pass over m columns
    Wm = C[idx, :]                    # (m, m)
    Wm = 0.5 * (Wm + Wm.T)
    evals, U = jnp.linalg.eigh(Wm)
    evals = evals[::-1]
    U = U[:, ::-1]
    thresh = jnp.maximum(eps * jnp.max(jnp.abs(evals)), _ABS_EIG_FLOOR)
    if optimal_truncation:
        inv_sqrt = jnp.where(evals > thresh,
                             1.0 / jnp.sqrt(jnp.maximum(evals, thresh)), 0.0)
        F = C @ (U * inv_sqrt[None, :])   # (n, m): K_hat = F F^T
        Uf, Sf, _ = jnp.linalg.svd(F, full_matrices=False)
        Y = Sf[:r, None] * Uf[:, :r].T    # (r, n)
        return NystromResult(Y=Y, idx=idx, eigvals=(Sf[:r] ** 2))
    inv_sqrt_r = jnp.where(evals[:r] > thresh,
                           1.0 / jnp.sqrt(jnp.maximum(evals[:r], thresh)), 0.0)
    Y = (inv_sqrt_r[:, None] * U[:, :r].T) @ C.T   # (r, n)
    # Zero the eigenvalues of directions the truncation refused to invert
    # (where inv_sqrt_r is 0, i.e. Y's row is 0), so downstream consumers
    # — the serving projection Sigma^{-1/2} U^T in repro.serve, which
    # zeroes eigvals below its own absolute epsilon — make the SAME rank
    # decision as this fit. Without this, a direction between the serving
    # epsilon and this relative threshold would be zeroed here but
    # inverted (with huge amplification) at serve time.
    evals_r = jnp.where(evals[:r] > thresh, evals[:r], 0.0)
    return NystromResult(Y=Y, idx=idx, eigvals=evals_r, U=U[:, :r])

"""jaxlint: AST lint for JAX tracing / RNG discipline (rules J001-J004).

Pure-AST, no imports of the linted code — the rules are heuristics tuned
to this repo's idioms, each documented in docs/ANALYSIS.md:

J001  PRNG key reuse. Within one function scope, a key variable (bound
      from jax.random.PRNGKey/split/fold_in, or a parameter named like a
      key) may be CONSUMED — passed as the key argument of any
      jax.random.* call, split included — at most once per binding.
      Reassignment (`key, sub = jax.random.split(key)`) starts a fresh
      binding; consuming a key inside a loop that was bound outside the
      loop fires too (every iteration would see the same stream). This
      is exactly the split-before-double-use discipline the fit/serve
      bit-identity contracts (PR 6/7) rely on.

J002  Host sync inside traced code. In a jit- or Pallas-traced scope,
      `.item()`, `.tolist()`, `np.asarray`/`np.array`, and
      `float()/int()/bool()` over tracer-typed values force a device
      sync (or fail outright under tracing) — each is a serving-path
      stall at best.

J003  Python branch on a tracer. `if`/`while`/`assert`/conditional
      expressions whose test involves a tracer-typed value raise a
      ConcretizationTypeError under jit. Shape-derived values
      (`x.shape`, `len(x)`, `.ndim`, `.dtype`) and static args are
      concrete and exempt, as are `x is None` identity checks.

J004  Mutable static jit args. A parameter listed in `static_argnames`
      that is annotated as a dict/list/set or a non-frozen dataclass
      defined in the same module hashes by identity (or not at all):
      every call constructs a new object and retraces. Frozen
      dataclasses (the `ComputePolicy` pattern) are the positive
      exemplar and pass.

Tracedness inference is deliberately simple: non-static parameters are
traced; an assignment whose right-hand side references a traced name is
traced, UNLESS every such reference sits under a shape-like accessor
(.shape/.ndim/.dtype/.size, len()). Module globals and closure values
are assumed concrete. One textual forward pass — good enough for the
kernel wrappers this repo writes, and every miss is baseline-able.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

# Attribute accesses that yield concrete (host) values even on tracers.
_CONCRETE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}

# jax.random.* members that PRODUCE keys when assigned from.
_KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in", "clone"}

# Parameter names seeded as key variables.
def _is_key_param(name: str) -> bool:
    return (name in ("key", "rng", "prng_key", "rng_key")
            or name.endswith("_key") or name.endswith("_rng"))


_MUTABLE_ANNOTATIONS = {"dict", "Dict", "defaultdict", "OrderedDict",
                        "list", "List", "set", "Set", "bytearray"}


class _ImportMap:
    """Resolve names/attribute chains to dotted module paths."""

    def __init__(self, tree: ast.Module):
        self.alias: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.alias[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    self.alias[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted path of a Name/Attribute chain, e.g. 'jax.random.normal'."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.alias.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))


def _terminates(stmts: List[ast.stmt]) -> bool:
    """True when a statement list unconditionally leaves the region."""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _normalize_random(dotted: Optional[str]) -> Optional[str]:
    """'jax.random.normal' -> 'normal'; None when not a jax.random call."""
    if dotted and dotted.startswith("jax.random."):
        return dotted[len("jax.random."):]
    return None


# -- traced-scope discovery -------------------------------------------------

def _decorator_jit_statics(dec: ast.expr, imports: _ImportMap
                           ) -> Optional[Tuple[Set[str], Set[int]]]:
    """If `dec` marks the function as jitted, its (static names, static
    positional indices) — the caller maps indices onto parameter names."""
    if imports.resolve(dec) == "jax.jit":
        return set(), set()
    if isinstance(dec, ast.Call):
        target = imports.resolve(dec.func)
        if target == "jax.jit":
            return _static_names(dec)
        if target == "functools.partial" and dec.args \
                and imports.resolve(dec.args[0]) == "jax.jit":
            return _static_names(dec)
    return None


def _static_names(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        values: List[ast.expr] = []
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            values = list(kw.value.elts)
        elif isinstance(kw.value, ast.Constant):
            values = [kw.value]
        if kw.arg == "static_argnames":
            names |= {e.value for e in values
                      if isinstance(e, ast.Constant)
                      and isinstance(e.value, str)}
        elif kw.arg == "static_argnums":
            nums |= {e.value for e in values
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, int)}
    return names, nums


def _pallas_kernel_names(tree: ast.Module, imports: _ImportMap) -> Set[str]:
    """Function names passed (possibly via functools.partial) as the first
    argument of a pallas_call anywhere in the module."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and imports.resolve(node.func)
                in ("jax.experimental.pallas.pallas_call",)):
            continue
        if not node.args:
            continue
        body = node.args[0]
        if isinstance(body, ast.Call) and imports.resolve(body.func) \
                == "functools.partial" and body.args:
            body = body.args[0]
        if isinstance(body, ast.Name):
            out.add(body.id)
    return out


# -- tracedness inference ---------------------------------------------------

class _Tracedness:
    """Forward-pass traced/concrete classification of local names."""

    def __init__(self, fn: ast.FunctionDef, statics: Set[str],
                 is_pallas_body: bool):
        args = fn.args
        params = [a.arg for a in args.posonlyargs + args.args
                  + args.kwonlyargs]
        if is_pallas_body:
            # Pallas kernel bodies: refs and positional operands are
            # traced; keyword-only params are bound via functools.partial
            # with host values (the repo's kernel idiom) — static.
            statics = statics | {a.arg for a in args.kwonlyargs}
        self.traced: Set[str] = {p for p in params if p not in statics}
        self._infer(fn)

    def _infer(self, fn: ast.FunctionDef) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                self._bind(node.targets, self.is_traced(node.value))
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                self._bind([node.target], self.is_traced(node.value))
            elif isinstance(node, ast.AugAssign):
                if self.is_traced(node.value):
                    self._bind([node.target], True)
            elif isinstance(node, ast.For):
                self._bind([node.target], self.is_traced(node.iter))

    def _bind(self, targets: List[ast.expr], traced: bool) -> None:
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                self._bind(list(t.elts), traced)
            elif isinstance(t, ast.Name):
                if traced:
                    self.traced.add(t.id)
                else:
                    self.traced.discard(t.id)

    def is_traced(self, node: ast.expr) -> bool:
        """True when evaluating `node` could yield a tracer value."""
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in _CONCRETE_ATTRS:
                return False
            return self.is_traced(node.value)
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id == "len":
                return False
            # A method call on a traced receiver (x.sum()) is traced even
            # with no arguments; shape-like accessors stay concrete via
            # the Attribute case above.
            return self.is_traced(node.func) or \
                any(self.is_traced(a) for a in node.args) or \
                any(self.is_traced(k.value) for k in node.keywords)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False                      # identity check, not value
            return self.is_traced(node.left) or \
                any(self.is_traced(c) for c in node.comparators)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_traced(node.left) or self.is_traced(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_traced(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_traced(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return any(self.is_traced(v)
                       for v in (node.test, node.body, node.orelse))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_traced(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.is_traced(node.value)
        return False                              # constants, lambdas, ...


# -- the lint pass ----------------------------------------------------------

class _Linter(ast.NodeVisitor):
    def __init__(self, tree: ast.Module, path: str):
        self.path = path
        self.imports = _ImportMap(tree)
        self.pallas_bodies = _pallas_kernel_names(tree, self.imports)
        self.findings: List[Finding] = []
        self.dataclass_frozen: Dict[str, bool] = self._dataclasses(tree)
        self._symbol: List[str] = []

    # dataclass registry (for J004): name -> frozen?
    def _dataclasses(self, tree: ast.Module) -> Dict[str, bool]:
        out: Dict[str, bool] = {}
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                target = self.imports.resolve(
                    dec.func if isinstance(dec, ast.Call) else dec)
                if target in ("dataclasses.dataclass", "dataclass"):
                    frozen = False
                    if isinstance(dec, ast.Call):
                        for kw in dec.keywords:
                            if kw.arg == "frozen" and isinstance(
                                    kw.value, ast.Constant):
                                frozen = bool(kw.value.value)
                    out[node.name] = frozen
        return out

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.path, line=getattr(node, "lineno", 0),
            symbol=".".join(self._symbol), message=message))

    # -- traversal -------------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._symbol.append(node.name)
        self.generic_visit(node)
        self._symbol.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._symbol.append(node.name)
        statics: Optional[Set[str]] = None
        for dec in node.decorator_list:
            s = _decorator_jit_statics(dec, self.imports)
            if s is not None:
                names, nums = s
                params = [a.arg for a in
                          node.args.posonlyargs + node.args.args]
                statics = names | {params[i] for i in nums
                                   if 0 <= i < len(params)}
        is_pallas = node.name in self.pallas_bodies
        if is_pallas and statics is None:
            statics = set()
        if statics is not None:
            self._check_traced_scope(node, statics, is_pallas)
            self._check_static_args(node, statics)
        self._check_key_reuse(node)
        self.generic_visit(node)
        self._symbol.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    # -- J002 / J003 ------------------------------------------------------

    def _check_traced_scope(self, fn: ast.FunctionDef, statics: Set[str],
                            is_pallas: bool) -> None:
        tr = _Tracedness(fn, statics, is_pallas)
        for node in ast.walk(fn):
            if isinstance(node, ast.FunctionDef) and node is not fn:
                continue                      # nested defs get their own scope
            if isinstance(node, ast.Call):
                self._check_host_sync(node, tr)
            if isinstance(node, (ast.If, ast.While)) and \
                    tr.is_traced(node.test):
                self._emit("J003", node,
                           "Python branch on a tracer-typed test inside a "
                           "traced scope (use jnp.where / lax.cond, or "
                           "mark the value static)")
            if isinstance(node, ast.IfExp) and tr.is_traced(node.test):
                self._emit("J003", node,
                           "conditional expression on a tracer-typed test "
                           "inside a traced scope")
            if isinstance(node, ast.Assert) and tr.is_traced(node.test):
                self._emit("J003", node,
                           "assert on a tracer-typed value inside a "
                           "traced scope")

    def _check_host_sync(self, node: ast.Call, tr: _Tracedness) -> None:
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("item", "tolist") and not node.args:
            self._emit("J002", node,
                       f".{node.func.attr}() inside a traced scope forces "
                       f"a host sync (move it outside jit)")
            return
        dotted = self.imports.resolve(node.func)
        if dotted in ("numpy.asarray", "numpy.array"):
            self._emit("J002", node,
                       f"{dotted}() inside a traced scope pulls the value "
                       f"to host (use jnp, or hoist out of jit)")
            return
        if isinstance(node.func, ast.Name) and \
                node.func.id in ("float", "int", "bool") and node.args \
                and any(tr.is_traced(a) for a in node.args):
            self._emit("J002", node,
                       f"{node.func.id}() over a tracer-typed value inside "
                       f"a traced scope (host sync / concretization)")

    # -- J004 -------------------------------------------------------------

    def _check_static_args(self, fn: ast.FunctionDef,
                           statics: Set[str]) -> None:
        args = fn.args
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            if a.arg not in statics or a.annotation is None:
                continue
            ann = a.annotation
            base = ann.value if isinstance(ann, ast.Subscript) else ann
            name = base.id if isinstance(base, ast.Name) else (
                base.attr if isinstance(base, ast.Attribute) else None)
            if name in _MUTABLE_ANNOTATIONS:
                self._emit("J004", a,
                           f"static jit arg {a.arg!r} is annotated "
                           f"{name} — unhashable/mutable statics retrace "
                           f"on every call (pass a frozen dataclass, cf. "
                           f"ComputePolicy)")
            elif name in self.dataclass_frozen and \
                    not self.dataclass_frozen[name]:
                self._emit("J004", a,
                           f"static jit arg {a.arg!r} is a non-frozen "
                           f"dataclass {name} — identity hashing "
                           f"recompiles per instance (declare "
                           f"frozen=True, cf. ComputePolicy)")

    # -- J001 -------------------------------------------------------------

    def _check_key_reuse(self, fn: ast.FunctionDef) -> None:
        # binding state: name -> (uses_since_binding, binding_loop_depth)
        state: Dict[str, Tuple[int, int]] = {}
        args = fn.args
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            if _is_key_param(a.arg):
                state[a.arg] = (0, 0)

        def key_arg_names(call: ast.Call) -> List[ast.Name]:
            """Names passed in the key slot of a jax.random.* call.

            fold_in is exempt: fold_in(key, step) DERIVES a fresh
            stream from (key, data) — the canonical per-iteration
            pattern — so the folded key is not consumed by it.
            """
            member = _normalize_random(self.imports.resolve(call.func))
            if member is None or member in ("key_data", "wrap_key_data",
                                            "fold_in"):
                return []
            cands: List[ast.expr] = []
            if call.args:
                cands.append(call.args[0])
            cands += [kw.value for kw in call.keywords if kw.arg == "key"]
            return [c for c in cands if isinstance(c, ast.Name)]

        def produces_key(value: ast.expr) -> bool:
            return isinstance(value, ast.Call) and _normalize_random(
                self.imports.resolve(value.func)) in _KEY_PRODUCERS

        def bind(target: ast.expr, depth: int) -> None:
            if isinstance(target, (ast.Tuple, ast.List)):
                for e in target.elts:
                    bind(e, depth)
            elif isinstance(target, ast.Name):
                state[target.id] = (0, depth)

        def unbind(target: ast.expr) -> None:
            if isinstance(target, (ast.Tuple, ast.List)):
                for e in target.elts:
                    unbind(e)
            elif isinstance(target, ast.Name):
                state.pop(target.id, None)

        def scan(node: ast.AST, depth: int) -> None:
            """Consumption pass over one expression/simple-statement tree."""
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Call):
                    continue
                for name in key_arg_names(sub):
                    if name.id not in state:
                        continue
                    uses, bound_at = state[name.id]
                    if uses >= 1:
                        self._emit(
                            "J001", sub,
                            f"PRNG key {name.id!r} consumed again without "
                            f"a fresh jax.random.split")
                    elif depth > bound_at:
                        self._emit(
                            "J001", sub,
                            f"PRNG key {name.id!r} bound outside the loop "
                            f"is consumed every iteration — split per "
                            f"iteration")
                    state[name.id] = (uses + 1, bound_at)

        def walk(stmts: List[ast.stmt], depth: int) -> None:
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                    continue                    # nested scopes lint themselves
                if isinstance(st, ast.For):
                    scan(st.iter, depth)
                    walk(st.body, depth + 1)
                    walk(st.orelse, depth + 1)
                elif isinstance(st, ast.While):
                    scan(st.test, depth + 1)    # re-evaluated per iteration
                    walk(st.body, depth + 1)
                    walk(st.orelse, depth + 1)
                elif isinstance(st, ast.If):
                    # The branches are exclusive at runtime: walk each
                    # from the same pre-If state, then continue with the
                    # per-key worst case of the branches that can fall
                    # through (a branch ending in return/raise/continue/
                    # break contributes nothing downstream). Double use
                    # split across `if`/`else` is NOT reuse.
                    scan(st.test, depth)
                    pre = dict(state)
                    walk(st.body, depth)
                    body_state = dict(state)
                    state.clear()
                    state.update(pre)
                    walk(st.orelse, depth)
                    orelse_state = dict(state)
                    survivors = [s for s, stmts in
                                 ((body_state, st.body),
                                  (orelse_state, st.orelse))
                                 if not _terminates(stmts)] or [pre]
                    state.clear()
                    for branch in survivors:
                        for name, (uses, bound_at) in branch.items():
                            if name in state:
                                pu, pd = state[name]
                                state[name] = (max(pu, uses),
                                               min(pd, bound_at))
                            else:
                                state[name] = (uses, bound_at)
                elif isinstance(st, ast.With):
                    for item in st.items:
                        scan(item.context_expr, depth)
                    walk(st.body, depth)
                elif isinstance(st, ast.Try):
                    walk(st.body, depth)
                    for h in st.handlers:
                        walk(h.body, depth)
                    walk(st.orelse, depth)
                    walk(st.finalbody, depth)
                else:
                    # Simple statement: consume first (the RHS evaluates
                    # before the bind), then apply (re)bindings.
                    scan(st, depth)
                    if isinstance(st, ast.Assign):
                        for t in st.targets:
                            if produces_key(st.value):
                                bind(t, depth)
                            else:
                                unbind(t)
                    elif isinstance(st, ast.AnnAssign) and \
                            st.value is not None:
                        if produces_key(st.value):
                            bind(st.target, depth)
                        else:
                            unbind(st.target)

        walk(fn.body, 0)

    # Module-level statements are visited by generic_visit; key reuse at
    # module scope is rare and intentionally unchecked.


def lint_source(source: str, path: str) -> List[Finding]:
    """Run jaxlint over one file's source; `path` only labels findings."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(rule="X001", path=path, line=exc.lineno or 0,
                        symbol="", message=f"file does not parse: {exc}")]
    linter = _Linter(tree, path)
    linter.visit(tree)
    return linter.findings


def lint_file(filename: str, repo_rel: str) -> List[Finding]:
    with open(filename, "r", encoding="utf-8") as fh:
        return lint_source(fh.read(), repo_rel)

"""CLI entry point: `python -m repro.analysis [paths]`.

Runs all three checker families (jaxlint + lock discipline over the
given paths, the kernel-contract verifier over the registry), applies
the repo-root `analysis_baseline.toml` suppressions, prints one
findings table, mirrors it into the GitHub step summary when running in
CI, and exits non-zero iff any ACTIVE (unsuppressed) finding remains.

Exit codes: 0 clean, 1 active findings, 2 the run itself is broken
(malformed baseline, nonexistent path).
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path
from typing import List, Sequence, TextIO

from repro.analysis import jaxlint, locks
from repro.analysis.baseline import BaselineError, apply_baseline, \
    load_baseline
from repro.analysis.findings import Finding, RULES, format_markdown, \
    format_table

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".ruff_cache",
              "build", "dist", ".eggs"}


def discover(paths: Sequence[str]) -> List[Path]:
    """All .py files under `paths` (files taken as-is), sorted, deduped."""
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_file():
            if path.suffix == ".py":
                out.append(path)
        elif path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if not any(part in _SKIP_DIRS or part.startswith(".")
                           for part in f.parts):
                    out.append(f)
        else:
            raise FileNotFoundError(p)
    seen = set()
    uniq = []
    for f in out:
        key = f.resolve()
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq


def _rel(path: Path) -> str:
    """Repo-relative posix path (what baseline entries match against)."""
    try:
        return path.resolve().relative_to(Path.cwd().resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def run(paths: Sequence[str], baseline: str = "analysis_baseline.toml",
        contracts: bool = True, out: TextIO = sys.stdout) -> int:
    try:
        suppressions = load_baseline(baseline)
    except BaselineError as exc:
        print(f"error: {exc}", file=out)
        return 2
    try:
        files = discover(paths)
    except FileNotFoundError as exc:
        print(f"error: no such path: {exc}", file=out)
        return 2

    findings: List[Finding] = []
    for f in files:
        rel = _rel(f)
        source = f.read_text(encoding="utf-8")
        findings += jaxlint.lint_source(source, rel)
        findings += locks.check_source(source, rel)
    if contracts:
        from repro.analysis.contracts import verify_contracts
        findings += verify_contracts()

    active, suppressed, stale = apply_baseline(findings, suppressions)

    print(f"repro.analysis: {len(files)} files, "
          f"{len(findings)} findings "
          f"({len(active)} active, {len(suppressed)} suppressed)",
          file=out)
    if active:
        print(format_table(active, title="ACTIVE findings:"), file=out)
    if suppressed:
        print(format_table(suppressed,
                           title=f"baseline-suppressed ({baseline}):"),
              file=out)
    for s in stale:
        print(f"warning: stale suppression matched nothing: "
              f"{s.rule} {s.path} {s.symbol or '(whole file)'} — "
              f"remove it from {baseline}", file=out)

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a", encoding="utf-8") as fh:
            fh.write(format_markdown(active, suppressed))

    return 1 if active else 0


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract checker: JAX tracing/RNG lint, "
                    "Pallas memory-contract verifier, lock discipline.")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint "
                             "(default: src)")
    parser.add_argument("--baseline", default="analysis_baseline.toml",
                        help="suppression file (default: "
                             "analysis_baseline.toml)")
    parser.add_argument("--skip-contracts", action="store_true",
                        help="skip the kernel-contract verifier "
                             "(pure-AST run, no jax import)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    ns = parser.parse_args(argv)
    if ns.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}  {RULES[rule]}")
        return 0
    return run(ns.paths or ["src"], baseline=ns.baseline,
               contracts=not ns.skip_contracts)

"""Kernel memory-contract verifier (rules C001-C003).

The kernel packages declare closed-form byte models (`memory_contract`
in each ops.py) that serve/bench.py reports as the paper's memory-
frugality numbers. Nothing about a closed form keeps it honest, so this
pass derives the SAME quantities from the kernels' actual BlockSpecs
and fails on divergence:

* Every registered package's `op` is invoked (through its own public
  wrapper, on zeros built by its own `build`) under a monkeypatched
  `pallas_call` that records grid / BlockSpecs / shapes instead of
  running the kernel.
* HBM traffic: for each operand, walk every grid point through the
  spec's index_map and count DISTINCT block coordinates — a
  constant-index (VMEM-resident) operand crosses HBM once, a moving
  operand once per distinct block — then multiply by block bytes.
* VMEM residency: sum of per-operand block bytes, double-buffered (x2)
  for moving operands, single for resident ones, checked against the
  contract's budget at every registered parity case.

Derivation is per parity case, so a drifted tile size, a forgotten
padding change, or a new output that bench.py's model missed all
surface as C001 the moment they land.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import math
import os
from typing import Callable, List, Tuple

from repro.analysis.findings import Finding

# Default per-core VMEM ceiling (TPU v4/v5 class, see the Pallas guide);
# packages can declare a tighter budget in their KernelContract.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

# Derivation walks every grid point; registered parity shapes are tiny
# (tens of steps), so a huge grid means a derivation bug, not a kernel.
_MAX_GRID_POINTS = 1 << 16


@dataclasses.dataclass(frozen=True)
class OperandReport:
    """Derived traffic for one pallas_call operand."""
    name: str                    # "in0" / "out1" ...
    block_shape: Tuple[int, ...]
    block_bytes: int
    distinct_blocks: int
    resident: bool               # constant index map -> revisited block

    @property
    def hbm_bytes(self) -> int:
        return self.distinct_blocks * self.block_bytes

    @property
    def vmem_bytes(self) -> int:
        # Moving blocks are double-buffered by the Pallas pipeline;
        # resident blocks occupy one buffer for the whole sweep.
        return self.block_bytes * (1 if self.resident else 2)


@dataclasses.dataclass(frozen=True)
class CallReport:
    """Derived totals for one captured pallas_call."""
    grid: Tuple[int, ...]
    operands: Tuple[OperandReport, ...]

    @property
    def hbm_bytes(self) -> int:
        return sum(op.hbm_bytes for op in self.operands)

    @property
    def vmem_bytes(self) -> int:
        return sum(op.vmem_bytes for op in self.operands)


@dataclasses.dataclass(frozen=True)
class _Capture:
    grid: tuple
    in_specs: tuple
    out_specs: tuple
    arg_shapes: tuple            # ((shape, itemsize), ...) matching in_specs
    out_shapes: tuple            # ((shape, itemsize), ...) matching out_specs


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    if isinstance(x, (tuple, list)):
        return tuple(x)
    return (x,)


def capture_pallas_calls(thunk: Callable[[], object]) -> List[_Capture]:
    """Run `thunk` with pallas_call swapped for a recorder.

    The recorder never executes the kernel body — it logs the call's
    grid/specs/shapes and returns zeros of out_shape, which is enough
    for the wrappers' pad/slice plumbing to trace through.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas

    caps: List[_Capture] = []
    real = pallas.pallas_call

    def fake(kernel, *, out_shape, grid=None, in_specs=None,
             out_specs=None, **unused_kw):
        outs = _as_tuple(out_shape)

        def runner(*args):
            caps.append(_Capture(
                grid=_as_tuple(grid),
                in_specs=_as_tuple(in_specs),
                out_specs=_as_tuple(out_specs),
                arg_shapes=tuple((tuple(a.shape), jnp.dtype(a.dtype).itemsize)
                                 for a in args),
                out_shapes=tuple((tuple(s.shape), jnp.dtype(s.dtype).itemsize)
                                 for s in outs),
            ))
            zeros = [jnp.zeros(s.shape, s.dtype) for s in outs]
            if isinstance(out_shape, (tuple, list)):
                return type(out_shape)(zeros)
            return zeros[0]

        return runner

    pallas.pallas_call = fake
    try:
        thunk()
    finally:
        pallas.pallas_call = real
    return caps


def derive_call(cap: _Capture) -> CallReport:
    """BlockSpec-derived HBM/VMEM totals for one captured call."""
    grid = tuple(int(g) for g in cap.grid)
    n_points = math.prod(grid) if grid else 1
    if n_points > _MAX_GRID_POINTS:
        raise ValueError(f"grid {grid} has {n_points} points; refusing "
                         f"to enumerate (derivation bug?)")
    points = list(itertools.product(*(range(g) for g in grid))) or [()]

    operands: List[OperandReport] = []

    def add(name: str, spec, itemsize: int) -> None:
        block = tuple(int(d) for d in spec.block_shape)
        coords = {_as_tuple(spec.index_map(*pt)) for pt in points}
        block_bytes = math.prod(block) * itemsize
        operands.append(OperandReport(
            name=name, block_shape=block, block_bytes=block_bytes,
            distinct_blocks=len(coords), resident=len(coords) == 1))

    for i, (spec, (_, itemsize)) in enumerate(
            zip(cap.in_specs, cap.arg_shapes)):
        add(f"in{i}", spec, itemsize)
    for i, (spec, (_, itemsize)) in enumerate(
            zip(cap.out_specs, cap.out_shapes)):
        add(f"out{i}", spec, itemsize)
    return CallReport(grid=grid, operands=tuple(operands))


def capture_case(entry, case: dict) -> List[CallReport]:
    """Capture + derive every pallas_call `entry.op` issues for `case`.

    The jit cache is cleared around the capture: before, so a previous
    real run of the same shapes cannot swallow the trace; after, so the
    recorder's zeros-executable cannot leak into later real runs.
    """
    import jax

    args, op_kwargs, _ = entry.build(jax.random.PRNGKey(0), case)
    kwargs = dict(op_kwargs, interpret=True)
    clear = getattr(entry.op, "clear_cache", None)
    if clear:
        clear()
    try:
        caps = capture_pallas_calls(lambda: entry.op(*args, **kwargs))
    finally:
        if clear:
            clear()
    return [derive_call(c) for c in caps]


def _anchor(obj) -> Tuple[str, int]:
    """(repo-relative path, line) for a callable, for finding anchors."""
    try:
        path = inspect.getsourcefile(obj) or "<unknown>"
        line = obj.__code__.co_firstlineno
    except (TypeError, AttributeError):
        return "<unknown>", 1
    path = path.replace(os.sep, "/")
    marker = "/src/repro/"
    idx = path.find(marker)
    if idx >= 0:
        path = "src/repro/" + path[idx + len(marker):]
    return path, line


def verify_contracts() -> List[Finding]:
    """Cross-check every registered kernel package at every parity case."""
    import repro.kernels  # noqa: F401  (imports populate the registry)
    from repro.kernels.registry import get_contract, kernel_entries

    findings: List[Finding] = []
    for entry in kernel_entries():
        contract = get_contract(entry.name)
        path, line = _anchor(entry.op)
        if contract is None:
            findings.append(Finding(
                rule="C003", path=path, line=line, symbol=entry.name,
                message=f"registered kernel {entry.name!r} declares no "
                        f"memory contract (register_contract missing)"))
            continue
        for case in entry.cases:
            reports = capture_case(entry, case)
            declared = float(contract.declared(case)["hbm_bytes"])
            derived = float(sum(r.hbm_bytes for r in reports))
            if not reports:
                findings.append(Finding(
                    rule="C001", path=path, line=line, symbol=entry.name,
                    message=f"case {case}: op issued no pallas_call to "
                            f"derive a contract from"))
                continue
            if abs(derived - declared) > 0.5:
                findings.append(Finding(
                    rule="C001", path=path, line=line, symbol=entry.name,
                    message=f"case {case}: declared {declared:.0f} B but "
                            f"BlockSpecs imply {derived:.0f} B of HBM "
                            f"traffic"))
            for i, rep in enumerate(reports):
                if rep.vmem_bytes > contract.vmem_budget:
                    findings.append(Finding(
                        rule="C002", path=path, line=line,
                        symbol=entry.name,
                        message=f"case {case}: pallas_call #{i} holds "
                                f"{rep.vmem_bytes} B resident in VMEM "
                                f"(budget {contract.vmem_budget} B)"))
    return findings

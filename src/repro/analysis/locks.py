"""Lock-discipline pass for the serve tier (rules L001-L003).

Two comment contracts drive this checker, both machine-read from the
source so the documentation and the enforcement can never drift apart:

guarded-by   on the line initialising an instance field::

                 self._queue: List[_Pending] = []   # guarded-by: _lock

             Every MUTATION of `self._queue` — assignment (tuple targets
             included), augmented assignment, `del`, subscript stores,
             and calls of mutating methods (append/pop/clear/...) — must
             sit lexically inside `with self._lock:` in the same class.
             Reads are deliberately unchecked: the serve tier's
             single-writer read paths (stats snapshots, `names()`) are
             part of its design. `__init__` is exempt — construction
             precedes sharing.

lock-order   a module-level comment::

                 # lock-order: _flush_lock -> _lock

             declaring the only permitted nesting order for the named
             pair. Any `with self.B:` lexically nested inside
             `with self.A:` where the contract says B must come first is
             an inversion (L002) — the classic ABBA deadlock shape.

L003 flags contract rot itself: a guarded-by/lock-order annotation
naming a lock attribute the class never assigns.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

_GUARDED_BY = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_LOCK_ORDER = re.compile(r"#\s*lock-order:\s*([A-Za-z_]\w*)\s*->\s*"
                         r"([A-Za-z_]\w*)")

# Method names that mutate their receiver in place.
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "popitem",
             "clear", "update", "setdefault", "add", "discard",
             "appendleft", "popleft", "sort", "reverse"}


def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' for `self.x`, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _with_locks(item: ast.withitem) -> Optional[str]:
    """Lock attr name for `with self.<lock>:` items."""
    return _self_attr(item.context_expr)


class _ClassPass:
    """Check one class body against its guarded-by / lock-order contracts."""

    def __init__(self, cls: ast.ClassDef, path: str, lines: List[str],
                 order: List[Tuple[str, int]], findings: List[Finding]):
        self.cls = cls
        self.path = path
        self.lines = lines
        self.findings = findings
        self.guards: Dict[str, Tuple[str, int]] = {}   # field -> (lock, line)
        self.lock_fields: Set[str] = set()
        self.order = order        # [(lock, rank)] from the module contract
        self._collect()

    def _emit(self, rule: str, line: int, symbol: str, msg: str) -> None:
        self.findings.append(Finding(rule=rule, path=self.path, line=line,
                                     symbol=symbol, message=msg))

    def _collect(self) -> None:
        """Find guarded-by annotations + lock fields across the class."""
        for node in ast.walk(self.cls):
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                targets = [node.target]
            else:
                continue
            for t in targets:
                attr = _self_attr(t)
                if attr is None:
                    continue
                if isinstance(node.value, ast.Call):
                    dotted = ast.unparse(node.value.func)
                    if dotted.endswith(("Lock", "RLock", "Condition",
                                        "Semaphore")):
                        self.lock_fields.add(attr)
                src_line = self.lines[node.lineno - 1] \
                    if node.lineno - 1 < len(self.lines) else ""
                m = _GUARDED_BY.search(src_line)
                if m:
                    self.guards[attr] = (m.group(1), node.lineno)

    def run(self) -> None:
        cls_name = self.cls.name
        for lock, line in self.guards.values():
            if lock not in self.lock_fields:
                self._emit("L003", line, cls_name,
                           f"guarded-by names {lock!r} but {cls_name} "
                           f"never assigns self.{lock} to a lock")
        for m in self.cls.body:
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_method(m, cls_name)

    # -- per-method walk --------------------------------------------------

    def _check_method(self, fn: ast.FunctionDef, cls_name: str) -> None:
        symbol = f"{cls_name}.{fn.name}"
        exempt = fn.name == "__init__"
        ranks = dict(self.order)

        def held_ok(lock: str, held: Tuple[str, ...]) -> bool:
            return lock in held

        def visit(node: ast.AST, held: Tuple[str, ...]) -> None:
            if isinstance(node, ast.With):
                acquired = [a for a in map(_with_locks, node.items)
                            if a is not None and a in self.lock_fields]
                for a in acquired:
                    for h in held:
                        if a in ranks and h in ranks \
                                and ranks[a] < ranks[h]:
                            self._emit(
                                "L002", node.lineno, symbol,
                                f"acquires self.{a} while holding "
                                f"self.{h}; the lock-order contract "
                                f"requires {self._order_str()}")
                new_held = held + tuple(acquired)
                for item in node.items:
                    visit(item.context_expr, held)
                for st in node.body:
                    visit(st, new_held)
                return
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                return                        # nested defs escape the region
            mutated = self._mutation_target(node)
            if mutated is not None and not exempt:
                field, verb = mutated
                lock = self.guards.get(field, (None, 0))[0]
                if lock is not None and not held_ok(lock, held):
                    self._emit(
                        "L001", node.lineno, symbol,
                        f"self.{field} is guarded-by {lock} but {verb} "
                        f"outside `with self.{lock}`")
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        for st in fn.body:
            visit(st, ())

    def _mutation_target(self, node: ast.AST
                         ) -> Optional[Tuple[str, str]]:
        """(field, verb) when `node` mutates an annotated self.<field>."""
        if isinstance(node, ast.Assign):
            for t in node.targets:
                f = self._store_target(t)
                if f is not None:
                    return f, "assigned"
        elif isinstance(node, ast.AugAssign):
            f = self._store_target(node.target)
            if f is not None:
                return f, "aug-assigned"
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                f = self._store_target(t)
                if f is not None:
                    return f, "deleted"
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS:
            f = _self_attr(node.func.value)
            if f is not None and f in self.guards:
                return f, f".{node.func.attr}()-mutated"
        return None

    def _store_target(self, t: ast.expr) -> Optional[str]:
        """Annotated field stored into by target `t` (tuple/subscript ok)."""
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                f = self._store_target(e)
                if f is not None:
                    return f
            return None
        if isinstance(t, ast.Subscript):
            f = _self_attr(t.value)
            return f if f is not None and f in self.guards else None
        f = _self_attr(t)
        return f if f is not None and f in self.guards else None

    def _order_str(self) -> str:
        names = [n for n, _ in sorted(self.order, key=lambda kv: kv[1])]
        return " -> ".join(names)


def check_source(source: str, path: str) -> List[Finding]:
    """Run the lock-discipline pass over one file's source."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError:
        return []                 # jaxlint already reports parse failures
    lines = source.splitlines()
    order: List[Tuple[str, int]] = []
    for line in lines:
        m = _LOCK_ORDER.search(line)
        if m:
            order = [(m.group(1), 0), (m.group(2), 1)]
            break
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            pas = _ClassPass(node, path, lines, order, findings)
            pas.run()
            if order:
                missing = [n for n, _ in order
                           if n not in pas.lock_fields]
                if missing and not pas.lock_fields.isdisjoint(
                        {n for n, _ in order}):
                    # The contract names this class's locks partially:
                    # one side exists, the other never does — rot.
                    for n in missing:
                        findings.append(Finding(
                            rule="L003", path=path, line=1,
                            symbol=node.name,
                            message=f"lock-order names {n!r} but "
                                    f"{node.name} never assigns "
                                    f"self.{n} to a lock"))
    return findings


def check_file(filename: str, repo_rel: str) -> List[Finding]:
    with open(filename, "r", encoding="utf-8") as fh:
        return check_source(fh.read(), repo_rel)

"""Finding model + rule catalogue for the static contract checker.

Every checker (jaxlint, kernel contracts, lock discipline) reports
`Finding` records — rule id, file:line anchor, the enclosing symbol and
a one-line message — so the runner can render one table, match baseline
suppressions uniformly, and gate CI on the active count.

Rule ids are stable API: tests, `analysis_baseline.toml` entries and the
docs catalogue (docs/ANALYSIS.md) all key on them. Add new rules with
new ids; never recycle a retired id.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

# -- rule catalogue ---------------------------------------------------------
# id -> one-line description (docs/ANALYSIS.md carries the long form).

RULES: Dict[str, str] = {
    # jaxlint (AST): JAX tracing / RNG discipline
    "J001": "PRNG key consumed more than once without a jax.random.split",
    "J002": "host-sync call (.item()/.tolist()/np.asarray/float/int) "
            "inside a jit- or Pallas-traced scope",
    "J003": "Python `if`/`while` branches on a tracer-typed value inside "
            "a traced scope",
    "J004": "mutable value (dict/list/non-frozen dataclass) declared as a "
            "static jit argument — retrace/recompile hazard",
    # kernel-contract verifier (registry-driven)
    "C001": "kernel's declared memory-contract bytes diverge from the "
            "BlockSpec-derived HBM traffic",
    "C002": "kernel's per-grid-step VMEM residency exceeds the budget at "
            "a registered parity shape",
    "C003": "registered kernel package has no memory contract",
    # infrastructure
    "X001": "file does not parse",
    # lock discipline (serve tier)
    "L001": "field annotated `# guarded-by: <lock>` mutated outside "
            "`with self.<lock>`",
    "L002": "lock acquisition order contradicts the file's "
            "`# lock-order:` contract",
    "L003": "guarded-by/lock-order annotation names a lock the class "
            "never defines",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One checker hit, anchored to file:line and the enclosing symbol."""
    rule: str
    path: str          # repo-relative posix path
    line: int
    symbol: str        # enclosing function/class qualname ("" at module level)
    message: str

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule}{sym} {self.message}"


def sort_findings(findings: List[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def format_table(findings: List[Finding],
                 title: Optional[str] = None) -> str:
    """Fixed-width findings table (the CLI read-out)."""
    lines = []
    if title:
        lines.append(title)
    if not findings:
        lines.append("  (no findings)")
        return "\n".join(lines)
    for f in sort_findings(findings):
        lines.append("  " + f.render())
    return "\n".join(lines)


def format_markdown(active: List[Finding], suppressed: List[Finding]) -> str:
    """GitHub step-summary markdown: one table, active findings first."""
    out = ["## repro.analysis findings",
           "",
           f"**{len(active)} active**, {len(suppressed)} baseline-suppressed",
           ""]
    if active or suppressed:
        out += ["| status | rule | location | symbol | message |",
                "|---|---|---|---|---|"]
        for status, batch in (("ACTIVE", active), ("baseline", suppressed)):
            for f in sort_findings(batch):
                msg = f.message.replace("|", "\\|")
                out.append(f"| {status} | {f.rule} | `{f.path}:{f.line}` | "
                           f"`{f.symbol}` | {msg} |")
    return "\n".join(out) + "\n"

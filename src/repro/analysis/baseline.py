"""Baseline suppressions: `analysis_baseline.toml` at the repo root.

A suppression is a JUSTIFIED, reviewed exception — every entry must
carry a non-empty `reason`, and matches are as narrow as the entry
makes them:

    [[suppress]]
    rule   = "J001"                      # required: exact rule id
    path   = "src/repro/serve/bench.py"  # required: repo-relative path
    symbol = "benchmark_backends"        # optional: enclosing qualname
    reason = "same key reused on purpose: every backend must see the "
             "same draw so the accuracy column compares like for like"

Omitting `symbol` suppresses the rule for the whole file (use
sparingly). Line numbers are deliberately NOT part of the match — they
churn on every edit; rule+path+symbol is stable across refactors that
do not change behavior.

A malformed baseline (missing reason, unknown rule id) is itself a
fatal error: the suppression file must never rot into a silent
allowlist.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Tuple

try:
    import tomllib
except ImportError:                       # Python 3.10: stdlib tomllib is 3.11+
    import tomli as tomllib

from repro.analysis.findings import RULES, Finding


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    path: str
    symbol: str          # "" = whole file
    reason: str

    def matches(self, f: Finding) -> bool:
        if self.rule != f.rule or self.path != f.path:
            return False
        return self.symbol in ("", f.symbol)


class BaselineError(ValueError):
    """analysis_baseline.toml is malformed; fix the file, don't skip it."""


def load_baseline(path: str | Path) -> List[Suppression]:
    """Parse + validate the baseline file; missing file = no suppressions."""
    p = Path(path)
    if not p.exists():
        return []
    with open(p, "rb") as fh:
        doc = tomllib.load(fh)
    entries = doc.get("suppress", [])
    if not isinstance(entries, list):
        raise BaselineError(f"{p}: [[suppress]] must be an array of tables")
    out = []
    for i, e in enumerate(entries):
        where = f"{p}: suppress[{i}]"
        for req in ("rule", "path", "reason"):
            if not isinstance(e.get(req), str) or not e.get(req).strip():
                raise BaselineError(f"{where}: non-empty {req!r} is required")
        if e["rule"] not in RULES:
            raise BaselineError(f"{where}: unknown rule id {e['rule']!r}; "
                                f"known: {sorted(RULES)}")
        out.append(Suppression(rule=e["rule"],
                               path=Path(e["path"]).as_posix(),
                               symbol=str(e.get("symbol", "")),
                               reason=e["reason"].strip()))
    return out


def apply_baseline(findings: List[Finding],
                   suppressions: List[Suppression]
                   ) -> Tuple[List[Finding], List[Finding], List[Suppression]]:
    """Partition findings into (active, suppressed); third element is the
    stale suppressions that matched nothing (reported so the baseline
    shrinks when fixes land, instead of accreting dead entries)."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    used: Dict[Suppression, int] = {s: 0 for s in suppressions}
    for f in findings:
        hit = next((s for s in suppressions if s.matches(f)), None)
        if hit is None:
            active.append(f)
        else:
            used[hit] += 1
            suppressed.append(f)
    stale = [s for s, n in used.items() if n == 0]
    return active, suppressed, stale

"""repro.analysis: CI-gated static contract checker.

Three checker families behind one runner (`python -m repro.analysis`):

* jaxlint (J00x)      — AST lint for JAX tracing/RNG discipline
* contracts (C00x)    — Pallas memory contracts vs. actual BlockSpecs
* locks (L00x)        — serve-tier guarded-by / lock-order discipline

See docs/ANALYSIS.md for the rule catalogue and the suppression
workflow (`analysis_baseline.toml`).
"""
from repro.analysis.findings import RULES, Finding          # noqa: F401
from repro.analysis.runner import main, run                 # noqa: F401

"""Pure-jnp oracle for the FWHT Pallas kernel."""
import jax.numpy as jnp


def fwht_ref(x: jnp.ndarray, normalize: bool = True) -> jnp.ndarray:
    """Walsh-Hadamard transform along axis 0; x: (n, c), n = 2^m."""
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"FWHT needs power-of-two length, got {n}")
    shape = x.shape
    x = x.reshape(n, -1)
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, -1)
        a, b = x[:, 0], x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    x = x.reshape(shape)
    if normalize:
        x = x / jnp.sqrt(jnp.asarray(n, x.dtype))
    return x

"""Pallas TPU kernel: Fast Walsh-Hadamard transform along axis 0.

TPU adaptation of the paper's pthread-parallel C/mex FWHT (DESIGN.md §3).

Tiling strategy
---------------
x is (n, c), n = 2^m. The grid runs over column tiles; each program instance
holds an (n_block, col_tile) slab in VMEM and performs ALL log2(n_block)
butterfly stages over it before writing back — HBM traffic is exactly one
read + one write per super-stage instead of one per stage (the naive
pay-per-stage schedule is log2(n)x more HBM traffic; that is the whole
perf argument for fusing stages in VMEM).

For n larger than a VMEM slab, ops.py factorizes H_n = (H_a (x) I_b) .
(I_a (x) H_b): two grid sweeps of this same kernel around a transpose, so
the per-sweep working set stays (<= 2^13, 128) floats. Butterflies are VPU
adds/subs on (8,128)-aligned tiles; there is no MXU work in this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref, *, n: int, scale: float):
    """All log2(n) stages fused over a VMEM-resident (n, ct) slab."""
    x = x_ref[...]                      # (n, ct) in VMEM
    ct = x.shape[1]
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, ct)
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    o_ref[...] = x.reshape(n, ct) * scale


def fwht_1level(x: jnp.ndarray, col_tile: int = 128, normalize: bool = True,
                interpret: bool = False) -> jnp.ndarray:
    """FWHT for n small enough that an (n, col_tile) slab fits VMEM."""
    n, c = x.shape
    if n & (n - 1):
        raise ValueError(f"power-of-two length required, got {n}")
    col_tile = min(col_tile, c)
    if c % col_tile:
        pad = col_tile - c % col_tile
        x = jnp.pad(x, ((0, 0), (0, pad)))
    cp = x.shape[1]
    scale = float(1.0 / (n ** 0.5)) if normalize else 1.0
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, n=n, scale=scale),
        out_shape=jax.ShapeDtypeStruct((n, cp), x.dtype),
        grid=(cp // col_tile,),
        in_specs=[pl.BlockSpec((n, col_tile), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, col_tile), lambda j: (0, j)),
        interpret=interpret,
    )(x)
    return out[:, :c]

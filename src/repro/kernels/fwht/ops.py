"""Public jit'd wrapper for the FWHT Pallas kernel.

Handles the two-level factorization H_n = (H_a (x) I_b)(I_a (x) H_b) for n
beyond a single VMEM slab: sweep 1 applies H_b inside contiguous length-b
blocks, sweep 2 applies H_a across blocks (via a transpose so the strided
butterflies become contiguous again). Both sweeps reuse the same fused-stage
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fwht.fwht import fwht_1level
from repro.kernels.fwht.ref import fwht_ref
from repro.kernels.registry import (KernelContract, KernelEntry,
                                    register_contract, register_kernel)

# Max rows for a single-level slab: 2^13 x 128 lanes x 4B = 4 MiB of VMEM
# (input + stacked temporaries stay < 16 MiB).
_MAX_SINGLE = 1 << 13


def sweep_shapes(n: int, c: int) -> tuple:
    """The (rows, cols) slab per fwht_1level sweep fwht_pallas issues —
    one slab for n <= _MAX_SINGLE, else the two-level factorization."""
    if n <= _MAX_SINGLE:
        return ((n, c),)
    b = _MAX_SINGLE
    return ((b, (n // b) * c), (n // b, b * c))


def memory_contract(n: int, c: int, col_tile: int = 128) -> dict:
    """Declared HBM byte model: each sweep reads + writes its padded
    slab exactly once — the fused-stage schedule's whole perf argument
    (the naive pay-per-stage schedule is log2(n)x more). Cross-checked
    against fwht_1level's BlockSpecs by `repro.analysis` (rule C001)."""
    hbm = 0.0
    for rows, cols in sweep_shapes(n, c):
        ct = min(col_tile, cols)
        cp = -(-cols // ct) * ct
        hbm += 2 * 4.0 * rows * cp
    return {"sweeps": sweep_shapes(n, c), "hbm_bytes": hbm}


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("normalize", "col_tile",
                                             "interpret"))
def fwht_pallas(x: jnp.ndarray, normalize: bool = True, col_tile: int = 128,
                interpret: bool | None = None) -> jnp.ndarray:
    """FWHT along axis 0 of (n, c); n = 2^m. Pallas on TPU, interpret on CPU."""
    interp = _is_cpu() if interpret is None else interpret
    n, c = x.shape
    if n & (n - 1):
        raise ValueError(f"power-of-two length required, got {n}")
    if n <= _MAX_SINGLE:
        return fwht_1level(x, col_tile, normalize, interp)
    # Two-level: n = a * b with b = _MAX_SINGLE.
    b = _MAX_SINGLE
    a = n // b
    # Sweep 1: H_b within blocks. (a*b, c) -> treat as a separate columns.
    xb = x.reshape(a, b, c).transpose(1, 0, 2).reshape(b, a * c)
    xb = fwht_1level(xb, col_tile, False, interp)
    # Sweep 2: H_a across blocks.
    xa = xb.reshape(b, a, c).transpose(1, 0, 2).reshape(a, b * c)
    xa = fwht_1level(xa, col_tile, False, interp)
    out = xa.reshape(a, b, c)
    if normalize:
        out = out / jnp.sqrt(jnp.asarray(n, x.dtype))
    return out.reshape(n, c)


def _fwht_build(key, case):
    x = jax.random.normal(key, (case["n"], case["c"]), jnp.float32)
    return (x,), {}, {}


register_kernel(KernelEntry(
    name="fwht", op=fwht_pallas, ref=fwht_ref,
    cases=({"n": 8, "c": 3}, {"n": 512, "c": 128}, {"n": 4096, "c": 1},
           {"n": 1 << 14, "c": 2}),
    build=_fwht_build, rtol=2e-4, atol=2e-4))


def _fwht_declared(case: dict) -> dict:
    return memory_contract(case["n"], case["c"])


register_contract(KernelContract(name="fwht", declared=_fwht_declared))

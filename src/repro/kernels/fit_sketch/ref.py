"""Pure-jnp oracle for the fused fit-sketch accumulate kernel."""
import jax.numpy as jnp

from repro.kernels.gram.ref import gram_stripe_ref


def fit_sketch_ref(X: jnp.ndarray, Omega: jnp.ndarray, C: jnp.ndarray,
                   Ocross: jnp.ndarray, V: jnp.ndarray = None,
                   kind: str = "polynomial", gamma: float = 0.0,
                   degree: int = 2):
    """All four contractions of K = kappa(X, C) the fit update consumes.

    X (p, m), Omega (m, r'), C (p, b), Ocross (b, r'), V (8, m) row 0
    the row-validity mask (None = all valid). Returns
      new_rows (b, r') = K^T Omega    (the b new sketch rows)
      delta    (m, r') = K Ocross     (cross-term update, caller masks)
      rn_rows  (m,)    = row sums of K*K
      rn_cols  (b,)    = V-masked column sums of K*K
    """
    K = gram_stripe_ref(X, C, kind=kind, gamma=gamma, degree=degree)
    vm = (jnp.ones((X.shape[1],), jnp.float32) if V is None
          else V[0].astype(jnp.float32))
    new_rows = K.T @ Omega
    delta = K @ Ocross
    rn_rows = jnp.sum(K * K, axis=1)
    rn_cols = vm @ (K * K)
    return new_rows, delta, rn_rows, rn_cols

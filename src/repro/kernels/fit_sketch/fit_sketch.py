"""Pallas TPU kernel: fused gram-stripe -> sketch-accumulate for fit.

The one-pass training update (stream/accumulate.py) consumes each
(m, b) kernel block Kc = kappa(X, C) three ways: contracted against the
sketch rows Omega[:m] into the b new sketch rows (new_rows = Kc^T Omega),
contracted against the block's own sketch rows into the cross-term update
of the already-applied sketch rows (delta = Kc Omega[q:q+b]), and
squared-and-summed both ways for the Frobenius ledger row_norms2. Running
those as separate executables round-trips the (m, b) block through HBM
between the gram build and every contraction — the exact traffic
kernels/extend_embed deletes on the serving path. This kernel applies the
same trick to training: each grid instance builds one (bm, b) gram tile
(MXU matmul + fused VPU nonlinearity, same tiling as kernels/gram) and
immediately contracts/reduces it into all four outputs, with the (b, r')
sketch accumulator VMEM-resident across the grid (constant output index
map, zeroed at i=0, accumulated into thereafter — the extend_embed
accumulator pattern). The (m, b) block never exists outside VMEM.

Tiling: grid over row tiles i of X; instance i holds X_i (p, bm),
O_i (bm, r'), V_i (8, bm) plus the resident C (p, b), Ocross (b, r'),
and the resident accumulators acc (b, r') / rn_col (8, b). Outputs
delta (bm, r') and rn_row (bm, 128) are written tile by tile. MXU dims:
(bm x p)@(p x b), (b x bm)@(bm x r'), (bm x b)@(b x r'); bm, b, r'
multiples of 128, masks in 8-sublane rows.

Exactness of padding/masking (see ops.py): garbage gram rows (padded or
invalid X columns) are annihilated by zero rows of O (new_rows), masked
by V (rn_col) or sliced/masked by the caller (delta, rn_row); garbage
gram COLUMNS (padded C columns) are annihilated by zero rows of Ocross
(delta), excluded by the static b_real column mask (rn_row) or sliced by
the caller (new_rows, rn_col).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fit_sketch_kernel(xi_ref, oi_ref, xb_ref, ocr_ref, vi_ref,
                       acc_ref, dl_ref, rnr_ref, rnc_ref, *, kind: str,
                       gamma: float, degree: int, b_real: int):
    i = pl.program_id(0)
    xi = xi_ref[...]                    # (p, bm)   X row tile
    xb = xb_ref[...]                    # (p, w)    block columns C
    z = jax.lax.dot_general(xi, xb, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bm, w)
    if kind == "polynomial":
        k = (z + gamma) ** degree
    elif kind == "rbf":
        xn = jnp.sum(xi * xi, axis=0)[:, None]
        yn = jnp.sum(xb * xb, axis=0)[None, :]
        k = jnp.exp(-gamma * jnp.maximum(xn + yn - 2.0 * z, 0.0))
    else:  # linear
        k = z
    oi = oi_ref[...]                    # (bm, rp)  sketch rows of this tile
    acc_part = jax.lax.dot_general(k, oi, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    ocr = ocr_ref[...]                  # (w, rp)   sketch rows of the block
    delta = jax.lax.dot_general(k, ocr, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    k2 = k * k
    colmask = jax.lax.broadcasted_iota(jnp.int32, (1, k.shape[1]),
                                       1) < b_real
    rnr = jnp.sum(jnp.where(colmask, k2, 0.0), axis=1, keepdims=True)
    vi = vi_ref[...]                    # (8, bm)   row 0 = validity mask
    rnc_part = jax.lax.dot_general(vi, k2, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rnc_ref[...] = jnp.zeros_like(rnc_ref)

    acc_ref[...] += acc_part.astype(acc_ref.dtype)   # (w, rp) resident
    rnc_ref[...] += rnc_part.astype(rnc_ref.dtype)   # (8, w) resident
    dl_ref[...] = delta.astype(dl_ref.dtype)         # (bm, rp) per tile
    rnr_ref[...] = jnp.broadcast_to(rnr, rnr_ref.shape).astype(
        rnr_ref.dtype)                               # (bm, 128) per tile


def fit_sketch_call(X: jnp.ndarray, Omega: jnp.ndarray, C: jnp.ndarray,
                    Ocross: jnp.ndarray, V: jnp.ndarray, kind: str,
                    gamma: float, degree: int, b_real: int, row_tile: int,
                    interpret: bool):
    """All four fit contractions of kappa(X, C); m % row_tile == 0.

    X (p, m), Omega (m, rp), C (p, w), Ocross (w, rp), V (8, m) ->
    acc (w, rp), delta (m, rp), rn_row (m, 128), rn_col (8, w);
    b_real = count of real (unpadded) block columns, for the static
    rn_row column mask.
    """
    p, m = X.shape
    rp = Omega.shape[1]
    w = C.shape[1]
    return pl.pallas_call(
        functools.partial(_fit_sketch_kernel, kind=kind, gamma=gamma,
                          degree=degree, b_real=b_real),
        out_shape=(
            jax.ShapeDtypeStruct((w, rp), jnp.float32),
            jax.ShapeDtypeStruct((m, rp), jnp.float32),
            jax.ShapeDtypeStruct((m, 128), jnp.float32),
            jax.ShapeDtypeStruct((8, w), jnp.float32),
        ),
        grid=(m // row_tile,),
        in_specs=[
            pl.BlockSpec((p, row_tile), lambda i: (0, i)),
            pl.BlockSpec((row_tile, rp), lambda i: (i, 0)),
            pl.BlockSpec((p, w), lambda i: (0, 0)),
            pl.BlockSpec((w, rp), lambda i: (0, 0)),
            pl.BlockSpec((8, row_tile), lambda i: (0, i)),
        ],
        out_specs=(
            pl.BlockSpec((w, rp), lambda i: (0, 0)),
            pl.BlockSpec((row_tile, rp), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 128), lambda i: (i, 0)),
            pl.BlockSpec((8, w), lambda i: (0, 0)),
        ),
        interpret=interpret,
    )(X, Omega, C, Ocross, V)

"""Public jit'd wrapper for the fused fit-sketch accumulate kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fit_sketch.fit_sketch import fit_sketch_call
from repro.kernels.fit_sketch.ref import fit_sketch_ref
from repro.kernels.registry import (KernelContract, KernelEntry,
                                    register_contract, register_kernel)


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def padded_shapes(m: int, b: int, rp: int, row_tile: int = 256
                  ) -> tuple[int, int, int, int]:
    """(row_tile, m_pad, b_pad, rp_pad) the kernel actually runs at.

    The single source of truth for the tiling: fit_sketch_pallas pads
    with exactly these values, and the "fit_scaling" bench section
    (serve/bench.py) derives the fused fit engine's HBM byte count from
    them — each padded operand crosses HBM once, that IS the kernel's
    memory contract.
    """
    row_tile = min(row_tile, max(128, 1 << (m - 1).bit_length()))
    m_pad = -(-m // row_tile) * row_tile
    b_pad = -(-b // 128) * 128
    rp_pad = -(-rp // 128) * 128
    return row_tile, m_pad, b_pad, rp_pad


def memory_contract(p: int, m: int, b: int, rp: int, row_tile: int = 256
                    ) -> dict:
    """Declared HBM byte model for one fused fit-block call.

    Every operand block crosses HBM exactly once per distinct grid
    coordinate (moving operands stream, constant-index operands stay
    VMEM-resident), so the f32 traffic is the sum of the padded operand
    footprints. serve/bench.py reports THESE numbers and
    `repro.analysis` cross-checks them against the kernel's BlockSpecs
    at every registered parity case (rule C001).
    """
    row_tile, m_pad, b_pad, rp_pad = padded_shapes(m, b, rp, row_tile)
    hbm = 4.0 * (p * m_pad             # X (p, m_pad) streamed
                 + m_pad * rp_pad      # Omega rows streamed
                 + p * b_pad           # C block, resident
                 + b_pad * rp_pad      # Ocross, resident
                 + 8 * m_pad           # V validity mask, streamed
                 + b_pad * rp_pad      # new_rows accumulator, resident
                 + m_pad * rp_pad      # delta out, streamed
                 + m_pad * 128         # row-norm out, streamed
                 + 8 * b_pad)          # col-norm out, resident
    return {"row_tile": row_tile, "m_pad": m_pad, "b_pad": b_pad,
            "rp_pad": rp_pad, "hbm_bytes": hbm}


@functools.partial(jax.jit, static_argnames=("kind", "gamma", "degree",
                                             "row_tile", "interpret"))
def fit_sketch_pallas(X: jnp.ndarray, Omega: jnp.ndarray, C: jnp.ndarray,
                      Ocross: jnp.ndarray, V: jnp.ndarray | None = None,
                      kind: str = "polynomial", gamma: float = 0.0,
                      degree: int = 2, row_tile: int = 256,
                      interpret: bool | None = None):
    """Fused fit-block contractions of K = kappa(X, C), one executable.

    X (p, m) samples as columns, Omega (m, r') sketch rows (callers zero
    the rows of invalid/garbage X columns — that zeroing is what makes
    the padding exact), C (p, b) block columns, Ocross (b, r') the
    block's own sketch rows, V (8, m) optional row-validity mask in row
    0 (None = all m rows valid). Returns
      (new_rows (b, r'), delta (m, r'), rn_rows (m,), rn_cols (b,))
    matching fit_sketch_ref. Pads m to the row tile, b and r' to 128
    lanes; padded Omega/Ocross rows are zero and padded V columns are
    zero, so every padded contribution is annihilated (exact, not
    approximate), and padded output rows/columns are sliced off.
    """
    interp = _is_cpu() if interpret is None else interpret
    m = X.shape[1]
    b = C.shape[1]
    rp = Omega.shape[1]
    row_tile, _, _, _ = padded_shapes(m, b, rp, row_tile)
    if V is None:
        V = jnp.zeros((8, m), jnp.float32).at[0].set(1.0)
    Xp = _pad_to(X, 1, row_tile)
    Op = _pad_to(_pad_to(Omega, 0, row_tile), 1, 128)
    Cp = _pad_to(C, 1, 128)
    Ocrp = _pad_to(_pad_to(Ocross, 0, 128), 1, 128)
    Vp = _pad_to(V, 1, row_tile)
    acc, delta, rnr, rnc = fit_sketch_call(Xp, Op, Cp, Ocrp, Vp, kind,
                                           gamma, degree, b, row_tile,
                                           interp)
    return acc[:b, :rp], delta[:m, :rp], rnr[:m, 0], rnc[0, :b]


def _fit_sketch_build(key, case):
    p, m, b, rp = case["p"], case["m"], case["b"], case["rp"]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    X = jax.random.normal(k1, (p, m), jnp.float32)
    Omega = jax.random.normal(k2, (m, rp), jnp.float32)
    C = jax.random.normal(k3, (p, b), jnp.float32)
    Ocr = jax.random.normal(k4, (b, rp), jnp.float32)
    valid = case.get("valid", m)
    if valid < m:
        # Mirror the fit caller's contract: Omega rows of invalid
        # columns are zeroed, V masks them out of the column norms.
        Omega = Omega.at[valid:].set(0.0)
    V = jnp.zeros((8, m), jnp.float32).at[0, :valid].set(1.0)
    kw = {k: case[k] for k in ("kind", "gamma", "degree") if k in case}
    return (X, Omega, C, Ocr, V), kw, kw


register_kernel(KernelEntry(
    name="fit_sketch", op=fit_sketch_pallas, ref=fit_sketch_ref,
    cases=(
        {"p": 2, "m": 100, "b": 12, "rp": 12},
        {"p": 19, "m": 555, "b": 64, "rp": 33, "kind": "rbf",
         "gamma": 0.5},
        {"p": 7, "m": 1024, "b": 128, "rp": 140, "valid": 700},
        {"p": 3, "m": 97, "b": 1, "rp": 5, "kind": "linear"},
        {"p": 5, "m": 300, "b": 37, "rp": 20, "kind": "polynomial",
         "gamma": 1.0, "degree": 3, "valid": 123},
    ),
    build=_fit_sketch_build, rtol=2e-3, atol=2e-3))


def _fit_sketch_declared(case: dict) -> dict:
    return memory_contract(case["p"], case["m"], case["b"], case["rp"])


register_contract(KernelContract(name="fit_sketch",
                                 declared=_fit_sketch_declared))

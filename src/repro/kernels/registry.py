"""Kernel parity registry: every Pallas package's (op, ref, shapes).

Each kernel package's ops.py registers a KernelEntry at import time —
its public op, its pure-jnp oracle, the seeded parity-shape grid the
oracle must match it on, and a `build` callable turning one case dict
into concrete arguments. The kernel-parity CI job and
tests/test_kernel_registry.py iterate THIS registry instead of
hard-coding imports, so a new kernel package (e.g. fit_sketch) gets
parity coverage by registering itself — no test edits.

Importing `repro.kernels` populates the registry (its __init__ imports
every package's ops module); this module itself imports none of them, so
there is no cycle.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple


class KernelEntry(NamedTuple):
    """One kernel package's parity contract.

    op:    public jit'd wrapper; must accept interpret= (the parity
           sweep forces interpret=True so it runs anywhere).
    ref:   pure-jnp oracle with the same positional signature.
    cases: tuple of case dicts, each one parity point of the shape grid.
    build: (key, case) -> (args, op_kwargs, ref_kwargs); args are passed
           positionally to both op and ref.
    rtol/atol: allclose tolerances for the default comparison.
    compare: optional (got, want, rtol, atol) override for ops whose
           outputs need more than leaf-wise allclose (e.g. argmin label
           ties in kmeans_assign).
    """
    name: str
    op: Callable
    ref: Callable
    cases: Tuple[Dict, ...]
    build: Callable
    rtol: float = 2e-3
    atol: float = 2e-3
    compare: Optional[Callable] = None


class KernelContract(NamedTuple):
    """One kernel package's declared memory-contract model.

    declared: (case) -> dict with at least "hbm_bytes": the closed-form
           byte model for one parity case — the number serve/bench.py
           reports. `repro.analysis` cross-checks it against the HBM
           traffic derived from the kernel's actual BlockSpecs at every
           registered case, so the model cannot silently drift from the
           kernel (rule C001).
    vmem_budget: per-grid-step VMEM residency ceiling in bytes the
           kernel must stay under at every registered case (rule C002).
    """
    name: str
    declared: Callable
    vmem_budget: int = 16 * 1024 * 1024


_REGISTRY: Dict[str, KernelEntry] = {}
_CONTRACTS: Dict[str, KernelContract] = {}


def register_kernel(entry: KernelEntry) -> KernelEntry:
    """Register one kernel package (idempotent per name; re-registering
    a name replaces it, so module reloads stay harmless)."""
    if not entry.cases:
        raise ValueError(f"kernel {entry.name!r} registered with no "
                         f"parity cases")
    _REGISTRY[entry.name] = entry
    return entry


def get_kernel(name: str) -> KernelEntry:
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{registered_kernels()}")
    return _REGISTRY[name]


def registered_kernels() -> list:
    """Registered kernel names, sorted."""
    return sorted(_REGISTRY)


def kernel_entries() -> Tuple[KernelEntry, ...]:
    """All entries, name-sorted — what the parity sweep iterates."""
    return tuple(_REGISTRY[n] for n in registered_kernels())


def register_contract(contract: KernelContract) -> KernelContract:
    """Register one package's memory contract (same replace semantics
    as register_kernel)."""
    _CONTRACTS[contract.name] = contract
    return contract


def get_contract(name: str) -> Optional[KernelContract]:
    """The declared contract for `name`, or None — `repro.analysis`
    reports a missing contract as C003 rather than raising here."""
    return _CONTRACTS.get(name)

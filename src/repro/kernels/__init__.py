"""Pallas TPU kernels for the paper's compute hot spots.

fwht/          in-VMEM radix-2 butterfly Walsh-Hadamard transform (the
               preconditioning transform H of Omega = D H R)
gram/          blocked kernel-matrix stripes on the MXU with the kernel
               nonlinearity fused (the streaming pass K[:, block])
kmeans_assign/ fused distance + argmin for the Lloyd assignment step
extend_embed/  fused gram->projection serving stripe: the (n, w) kernel
               block is built and contracted against Sigma^{-1/2} U^T
               tile by tile without ever leaving VMEM (serve/extend.py)
fit_sketch/    fused gram->sketch-accumulate training stripe: each
               (m, b) kernel block is contracted into the (b, r') sketch
               rows, cross-term and Frobenius ledgers in one pass with
               the sketch accumulator VMEM-resident (stream/accumulate)

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper, interpret=True on CPU) and ref.py (pure-jnp oracle used by
the allclose test sweeps). Each ops.py registers its (op, ref,
parity-shapes) triple in registry.py at import; the kernel-parity CI job
(tests/test_kernel_registry.py, `kernels`-marked) iterates that registry,
forcing every kernel through interpret mode against its oracle on the
registered seeded shape grid.
"""
from repro.kernels.extend_embed.ops import extend_embed_pallas
from repro.kernels.fit_sketch.ops import fit_sketch_pallas
from repro.kernels.fwht.ops import fwht_pallas
from repro.kernels.gram.ops import gram_stripe_pallas
from repro.kernels.kmeans_assign.ops import assign_pallas
from repro.kernels.registry import (KernelEntry, get_kernel,
                                    kernel_entries, register_kernel,
                                    registered_kernels)
__all__ = ["extend_embed_pallas", "fit_sketch_pallas", "fwht_pallas",
           "gram_stripe_pallas", "assign_pallas",
           "KernelEntry", "get_kernel", "kernel_entries",
           "register_kernel", "registered_kernels"]

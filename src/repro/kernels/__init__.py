"""Pallas TPU kernels for the paper's compute hot spots.

fwht/          in-VMEM radix-2 butterfly Walsh-Hadamard transform (the
               preconditioning transform H of Omega = D H R)
gram/          blocked kernel-matrix stripes on the MXU with the kernel
               nonlinearity fused (the streaming pass K[:, block])
kmeans_assign/ fused distance + argmin for the Lloyd assignment step
extend_embed/  fused gram->projection serving stripe: the (n, w) kernel
               block is built and contracted against Sigma^{-1/2} U^T
               tile by tile without ever leaving VMEM (serve/extend.py)

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper, interpret=True on CPU) and ref.py (pure-jnp oracle used by
the allclose test sweeps). CI's kernel-parity job runs the `kernels`-marked
pytest subset, which forces every kernel through interpret mode against
its oracle on a seeded shape grid.
"""
from repro.kernels.extend_embed.ops import extend_embed_pallas
from repro.kernels.fwht.ops import fwht_pallas
from repro.kernels.gram.ops import gram_stripe_pallas
from repro.kernels.kmeans_assign.ops import assign_pallas
__all__ = ["extend_embed_pallas", "fwht_pallas", "gram_stripe_pallas",
           "assign_pallas"]

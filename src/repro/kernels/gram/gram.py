"""Pallas TPU kernel: blocked kernel-matrix stripe with fused nonlinearity.

The streaming pass of Alg. 1 consumes K in column stripes K[:, j:j+w] =
kappa(X, X[:, j:j+w]). On TPU this is an MXU matmul (X^T X_b, contraction
over the feature dim p) followed by a cheap VPU nonlinearity. Fusing the
nonlinearity into the same kernel means the raw inner-product tile never
round-trips to HBM: arithmetic intensity of the stripe pass doubles for
small p (the regime the paper targets — p=2..19 in its experiments).

Tiling: grid over row tiles i of the stripe; each instance holds
X_i (p, bm) and X_b (p, w) in VMEM (X_b is re-fetched per row tile via a
constant index map; Pallas keeps it resident across the grid since the
block index is unchanged), emits a (bm, w) tile of K. MXU dims: (bm x p) @
(p x w) — bm, w multiples of 128; p padded to 8 lanes by Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(xi_ref, xb_ref, o_ref, *, kind: str, gamma: float,
                 degree: int):
    xi = xi_ref[...]                    # (p, bm)
    xb = xb_ref[...]                    # (p, w)
    z = jax.lax.dot_general(xi, xb, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bm, w)
    if kind == "polynomial":
        k = (z + gamma) ** degree
    elif kind == "rbf":
        xn = jnp.sum(xi * xi, axis=0)[:, None]
        yn = jnp.sum(xb * xb, axis=0)[None, :]
        k = jnp.exp(-gamma * jnp.maximum(xn + yn - 2.0 * z, 0.0))
    else:  # linear
        k = z
    o_ref[...] = k.astype(o_ref.dtype)


def gram_stripe_call(X: jnp.ndarray, Xb: jnp.ndarray, kind: str,
                     gamma: float, degree: int, row_tile: int,
                     interpret: bool) -> jnp.ndarray:
    """K stripe kappa(X, Xb); X (p, n), Xb (p, w), n % row_tile == 0."""
    p, n = X.shape
    w = Xb.shape[1]
    return pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind, gamma=gamma,
                          degree=degree),
        out_shape=jax.ShapeDtypeStruct((n, w), jnp.float32),
        grid=(n // row_tile,),
        in_specs=[
            pl.BlockSpec((p, row_tile), lambda i: (0, i)),
            pl.BlockSpec((p, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, w), lambda i: (i, 0)),
        interpret=interpret,
    )(X, Xb)

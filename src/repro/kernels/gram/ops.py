"""Public jit'd wrapper for the gram-stripe Pallas kernel (pads to tiles)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram.gram import gram_stripe_call
from repro.kernels.gram.ref import gram_stripe_ref
from repro.kernels.registry import (KernelContract, KernelEntry,
                                    register_contract, register_kernel)


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def padded_shapes(n: int, w: int, row_tile: int = 256
                  ) -> tuple[int, int, int]:
    """(row_tile, n_pad, w_pad) the kernel actually runs at — the single
    source of truth for the tiling (gram_stripe_pallas pads with exactly
    these values; memory_contract derives the byte model from them)."""
    row_tile = min(row_tile, max(128, 1 << (n - 1).bit_length()))
    n_pad = -(-n // row_tile) * row_tile
    w_pad = -(-w // 128) * 128
    return row_tile, n_pad, w_pad


def memory_contract(p: int, n: int, w: int, row_tile: int = 256) -> dict:
    """Declared HBM byte model for one gram stripe: X streams over the
    row-tile grid, the query block Xb stays VMEM-resident, and the
    (n_pad, w_pad) stripe is written out tile by tile. Cross-checked
    against the BlockSpecs by `repro.analysis` (rule C001)."""
    row_tile, n_pad, w_pad = padded_shapes(n, w, row_tile)
    hbm = 4.0 * (p * n_pad             # X (p, n_pad) streamed
                 + p * w_pad           # Xb query block, resident
                 + n_pad * w_pad)      # stripe out, streamed
    return {"row_tile": row_tile, "n_pad": n_pad, "w_pad": w_pad,
            "hbm_bytes": hbm}


@functools.partial(jax.jit, static_argnames=("kind", "gamma", "degree",
                                             "row_tile", "interpret"))
def gram_stripe_pallas(X: jnp.ndarray, Xb: jnp.ndarray,
                       kind: str = "polynomial", gamma: float = 0.0,
                       degree: int = 2, row_tile: int = 256,
                       interpret: bool | None = None) -> jnp.ndarray:
    """kappa(X, Xb) -> (n, w). Pads n and w up to MXU-aligned tiles.

    NOTE on RBF padding: padded columns of X are zero vectors, giving
    spurious exp(-gamma*||x||^2) entries in padded ROWS — they are sliced
    away before returning, and padded w columns likewise, so the visible
    result is exact.
    """
    interp = _is_cpu() if interpret is None else interpret
    p, n = X.shape
    w = Xb.shape[1]
    row_tile, _, _ = padded_shapes(n, w, row_tile)
    Xp = _pad_to(X, 1, row_tile)
    Xbp = _pad_to(Xb, 1, 128)
    out = gram_stripe_call(Xp, Xbp, kind, gamma, degree, row_tile, interp)
    return out[:n, :w]


def _gram_build(key, case):
    k1, k2 = jax.random.split(key)
    X = jax.random.normal(k1, (case["p"], case["n"]), jnp.float32)
    Xb = jax.random.normal(k2, (case["p"], case["w"]), jnp.float32)
    kw = {k: case[k] for k in ("kind", "gamma", "degree") if k in case}
    return (X, Xb), kw, kw


register_kernel(KernelEntry(
    name="gram_stripe", op=gram_stripe_pallas, ref=gram_stripe_ref,
    cases=(
        {"p": 2, "n": 100, "w": 12},
        {"p": 19, "n": 555, "w": 64, "kind": "rbf", "gamma": 0.5},
        {"p": 7, "n": 1024, "w": 128, "kind": "polynomial", "gamma": 1.0,
         "degree": 3},
        {"p": 3, "n": 97, "w": 1, "kind": "linear"},
    ),
    build=_gram_build, rtol=2e-3, atol=2e-3))


def _gram_declared(case: dict) -> dict:
    return memory_contract(case["p"], case["n"], case["w"])


register_contract(KernelContract(name="gram_stripe",
                                 declared=_gram_declared))

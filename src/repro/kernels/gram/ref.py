"""Pure-jnp oracle for the blocked gram-stripe Pallas kernel."""
import jax.numpy as jnp


def gram_stripe_ref(X: jnp.ndarray, Xb: jnp.ndarray, kind: str = "polynomial",
                    gamma: float = 0.0, degree: int = 2) -> jnp.ndarray:
    """K[:, block] = kappa(X, Xb). X: (p, n), Xb: (p, w) -> (n, w)."""
    z = X.T @ Xb
    if kind == "polynomial":
        return (z + gamma) ** degree
    if kind == "rbf":
        xn = jnp.sum(X * X, axis=0)[:, None]
        yn = jnp.sum(Xb * Xb, axis=0)[None, :]
        return jnp.exp(-gamma * jnp.maximum(xn + yn - 2.0 * z, 0.0))
    if kind == "linear":
        return z
    raise ValueError(kind)

"""Pure-jnp oracle for the fused gram->projection serving-stripe kernel."""
import jax.numpy as jnp

from repro.kernels.gram.ref import gram_stripe_ref


def extend_embed_ref(X: jnp.ndarray, P: jnp.ndarray, Xb: jnp.ndarray,
                     kind: str = "polynomial", gamma: float = 0.0,
                     degree: int = 2) -> jnp.ndarray:
    """P @ kappa(X, Xb). X: (p, n), P: (r, n), Xb: (p, w) -> (r, w).

    This IS the two-pass path (gram stripe materialized, then projected);
    the Pallas kernel must match it to fp32 accumulation tolerance.
    """
    return P @ gram_stripe_ref(X, Xb, kind=kind, gamma=gamma, degree=degree)

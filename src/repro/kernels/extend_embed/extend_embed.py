"""Pallas TPU kernel: fused gram->projection serving stripe.

The out-of-sample extension y(x) = Sigma^{-1/2} U^T kappa(X_train, x)
(serve/extend.py) consumes the (n, w) kernel stripe kappa(X_train, X_q)
only to contract it against the tiny projection P = Sigma^{-1/2} U^T
(r, n). Running gram and projection as two executables round-trips the
(n, w) stripe through HBM; this kernel keeps it on-chip: each grid
instance builds one (bm, w) gram tile (MXU matmul + fused VPU
nonlinearity, same tiling as kernels/gram) and immediately contracts it
with the matching (r, bm) tile of P into a VMEM-resident (r, w)
accumulator. The (n, w) stripe never exists outside VMEM, so stripe HBM
traffic drops from O(n*w + n*(p+r)) to O(n*(p+r) + w*(p+r)).

Tiling: grid over row tiles i of the training set; instance i holds
X_i (p, bm), P_i (r, bm) and X_q (p, w) in VMEM (X_q and the (r, w)
output use constant index maps, so Pallas keeps both resident across the
grid — the output block is revisited, zeroed at i=0 and accumulated into
thereafter). MXU dims: (bm x p) @ (p x w) then (r x bm) @ (bm x w);
bm, w multiples of 128, r padded to 8 sublanes by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _extend_embed_kernel(xi_ref, pi_ref, xb_ref, o_ref, *, kind: str,
                         gamma: float, degree: int):
    i = pl.program_id(0)
    xi = xi_ref[...]                    # (p, bm)
    xb = xb_ref[...]                    # (p, w)
    z = jax.lax.dot_general(xi, xb, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bm, w)
    if kind == "polynomial":
        k = (z + gamma) ** degree
    elif kind == "rbf":
        xn = jnp.sum(xi * xi, axis=0)[:, None]
        yn = jnp.sum(xb * xb, axis=0)[None, :]
        k = jnp.exp(-gamma * jnp.maximum(xn + yn - 2.0 * z, 0.0))
    else:  # linear
        k = z
    pi = pi_ref[...]                    # (r, bm)
    part = jax.lax.dot_general(pi, k, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (r, w)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part.astype(o_ref.dtype)


def extend_embed_call(X: jnp.ndarray, P: jnp.ndarray, Xb: jnp.ndarray,
                      kind: str, gamma: float, degree: int, row_tile: int,
                      interpret: bool) -> jnp.ndarray:
    """P @ kappa(X, Xb); X (p, n), P (r, n), Xb (p, w), n % row_tile == 0."""
    p, n = X.shape
    r = P.shape[0]
    w = Xb.shape[1]
    return pl.pallas_call(
        functools.partial(_extend_embed_kernel, kind=kind, gamma=gamma,
                          degree=degree),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.float32),
        grid=(n // row_tile,),
        in_specs=[
            pl.BlockSpec((p, row_tile), lambda i: (0, i)),
            pl.BlockSpec((r, row_tile), lambda i: (0, i)),
            pl.BlockSpec((p, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r, w), lambda i: (0, 0)),
        interpret=interpret,
    )(X, P, Xb)

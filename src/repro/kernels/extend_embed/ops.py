"""Public jit'd wrapper for the fused gram->projection stripe kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.extend_embed.extend_embed import extend_embed_call
from repro.kernels.extend_embed.ref import extend_embed_ref
from repro.kernels.registry import (KernelContract, KernelEntry,
                                    register_contract, register_kernel)


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def padded_shapes(n: int, r: int, w: int, row_tile: int = 256
                  ) -> tuple[int, int, int, int]:
    """(row_tile, n_pad, r_pad, w_pad) the kernel actually runs at.

    The single source of truth for the tiling: extend_embed_pallas pads
    with exactly these values, and serve/bench.py derives the fused
    engine's HBM byte count from them (each padded operand crosses HBM
    once — that IS the kernel's memory contract).
    """
    row_tile = min(row_tile, max(128, 1 << (n - 1).bit_length()))
    n_pad = -(-n // row_tile) * row_tile
    r_pad = -(-r // 8) * 8
    w_pad = -(-w // 128) * 128
    return row_tile, n_pad, r_pad, w_pad


def memory_contract(p: int, n: int, r: int, w: int, row_tile: int = 256
                    ) -> dict:
    """Declared HBM byte model for one fused serving stripe.

    X and P stream over the row-tile grid (each padded element crosses
    HBM once); the query block Xb and the (r, w) output stay
    VMEM-resident across the whole sweep and cross once each. These are
    the bytes serve/bench.py reports, cross-checked against the
    BlockSpecs by `repro.analysis` (rule C001).
    """
    row_tile, n_pad, r_pad, w_pad = padded_shapes(n, r, w, row_tile)
    hbm = 4.0 * (p * n_pad             # X (p, n_pad) streamed
                 + r_pad * n_pad       # P streamed
                 + p * w_pad           # Xb query block, resident
                 + r_pad * w_pad)      # embedded out, resident
    return {"row_tile": row_tile, "n_pad": n_pad, "r_pad": r_pad,
            "w_pad": w_pad, "hbm_bytes": hbm}


@functools.partial(jax.jit, static_argnames=("kind", "gamma", "degree",
                                             "row_tile", "interpret"))
def extend_embed_pallas(X: jnp.ndarray, P: jnp.ndarray, Xb: jnp.ndarray,
                        kind: str = "polynomial", gamma: float = 0.0,
                        degree: int = 2, row_tile: int = 256,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Fused serving stripe P @ kappa(X, Xb) -> (r, w), one executable.

    X (p, n) training data, P (r, n) projection Sigma^{-1/2} U^T, Xb (p, w)
    query block. Pads n to the row tile, w to 128 lanes, r to 8 sublanes.

    Padding is exact, not approximate: padded columns of X produce garbage
    gram ROWS (nonzero for rbf, where kappa(0, x) != 0) but the matching
    padded columns of P are zero, so they are annihilated in the
    contraction; padded w columns and padded r rows are sliced off.
    """
    interp = _is_cpu() if interpret is None else interpret
    p, n = X.shape
    r = P.shape[0]
    w = Xb.shape[1]
    row_tile, _, _, _ = padded_shapes(n, r, w, row_tile)
    Xp = _pad_to(X, 1, row_tile)
    Pp = _pad_to(_pad_to(P, 1, row_tile), 0, 8)
    Xbp = _pad_to(Xb, 1, 128)
    out = extend_embed_call(Xp, Pp, Xbp, kind, gamma, degree, row_tile,
                            interp)
    return out[:r, :w]


def _extend_embed_build(key, case):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (case["p"], case["n"]), jnp.float32)
    P = jax.random.normal(k2, (case["r"], case["n"]), jnp.float32)
    Xb = jax.random.normal(k3, (case["p"], case["w"]), jnp.float32)
    kw = {k: case[k] for k in ("kind", "gamma", "degree") if k in case}
    return (X, P, Xb), kw, kw


register_kernel(KernelEntry(
    name="extend_embed", op=extend_embed_pallas, ref=extend_embed_ref,
    cases=(
        {"p": 2, "n": 100, "r": 2, "w": 12},
        {"p": 19, "n": 555, "r": 3, "w": 64, "kind": "rbf", "gamma": 0.5},
        {"p": 7, "n": 1024, "r": 16, "w": 128},
        {"p": 3, "n": 97, "r": 5, "w": 1, "kind": "linear"},
        {"p": 2, "n": 250, "r": 2, "w": 23, "kind": "polynomial",
         "gamma": 1.0, "degree": 3},
    ),
    build=_extend_embed_build, rtol=2e-3, atol=2e-3))


def _extend_embed_declared(case: dict) -> dict:
    return memory_contract(case["p"], case["n"], case["r"], case["w"])


register_contract(KernelContract(name="extend_embed",
                                 declared=_extend_embed_declared))

"""Public jit'd wrapper for the fused gram->projection stripe kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.extend_embed.extend_embed import extend_embed_call


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def padded_shapes(n: int, r: int, w: int, row_tile: int = 256
                  ) -> tuple[int, int, int, int]:
    """(row_tile, n_pad, r_pad, w_pad) the kernel actually runs at.

    The single source of truth for the tiling: extend_embed_pallas pads
    with exactly these values, and serve/bench.py derives the fused
    engine's HBM byte count from them (each padded operand crosses HBM
    once — that IS the kernel's memory contract).
    """
    row_tile = min(row_tile, max(128, 1 << (n - 1).bit_length()))
    n_pad = -(-n // row_tile) * row_tile
    r_pad = -(-r // 8) * 8
    w_pad = -(-w // 128) * 128
    return row_tile, n_pad, r_pad, w_pad


@functools.partial(jax.jit, static_argnames=("kind", "gamma", "degree",
                                             "row_tile", "interpret"))
def extend_embed_pallas(X: jnp.ndarray, P: jnp.ndarray, Xb: jnp.ndarray,
                        kind: str = "polynomial", gamma: float = 0.0,
                        degree: int = 2, row_tile: int = 256,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Fused serving stripe P @ kappa(X, Xb) -> (r, w), one executable.

    X (p, n) training data, P (r, n) projection Sigma^{-1/2} U^T, Xb (p, w)
    query block. Pads n to the row tile, w to 128 lanes, r to 8 sublanes.

    Padding is exact, not approximate: padded columns of X produce garbage
    gram ROWS (nonzero for rbf, where kappa(0, x) != 0) but the matching
    padded columns of P are zero, so they are annihilated in the
    contraction; padded w columns and padded r rows are sliced off.
    """
    interp = _is_cpu() if interpret is None else interpret
    p, n = X.shape
    r = P.shape[0]
    w = Xb.shape[1]
    row_tile, _, _, _ = padded_shapes(n, r, w, row_tile)
    Xp = _pad_to(X, 1, row_tile)
    Pp = _pad_to(_pad_to(P, 1, row_tile), 0, 8)
    Xbp = _pad_to(Xb, 1, 128)
    out = extend_embed_call(Xp, Pp, Xbp, kind, gamma, degree, row_tile,
                            interp)
    return out[:r, :w]

"""Pure-jnp oracle for the fused K-means assignment kernel."""
import jax.numpy as jnp


def assign_ref(Y: jnp.ndarray, C: jnp.ndarray):
    """Y: (n, r) samples, C: (k, r) centroids.

    Returns (labels (n,) int32, min_d2 (n,) f32) with squared distances.
    """
    yn = jnp.sum(Y * Y, axis=1)[:, None]
    cn = jnp.sum(C * C, axis=1)[None, :]
    d2 = jnp.maximum(yn + cn - 2.0 * (Y @ C.T), 0.0)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)

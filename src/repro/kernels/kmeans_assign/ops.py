"""Public jit'd wrapper for the fused K-means assignment kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels.kmeans_assign.kmeans_assign import assign_call
from repro.kernels.kmeans_assign.ref import assign_ref
from repro.kernels.registry import KernelEntry, register_kernel


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def assign_pallas(Y: jnp.ndarray, C: jnp.ndarray, row_tile: int = 512,
                  interpret: bool | None = None):
    """Fused assignment: Y (n, r), C (k, r) -> (labels (n,), min_d2 (n,)).

    Pads n to the row tile, r to 128 lanes, k to 8 sublanes; padded rows are
    sliced off, padded centroids masked inside the kernel.
    """
    interp = _is_cpu() if interpret is None else interpret
    n, r = Y.shape
    k = C.shape[0]
    row_tile = min(row_tile, max(8, 1 << (n - 1).bit_length()))
    n_pad = -(-n // row_tile) * row_tile
    r_pad = -(-r // 128) * 128
    k_pad = -(-k // 8) * 8
    Yp = jnp.pad(Y, ((0, n_pad - n), (0, r_pad - r)))
    Cp = jnp.pad(C, ((0, k_pad - k), (0, r_pad - r)))
    labels, d2 = assign_call(Yp, Cp, k, row_tile, interp)
    return labels[:n], d2[:n]


def _assign_build(key, case):
    k1, k2 = jax.random.split(key)
    Y = jax.random.normal(k1, (case["n"], case["r"]), jnp.float32)
    C = jax.random.normal(k2, (case["k"], case["r"]), jnp.float32)
    return (Y, C), {}, {}


def _assign_compare(got, want, rtol, atol):
    # Distances must match tightly; labels can differ only on exact ties.
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=rtol, atol=atol)
    mism = np.asarray(got[0]) != np.asarray(want[0])
    assert mism.mean() < 0.01


register_kernel(KernelEntry(
    name="kmeans_assign", op=assign_pallas, ref=assign_ref,
    cases=({"n": 50, "r": 2, "k": 2}, {"n": 1000, "r": 2, "k": 7},
           {"n": 513, "r": 16, "k": 100}, {"n": 31, "r": 5, "k": 3}),
    build=_assign_build, rtol=1e-4, atol=1e-4,
    compare=_assign_compare))

"""Public jit'd wrapper for the fused K-means assignment kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels.kmeans_assign.kmeans_assign import assign_call
from repro.kernels.kmeans_assign.ref import assign_ref
from repro.kernels.registry import (KernelContract, KernelEntry,
                                    register_contract, register_kernel)


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def padded_shapes(n: int, r: int, k: int, row_tile: int = 512
                  ) -> tuple[int, int, int, int]:
    """(row_tile, n_pad, r_pad, k_pad) the kernel actually runs at — the
    single source of truth for the tiling (assign_pallas pads with
    exactly these values; memory_contract derives bytes from them)."""
    row_tile = min(row_tile, max(8, 1 << (n - 1).bit_length()))
    n_pad = -(-n // row_tile) * row_tile
    r_pad = -(-r // 128) * 128
    k_pad = -(-k // 8) * 8
    return row_tile, n_pad, r_pad, k_pad


def memory_contract(n: int, r: int, k: int, row_tile: int = 512) -> dict:
    """Declared HBM byte model for one fused assignment sweep: Y streams
    over the row-tile grid, the centroids stay VMEM-resident, and only
    the two (n,) outputs come back — the (n, k) distance matrix never
    leaves VMEM. Cross-checked against the BlockSpecs by
    `repro.analysis` (rule C001)."""
    row_tile, n_pad, r_pad, k_pad = padded_shapes(n, r, k, row_tile)
    hbm = 4.0 * (n_pad * r_pad         # Y streamed
                 + k_pad * r_pad       # centroids, resident
                 + n_pad               # labels out (int32)
                 + n_pad)              # min-d2 out (f32)
    return {"row_tile": row_tile, "n_pad": n_pad, "r_pad": r_pad,
            "k_pad": k_pad, "hbm_bytes": hbm}


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def assign_pallas(Y: jnp.ndarray, C: jnp.ndarray, row_tile: int = 512,
                  interpret: bool | None = None):
    """Fused assignment: Y (n, r), C (k, r) -> (labels (n,), min_d2 (n,)).

    Pads n to the row tile, r to 128 lanes, k to 8 sublanes; padded rows are
    sliced off, padded centroids masked inside the kernel.
    """
    interp = _is_cpu() if interpret is None else interpret
    n, r = Y.shape
    k = C.shape[0]
    row_tile, n_pad, r_pad, k_pad = padded_shapes(n, r, k, row_tile)
    Yp = jnp.pad(Y, ((0, n_pad - n), (0, r_pad - r)))
    Cp = jnp.pad(C, ((0, k_pad - k), (0, r_pad - r)))
    labels, d2 = assign_call(Yp, Cp, k, row_tile, interp)
    return labels[:n], d2[:n]


def _assign_build(key, case):
    k1, k2 = jax.random.split(key)
    Y = jax.random.normal(k1, (case["n"], case["r"]), jnp.float32)
    C = jax.random.normal(k2, (case["k"], case["r"]), jnp.float32)
    return (Y, C), {}, {}


def _assign_compare(got, want, rtol, atol):
    # Distances must match tightly; labels can differ only on exact ties.
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=rtol, atol=atol)
    mism = np.asarray(got[0]) != np.asarray(want[0])
    assert mism.mean() < 0.01


register_kernel(KernelEntry(
    name="kmeans_assign", op=assign_pallas, ref=assign_ref,
    cases=({"n": 50, "r": 2, "k": 2}, {"n": 1000, "r": 2, "k": 7},
           {"n": 513, "r": 16, "k": 100}, {"n": 31, "r": 5, "k": 3}),
    build=_assign_build, rtol=1e-4, atol=1e-4,
    compare=_assign_compare))


def _assign_declared(case: dict) -> dict:
    return memory_contract(case["n"], case["r"], case["k"])


register_contract(KernelContract(name="kmeans_assign",
                                 declared=_assign_declared))

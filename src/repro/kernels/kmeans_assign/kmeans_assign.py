"""Pallas TPU kernel: fused Lloyd assignment (distance + argmin).

Per iteration, K-means computes an (n, k) distance matrix only to take its
row-wise argmin. Fusing the -2 Y C^T matmul (MXU), the norm corrections and
the argmin (VPU) means the (n, k) intermediate never leaves VMEM: HBM
traffic drops from O(n*k + n*r) to O(n*r + n) per iteration, which is the
memory-bound term for the small-r regime of the paper (r = 2..16, k <= 100).

Tiling: grid over row tiles of Y; centroids (k, r) are tiny and pinned in
VMEM for the whole sweep. Tiles are (bm, r_pad) x (r_pad, k_pad) on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(y_ref, c_ref, lab_ref, d2_ref, *, k: int):
    y = y_ref[...]                      # (bm, r)
    c = c_ref[...]                      # (k_pad, r)
    z = jax.lax.dot_general(y, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bm, k_pad)
    yn = jnp.sum(y * y, axis=1)[:, None]
    cn = jnp.sum(c * c, axis=1)[None, :]
    d2 = jnp.maximum(yn + cn - 2.0 * z, 0.0)
    # Mask padded centroids out of the argmin.
    k_pad = d2.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, k_pad), 1)
    d2 = jnp.where(col < k, d2, jnp.inf)
    lab_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2_ref[...] = jnp.min(d2, axis=1)


def assign_call(Y: jnp.ndarray, C: jnp.ndarray, k: int, row_tile: int,
                interpret: bool):
    n, r = Y.shape
    k_pad = C.shape[0]
    return pl.pallas_call(
        functools.partial(_assign_kernel, k=k),
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)),
        grid=(n // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, r), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, r), lambda i: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((row_tile,), lambda i: (i,)),
                   pl.BlockSpec((row_tile,), lambda i: (i,))),
        interpret=interpret,
    )(Y, C)

"""Admission control: shed load BEFORE it poisons the tail latency.

An overloaded serving tier has exactly two choices: queue everything and
watch p99 blow through the SLO for *every* request, or reject the excess
at the front door and keep the admitted traffic's latency bounded. This
module is the second choice, two mechanisms deep:

queue-depth cap   each worker may hold at most `max_queue_depth` query
                  columns; a request that would push its routed worker
                  past the cap is shed (`ShedError`, reason
                  "queue-full"). The cap IS the latency bound: admitted
                  work never waits behind more than max_queue_depth
                  columns of compute, so admitted p99 stays within the
                  SLO by construction — the property the fleet soak
                  bench gates.

SLO breaker       `update(p99_ms)` feeds the tier-level p99 (merged
                  LatencyStats) back in; while it breaches `slo_ms` the
                  controller tightens the effective cap by
                  `shed_factor` (reason "slo-breach" sheds) until the
                  tail recovers — classic closed-loop load shedding:
                  the breach signal lags, so the breaker keeps shedding
                  harder than the static cap until the signal clears.

Shedding is typed (`ShedError`), never silent: the caller sees which
worker, what depth, which reason — a load balancer retries elsewhere, a
client backs off. Counters (admitted/shed per reason) are the bench's
shed-rate read-out, lock-guarded because submits race the breaker update.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.fleet.worker import FleetWorker


class ShedError(RuntimeError):
    """A request the fleet refused to enqueue (typed, never silent).

    reason is "queue-full" (static per-worker cap) or "slo-breach" (the
    breaker tightened the cap while tier p99 exceeds the SLO)."""

    def __init__(self, worker_id: str, depth: int, limit: int,
                 reason: str):
        self.worker_id = worker_id
        self.depth = int(depth)
        self.limit = int(limit)
        self.reason = reason
        super().__init__(
            f"shed ({reason}): worker {worker_id!r} queue depth {depth} "
            f"+ request would exceed limit {limit}")


class AdmissionController:
    """Per-worker queue caps + an SLO feedback breaker.

    max_queue_depth: admitted query columns a worker may queue (the
        static cap; sized so cap/throughput < the SLO budget).
    slo_ms: tier p99 target for the breaker (None disables feedback —
        the static cap still applies).
    shed_factor: multiplier on the cap while the breaker is open
        (0.5 = admit only half a queue until p99 recovers).
    """

    def __init__(self, max_queue_depth: int = 2048,
                 slo_ms: Optional[float] = None,
                 shed_factor: float = 0.5):
        if max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, "
                             f"got {max_queue_depth}")
        if not 0.0 < shed_factor <= 1.0:
            raise ValueError(f"shed_factor must be in (0, 1], "
                             f"got {shed_factor}")
        self.max_queue_depth = int(max_queue_depth)
        self.slo_ms = slo_ms
        self.shed_factor = float(shed_factor)
        self._lock = threading.Lock()
        self._breaker_open = False            # guarded-by: _lock
        self._last_p99_ms = 0.0               # guarded-by: _lock
        self._admitted = 0                    # guarded-by: _lock
        self._shed: Dict[str, int] = {}       # guarded-by: _lock

    # -- feedback --------------------------------------------------------

    def update(self, p99_ms: float) -> bool:
        """Feed the tier p99 back in; returns True while the breaker is
        open (tier p99 over SLO -> effective caps tightened)."""
        with self._lock:
            self._last_p99_ms = float(p99_ms)
            self._breaker_open = (self.slo_ms is not None
                                  and p99_ms > self.slo_ms)
            return self._breaker_open

    @property
    def breaker_open(self) -> bool:
        with self._lock:
            return self._breaker_open

    def effective_depth(self) -> int:
        """The cap currently enforced (tightened while the breaker is
        open, never below one bucket's worth of columns)."""
        with self._lock:
            open_ = self._breaker_open
        if not open_:
            return self.max_queue_depth
        return max(int(self.max_queue_depth * self.shed_factor), 1)

    # -- the gate --------------------------------------------------------

    def admit(self, worker: FleetWorker, width: int) -> FleetWorker:
        """Admit a `width`-column request onto `worker` or raise ShedError.

        Returns the worker so the fleet's submit reads
        `admission.admit(router.route(key), w).submit(Xq)`."""
        limit = self.effective_depth()
        depth = worker.depth()
        if depth + int(width) > limit:
            reason = "slo-breach" if self.breaker_open else "queue-full"
            with self._lock:
                self._shed[reason] = self._shed.get(reason, 0) + 1
            raise ShedError(worker.worker_id, depth, limit, reason)
        with self._lock:
            self._admitted += 1
        return worker

    # -- read-outs -------------------------------------------------------

    @property
    def admitted(self) -> int:
        with self._lock:
            return self._admitted

    @property
    def shed(self) -> int:
        with self._lock:
            return sum(self._shed.values())

    @property
    def shed_rate(self) -> float:
        """Shed requests / offered requests (0.0 before any traffic)."""
        with self._lock:
            shed = sum(self._shed.values())
            offered = self._admitted + shed
        return shed / offered if offered else 0.0

    def summary(self) -> Dict:
        """JSON-ready counters (the bench's overload section)."""
        with self._lock:
            shed = dict(self._shed)
            return {
                "max_queue_depth": self.max_queue_depth,
                "effective_depth": self.max_queue_depth if not
                self._breaker_open else max(
                    int(self.max_queue_depth * self.shed_factor), 1),
                "slo_ms": self.slo_ms,
                "breaker_open": self._breaker_open,
                "last_p99_ms": self._last_p99_ms,
                "admitted": self._admitted,
                "shed": sum(shed.values()),
                "shed_by_reason": shed,
                "shed_rate": (sum(shed.values()) /
                              (self._admitted + sum(shed.values()))
                              if self._admitted + sum(shed.values())
                              else 0.0),
            }

"""AdaptiveWaitController: close the batching-vs-deadline loop per bucket.

`max_wait_ms` is the one knob with a real trade behind it: wait longer
and requests coalesce into bigger (cheaper per query) buckets; wait less
and every request keeps more deadline headroom. PR 5's per-bucket latency
breakdown (`LatencyStats.by_bucket`) is exactly the signal that says
which way each bucket should move — a fat p95 in ONE bucket is an
under-headroomed deadline there, not a fleet-wide problem — and the
per-bucket deadline override (`AsyncBatcher.set_bucket_wait`) is the
actuator. This module is the loop between them, AIMD-shaped like every
stable congestion controller:

    p95(bucket) >  budget       multiplicative DECREASE of the bucket's
                                wait (shed batching, buy headroom NOW —
                                breaches are expensive and lag the knob)
    p95(bucket) <= recover *    additive INCREASE (creep batching back
                   budget       one step per control period — cheap to
                                undo if the tail comes back)

where budget = slo_ms * headroom: the controller steers the bucket's p95
toward a fraction of the SLO, not the SLO itself, so compute jitter
lands in margin instead of violations. Decisions are per (worker,
bucket), only on fresh samples (a bucket that saw no traffic since the
last step holds), and bounded to [min_wait_ms, max_wait_ms] so a noisy
window can never drive the deadline to zero (no batching at all) or to
the SLO (no headroom at all).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.fleet.worker import FleetWorker


class AdaptiveWaitController:
    """AIMD controller over per-bucket flush deadlines.

    slo_ms: the latency SLO the fleet serves under.
    headroom: fraction of the SLO the per-bucket p95 may use before the
        controller trades batching away (budget = slo_ms * headroom).
    recover: fraction of the budget below which batching creeps back.
    min_wait_ms / max_wait_ms: hard bounds on any bucket's deadline.
    increase_ms / decrease_factor: the AI / MD step sizes.
    min_samples: fresh requests a bucket needs since the last step
        before its p95 is trusted (tiny windows are all jitter).
    """

    def __init__(self, slo_ms: float, *, headroom: float = 0.5,
                 recover: float = 0.5, min_wait_ms: float = 0.25,
                 max_wait_ms: float = 50.0, increase_ms: float = 0.5,
                 decrease_factor: float = 0.5, min_samples: int = 8):
        if slo_ms <= 0:
            raise ValueError(f"slo_ms must be positive, got {slo_ms!r}")
        if not 0.0 < headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], got {headroom}")
        if not 0.0 < decrease_factor < 1.0:
            raise ValueError(f"decrease_factor must be in (0, 1), "
                             f"got {decrease_factor}")
        if min_wait_ms <= 0 or max_wait_ms < min_wait_ms:
            raise ValueError(f"need 0 < min_wait_ms <= max_wait_ms, got "
                             f"{min_wait_ms} / {max_wait_ms}")
        self.slo_ms = float(slo_ms)
        self.budget_ms = float(slo_ms) * float(headroom)
        self.recover = float(recover)
        self.min_wait_ms = float(min_wait_ms)
        self.max_wait_ms = float(max_wait_ms)
        self.increase_ms = float(increase_ms)
        self.decrease_factor = float(decrease_factor)
        self.min_samples = int(min_samples)
        # (worker_id, bucket) -> requests seen at the last decision, so a
        # step only acts on buckets with fresh traffic. Single-writer
        # (the fleet's control loop), so no lock of its own.
        self._seen: Dict[Tuple[str, int], int] = {}

    def step(self, worker: FleetWorker) -> List[Dict]:
        """One control period for one worker; returns the adjustments.

        Each row: {worker, bucket, requests, p95_ms, wait_before_ms,
        wait_after_ms, action} with action in decrease/increase/hold —
        the rollout-timeline-style trace the fleet bench records.
        """
        sched = worker.scheduler()
        out: List[Dict] = []
        for bucket, hist in sorted(worker.latency.by_bucket.items()):
            key = (worker.worker_id, int(bucket))
            fresh = hist.n - self._seen.get(key, 0)
            before = sched.bucket_wait(bucket)
            if fresh < self.min_samples:
                continue                      # no fresh signal: hold
            self._seen[key] = hist.n
            p95 = hist.percentile(95.0)
            if p95 > self.budget_ms:
                after = max(before * self.decrease_factor,
                            self.min_wait_ms)
                action = "decrease"
            elif p95 <= self.budget_ms * self.recover:
                after = min(before + self.increase_ms, self.max_wait_ms)
                action = "increase"
            else:
                after, action = before, "hold"
            if after != before:
                sched.set_bucket_wait(bucket, after)
            out.append({"worker": worker.worker_id, "bucket": int(bucket),
                        "requests": int(hist.n), "fresh": int(fresh),
                        "p95_ms": float(p95),
                        "wait_before_ms": float(before),
                        "wait_after_ms": float(after), "action": action})
        return out

    def rebind(self) -> None:
        """Forget per-worker sample watermarks (after a worker set
        change or a latency-stats reset, stale watermarks would make
        every bucket look sample-starved or over-fresh)."""
        self._seen.clear()

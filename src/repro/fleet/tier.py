"""Fleet: the multi-worker front door — route, admit, serve, roll out.

One object owns the whole tier:

    store ---------- shared VersionStore (the artifact bus on disk)
    workers[N] ----- FleetWorker replicas, each a private ModelRegistry
                     pinned to a version
    router --------- least-loaded / consistent-hash request placement
    admission ------ per-worker queue caps + SLO breaker (ShedError)
    wait_controller- AIMD per-bucket max_wait_ms tuning
    rollouts ------- canary-then-promote version rollouts

`submit(Xq, key=)` is the serving call: route -> admit (may raise
ShedError) -> worker enqueue; `control()` is one control-loop period:
poll every worker's deadline, merge per-worker LatencyStats into the
tier summary, feed tier p99 to the admission breaker and the per-bucket
breakdowns to the wait controller. The loop is cooperative (the caller
— a bench, a CLI, an event loop — owns the cadence), exactly like
AsyncBatcher.poll(): deterministic under test, pump-threaded in a real
deployment by calling start() on each worker's scheduler.

Bit-identity note: routing only decides WHICH replica runs a request,
and every replica serves an identical artifact version between
rollouts, so results are independent of the routing policy — the same
invariance micro-batching already guarantees within one worker.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.fleet.admission import AdmissionController
from repro.fleet.controller import AdaptiveWaitController
from repro.fleet.rollout import RolloutManager, RolloutReport
from repro.fleet.router import Router
from repro.fleet.worker import FleetWorker
from repro.serve.latency import LatencyStats
from repro.serve.versions import VersionStore


class Fleet:
    """N serving replicas behind one admission-controlled front door.

    store / store_root: the shared VersionStore (must hold >= 1 version).
    n_workers: replica count.
    routing: "least-loaded" | "hash" (see fleet/router.py).
    slo_ms: the tier's latency SLO — drives per-request violation
        accounting on every worker, the admission breaker, AND the
        adaptive wait controller's budget.
    max_queue_depth: admission cap per worker (query columns).
    max_wait_ms: initial flush deadline for every worker/bucket.
    rollout_budget_ms: canary post-swap p95 gate (default: slo_ms).
    adaptive_wait: False disables the wait controller (the knob stays
        at max_wait_ms everywhere).
    worker_kwargs: forwarded to every FleetWorker (clock=, block=,
        policy=, ... — all replicas get the same construction).
    """

    def __init__(self, store, n_workers: int = 2, *,
                 routing: str = "least-loaded",
                 slo_ms: float = 250.0,
                 max_queue_depth: int = 2048,
                 max_wait_ms: float = 2.0,
                 shed_factor: float = 0.5,
                 rollout_budget_ms: Optional[float] = None,
                 adaptive_wait: bool = True,
                 version: Optional[int] = None,
                 **worker_kwargs):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.store = store if isinstance(store, VersionStore) \
            else VersionStore(str(store))
        self.slo_ms = float(slo_ms)
        self.workers: List[FleetWorker] = [
            FleetWorker(f"w{i}", self.store, version=version,
                        max_wait_ms=max_wait_ms, slo_ms=slo_ms,
                        **worker_kwargs)
            for i in range(int(n_workers))]
        self.router = Router(self.workers, policy=routing)
        self.admission = AdmissionController(
            max_queue_depth=max_queue_depth, slo_ms=slo_ms,
            shed_factor=shed_factor)
        self.wait_controller = (
            AdaptiveWaitController(slo_ms, max_wait_ms=max(
                max_wait_ms * 8, max_wait_ms)) if adaptive_wait else None)
        self.rollouts = RolloutManager(
            self.workers, self.store,
            budget_ms=(rollout_budget_ms if rollout_budget_ms is not None
                       else self.slo_ms))

    # -- serving ---------------------------------------------------------

    def submit(self, Xq, key: Optional[str] = None):
        """Route + admit + enqueue one request; returns its Future.

        Raises ShedError when admission refuses (the caller's backoff
        signal — nothing was enqueued anywhere)."""
        worker = self.router.route(key)
        return self.admission.admit(worker, Xq.shape[1]).submit(Xq)

    def poll(self) -> int:
        """Fire every worker's deadline trigger; returns requests run."""
        return sum(w.poll() for w in self.workers)

    def flush(self) -> int:
        """Force-flush every worker (drain the tier)."""
        return sum(w.flush() for w in self.workers)

    def depth(self) -> int:
        """Total queued query columns across the tier."""
        return sum(w.depth() for w in self.workers)

    def control(self) -> Dict:
        """One control period: poll deadlines, close both feedback loops.

        Merges per-worker LatencyStats into the tier summary, feeds the
        tier p99 to the admission breaker and the per-bucket breakdowns
        to the wait controller. Returns {"completed", "p99_ms",
        "breaker_open", "wait_adjustments"} — the soak bench's
        control-loop trace."""
        completed = self.poll()
        stats = self.latency()
        p99 = stats.total.percentile(99.0)
        breaker = self.admission.update(p99)
        adjust: List[Dict] = []
        if self.wait_controller is not None:
            for w in self.workers:
                adjust.extend(self.wait_controller.step(w))
        return {"completed": completed, "p99_ms": p99,
                "breaker_open": breaker, "wait_adjustments": adjust}

    # -- monitoring ------------------------------------------------------

    def latency(self) -> LatencyStats:
        """Tier-level aggregate: exact merge of every worker's stats."""
        return LatencyStats.merged([w.latency for w in self.workers])

    def latency_summary(self) -> Dict:
        return self.latency().summary()

    def stats(self) -> Dict:
        """JSON-ready tier health: per-worker rows + admission counters."""
        return {
            "workers": [w.stats() for w in self.workers],
            "admission": self.admission.summary(),
            "versions": {w.worker_id: w.version for w in self.workers},
            "latency": self.latency_summary(),
        }

    # -- lifecycle -------------------------------------------------------

    def rollout(self, version: Optional[int] = None,
                **kwargs) -> Optional[RolloutReport]:
        """Canary-then-promote the fleet to `version` (default latest)."""
        return self.rollouts.rollout(version, **kwargs)

    def sync(self) -> Optional[RolloutReport]:
        """Follower mode: rollout iff the store has a newer version."""
        return self.rollout()

    def stop(self) -> int:
        """Drain and retire every worker, release all pins; returns the
        requests the final drains flushed."""
        return sum(w.stop() for w in self.workers)

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc_info) -> None:
        self.stop()

"""FleetWorker: one serving replica — registry + pinned version + queue.

A worker is process-shaped: it owns a private `ModelRegistry` (one row),
talks to the rest of the fleet ONLY through the shared `VersionStore` on
disk (the artifact bus — this is what makes the same object runnable as N
threads in one process for tests/CI or as N real processes behind a
socket front door), and records a pin refcount (`VersionStore.pin`) for
whichever version it currently serves, so the store's GC can never delete
an artifact a replica still serves or may roll back to.

Lifecycle:

    FleetWorker(id, store)   load + pin the store's latest (or a pinned
                             `version=`) into the private registry
    submit(Xq) -> Future     enqueue on the worker's AsyncBatcher (the
                             router/admission tier in front decides WHICH
                             worker; the worker never sheds on its own)
    poll()/flush()           deadline-driven / forced flush passthrough
    sync() -> bool           poll the store: swap to latest if newer
                             (the follower path of a fleet-wide rollout)
    swap_to(version)         warm hot-swap to a pinned version — the
                             canary/promote/rollback primitive; re-pins
                             atomically (pin new BEFORE unpin old, so the
                             store never sees a moment where neither is
                             protected)
    stop()                   drain + retire the scheduler, release pins

The worker deliberately adds no locking of its own around serving: the
registry row flip (`ModelRegistry.swap`) and the scheduler queue already
carry the machine-checked lock contracts (see repro.analysis L-rules);
the worker's only mutable state — the pinned version — is guarded here.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

import numpy as np

from repro.serve.registry import ModelRegistry, SwapReport
from repro.serve.scheduler import AsyncBatcher
from repro.serve.versions import VersionStore


class FleetWorker:
    """One serving replica over a shared VersionStore.

    worker_id: stable identity — the pin-refcount owner name and the
        consistent-hash ring anchor, so it must be unique fleet-wide and
        survive restarts for hash stability.
    version: pin this version instead of the store's latest.
    max_wait_ms / slo_ms / clock / batcher kwargs go to the worker's
        AsyncBatcher (every worker of a fleet gets the same ones).
    """

    def __init__(self, worker_id: str, store: VersionStore, *,
                 version: Optional[int] = None,
                 max_wait_ms: float = 5.0,
                 slo_ms: Optional[float] = None,
                 clock=None, **batcher_kwargs):
        self.worker_id = str(worker_id)
        self.store = store
        self.registry = ModelRegistry()
        self._name = "served"                 # the single registry row
        v = version if version is not None else store.latest()
        if v is None:
            raise FileNotFoundError(
                f"worker {worker_id!r}: no versions under {store.root}; "
                f"publish one before starting the fleet")
        # Pin BEFORE load: between latest() and load() a concurrent GC
        # could sweep the version; the pin makes the read safe (and a
        # pin on a just-GC'ed version raises loudly instead of serving
        # a half-deleted artifact).
        store.pin(v, self.worker_id)
        self.registry.load_version(self._name, str(store.root), version=v)
        self._version = v                     # guarded-by: _lock
        self._lock = threading.Lock()
        kwargs: Dict = dict(batcher_kwargs)
        kwargs["max_wait_ms"] = max_wait_ms
        kwargs["slo_ms"] = slo_ms
        if clock is not None:
            kwargs["clock"] = clock
        self._scheduler_kwargs = kwargs
        self.registry.scheduler(self._name, **kwargs)

    # -- serving ---------------------------------------------------------

    def scheduler(self) -> AsyncBatcher:
        """The CURRENT AsyncBatcher (hot-swaps retire old handles)."""
        return self.registry.scheduler(self._name)

    def submit(self, Xq):
        """Enqueue one request; the fleet front door calls this after
        routing + admission."""
        return self.scheduler().submit(Xq)

    def poll(self) -> int:
        return self.scheduler().poll()

    def flush(self) -> int:
        return self.scheduler().flush()

    def depth(self) -> int:
        """Queued query columns — the router's load signal and the
        admission controller's shed signal."""
        return self.scheduler().pending_width

    @property
    def latency(self):
        """The worker's LatencyStats (survives hot-swaps by design)."""
        return self.scheduler().latency

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    # -- rollout primitives ---------------------------------------------

    def sync(self) -> Optional[SwapReport]:
        """Follow the store: swap to latest() when it is newer.

        Returns the SwapReport when a swap happened, None otherwise —
        the polling-follower path (a fleet-wide rollout is this, ordered
        canary-first by the RolloutManager)."""
        latest = self.store.latest()
        if latest is None or latest == self.version:
            return None
        return self.swap_to(latest)

    def swap_to(self, version: int) -> SwapReport:
        """Warm hot-swap this replica to a pinned `version`.

        Pin-new -> load -> registry.swap (drains in-flight requests into
        the outgoing model; zero stranded futures by the swap contract)
        -> unpin-old. Swapping to the current version is a cheap no-op
        shaped as a swap (idempotent promote)."""
        version = int(version)
        self.store.pin(version, self.worker_id)
        model = self.store.load(version)
        report = self.registry.swap(self._name, model, version=version)
        with self._lock:
            old, self._version = self._version, version
        if old != version:
            self.store.unpin(old, self.worker_id)
        return report

    def stop(self) -> int:
        """Retire the replica: drain the scheduler, release the pin.
        Returns the requests the final drain flushed."""
        drained = self.scheduler().stop()
        self.store.unpin(self.version, self.worker_id)
        return drained

    # -- monitoring ------------------------------------------------------

    def stats(self) -> Dict:
        """One JSON-ready health row (the fleet bench's per-worker dump)."""
        lat = self.latency
        return {
            "worker_id": self.worker_id,
            "version": self.version,
            "depth": self.depth(),
            "requests": lat.requests,
            "p95_ms": lat.total.percentile(95.0),
            "slo_violations": lat.slo_violations,
        }

    def probe_p95_ms(self, n_requests: int = 8, width: int = 8,
                     seed: int = 0) -> float:
        """Drive `n_requests` synthetic probes through THIS replica and
        return their end-to-end p95 (ms), measured on the worker's own
        clock. This is the canary gate's default health signal: it runs
        post-swap, through the real serving path (warmed executables),
        and touches only this worker."""
        from repro.serve.latency import Histogram

        rng = np.random.RandomState(seed)
        p = self.registry.get(self._name).spec.p
        clock = self.scheduler().clock
        hist = Histogram()
        for _ in range(int(n_requests)):
            t0 = clock()
            fut = self.submit(rng.randn(p, width).astype(np.float32))
            self.flush()
            fut.result()
            hist.record((clock() - t0) * 1e3)
        return hist.percentile(95.0)

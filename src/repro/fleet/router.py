"""Request routing over fleet workers: least-loaded and consistent-hash.

Two policies, picked per Router (the fleet front door owns exactly one):

least-loaded   route every request to the worker with the smallest queue
               depth (pending query columns), ties broken by ring order —
               the throughput policy: keeps all replicas' batching windows
               evenly fed, so no worker's bucket sits half-full while
               another's overflows.

hash           consistent hashing of a caller-supplied routing key onto a
               ring of virtual nodes — the affinity policy: the same key
               always lands on the same worker (session/cache locality),
               and adding or removing ONE worker remaps only ~1/N of the
               key space instead of reshuffling everything. Hashes are
               blake2b, never Python's hash(): routing must be stable
               across processes and PYTHONHASHSEED.

The worker set is mutable (a fleet may retire a replica), so membership
is lock-guarded and the hash ring is rebuilt on change; routing itself
reads an immutable snapshot of the ring — the machine-checked guarded-by
contract below is what keeps a rebuild from racing a route.
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from typing import List, Optional, Sequence, Tuple

from repro.fleet.worker import FleetWorker

POLICIES = ("least-loaded", "hash")


def _hash64(s: str) -> int:
    """Stable 64-bit point on the ring (blake2b, process-independent)."""
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8)
                          .digest(), "big")


class Router:
    """Route requests to one of N FleetWorkers.

    policy: "least-loaded" (default) or "hash".
    vnodes: virtual nodes per worker on the hash ring — more vnodes =
        smoother key-space split (64 keeps the max/min worker share
        within ~2x for small fleets).
    """

    def __init__(self, workers: Sequence[FleetWorker],
                 policy: str = "least-loaded", vnodes: int = 64):
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"have {POLICIES}")
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.policy = policy
        self.vnodes = int(vnodes)
        self._lock = threading.Lock()
        self._workers: List[FleetWorker] = []         # guarded-by: _lock
        self._ring: List[Tuple[int, int]] = []        # guarded-by: _lock
        for w in workers:
            self.add(w)

    # -- membership ------------------------------------------------------

    def add(self, worker: FleetWorker) -> None:
        with self._lock:
            if any(w.worker_id == worker.worker_id for w in self._workers):
                raise ValueError(
                    f"duplicate worker id {worker.worker_id!r} on the "
                    f"ring; ids are the hash anchors and must be unique")
            self._workers.append(worker)
            self._ring = self._build_ring(self._workers)

    def remove(self, worker_id: str) -> FleetWorker:
        with self._lock:
            for i, w in enumerate(self._workers):
                if w.worker_id == worker_id:
                    self._workers.pop(i)
                    self._ring = self._build_ring(self._workers)
                    return w
        raise KeyError(f"no worker {worker_id!r} on the ring")

    @property
    def workers(self) -> List[FleetWorker]:
        """Snapshot of the current membership (copy; safe to iterate)."""
        with self._lock:
            return list(self._workers)

    def _build_ring(self, workers: List[FleetWorker]
                    ) -> List[Tuple[int, int]]:
        """Sorted (point, worker_index) ring over vnodes per worker."""
        ring = []
        for i, w in enumerate(workers):
            for v in range(self.vnodes):
                ring.append((_hash64(f"{w.worker_id}#{v}"), i))
        ring.sort()
        return ring

    # -- routing ---------------------------------------------------------

    def route(self, key: Optional[str] = None) -> FleetWorker:
        """Pick the worker for one request.

        `key` is required under the hash policy (it IS the affinity) and
        ignored under least-loaded."""
        with self._lock:
            workers = list(self._workers)
            ring = self._ring
        if not workers:
            raise RuntimeError("no workers on the ring")
        if self.policy == "hash":
            if key is None:
                raise ValueError("hash routing needs a routing key")
            point = _hash64(str(key))
            # First vnode clockwise from the key's point (wraparound).
            i = bisect.bisect_right(ring, (point, len(workers)))
            return workers[ring[i % len(ring)][1]]
        # Least-loaded: min depth, ties to the lowest index so repeated
        # routing over an idle fleet is deterministic.
        return min(workers, key=lambda w: (w.depth(), w.worker_id))

"""Fleet-wide rollout: canary-then-promote with SLO-gated rollback.

A single-process hot-swap (PR 4) already makes one replica's flip safe;
a fleet needs an ORDER. The state machine here is the standard one:

    idle -> canary     ONE worker (the canary) warm-swaps to the target
                       version; everyone else keeps serving the old one
    canary -> probing  post-swap traffic is driven through the canary
                       and its p95 measured — through the REAL serving
                       path, warmed executables, on the canary only
    probing -> promoting   p95 <= budget: the remaining workers swap,
                           one by one (each is a warm swap, so the
                           fleet never has a cold replica)
    probing -> rolled-back p95 > budget: the canary swaps BACK to the
                           version it came from; nobody else ever saw
                           the bad version
    promoting -> done

Every transition is a warm `FleetWorker.swap_to` — in-flight requests
drain into the model that accepted them, so a rollout (or a rollback)
strands zero futures; the soak bench re-asserts it. Version pins make
the rollback always possible: the canary's OLD version stays pinned by
every not-yet-promoted worker, so no GC between canary and verdict can
delete the escape hatch.

The probe is injectable (`probe=`) because the gate is POLICY: the
default drives synthetic requests via `FleetWorker.probe_p95_ms`; a real
deployment would point it at shadow traffic; tests inject verdicts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.fleet.worker import FleetWorker
from repro.serve.versions import VersionStore

STATES = ("idle", "canary", "probing", "promoting", "done", "rolled-back")


@dataclasses.dataclass
class RolloutReport:
    """What one rollout did (the bench's rollout-timeline section)."""
    version: int                      # target version
    old_versions: Dict[str, int]      # worker_id -> version before
    canary_id: str
    canary_p95_ms: float              # the gate measurement
    budget_ms: float                  # promotion threshold
    promoted: bool                    # False = rolled back
    state: str                        # terminal state: done | rolled-back
    timeline: List[Tuple[str, float]]  # (state, seconds since start)
    wall_s: float
    swaps: Dict[str, Dict] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["timeline"] = [[s, t] for s, t in self.timeline]
        return d


class RolloutManager:
    """Drive canary-then-promote rollouts over a worker set.

    workers: the fleet's replicas; the FIRST is the canary by default
        (deterministic — rollouts are reproducible in tests).
    store: the shared VersionStore (targets default to its latest()).
    budget_ms: post-swap canary p95 threshold gating promotion.
    probe: callable(worker) -> p95_ms; defaults to the worker's
        synthetic self-probe.
    """

    def __init__(self, workers: Sequence[FleetWorker], store: VersionStore,
                 *, budget_ms: float,
                 probe: Optional[Callable[[FleetWorker], float]] = None):
        if not workers:
            raise ValueError("rollout needs at least one worker")
        self.workers = list(workers)
        self.store = store
        self.budget_ms = float(budget_ms)
        self.probe = probe if probe is not None \
            else (lambda w: w.probe_p95_ms())
        self.state = "idle"
        self.history: List[RolloutReport] = []

    def rollout(self, version: Optional[int] = None,
                canary: Optional[FleetWorker] = None,
                probe: Optional[Callable[[FleetWorker], float]] = None
                ) -> Optional[RolloutReport]:
        """Roll the fleet to `version` (default: store latest).

        Returns None when every worker already serves the target (a
        follower poll loop calls this unconditionally); otherwise the
        RolloutReport with the terminal state. Exactly one rollout runs
        at a time by construction — the manager is the fleet's single
        control loop, same single-writer discipline as RetrainWorker.
        """
        target = int(version if version is not None
                     else (self.store.latest() or 0))
        if target == 0:
            raise FileNotFoundError(f"no versions under {self.store.root}")
        old = {w.worker_id: w.version for w in self.workers}
        if all(v == target for v in old.values()):
            return None
        canary = canary if canary is not None else self.workers[0]
        probe = probe if probe is not None else self.probe
        t0 = time.perf_counter()
        timeline: List[Tuple[str, float]] = []
        swaps: Dict[str, Dict] = {}

        def enter(state: str) -> None:
            self.state = state
            timeline.append((state, time.perf_counter() - t0))

        def swap(worker: FleetWorker, v: int) -> None:
            rep = worker.swap_to(v)
            swaps[f"{worker.worker_id}->v{v}"] = {
                "flip_ms": rep.flip_ms, "warm_s": rep.warm_s,
                "drained_requests": rep.drained_requests}

        canary_old = canary.version
        # The canary's swap releases ITS pin on the outgoing version; on
        # a single-worker fleet nothing else would protect the rollback
        # target from a concurrent GC between swap and verdict. The
        # manager holds its own pin across the decision window.
        guard = f"rollout-guard-{canary.worker_id}"
        self.store.pin(canary_old, guard)
        try:
            enter("canary")
            swap(canary, target)
            enter("probing")
            p95 = float(probe(canary))
            if p95 > self.budget_ms:
                # Breach: the canary returns to the exact version it
                # left — still pinned by the guard (and by every
                # not-yet-promoted worker), so the load cannot fail.
                swap(canary, canary_old)
                enter("rolled-back")
                report = RolloutReport(
                    version=target, old_versions=old,
                    canary_id=canary.worker_id, canary_p95_ms=p95,
                    budget_ms=self.budget_ms, promoted=False,
                    state="rolled-back", timeline=timeline,
                    wall_s=time.perf_counter() - t0, swaps=swaps)
                self.history.append(report)
                return report
            enter("promoting")
            for w in self.workers:
                if w is not canary and w.version != target:
                    swap(w, target)
            enter("done")
        finally:
            self.store.unpin(canary_old, guard)
        report = RolloutReport(
            version=target, old_versions=old, canary_id=canary.worker_id,
            canary_p95_ms=p95, budget_ms=self.budget_ms, promoted=True,
            state="done", timeline=timeline,
            wall_s=time.perf_counter() - t0, swaps=swaps)
        self.history.append(report)
        return report

"""Fleet tier: N serving workers behind one admission-controlled door.

The single-process serving stack (repro.serve) ends at one ModelRegistry
in one process. This package is the tier above it:

    worker.py      FleetWorker — one replica: a private ModelRegistry
                   pinned to a VersionStore version (pin-before-load
                   closes the publish/GC race)
    router.py      Router — least-loaded or consistent-hash placement
    admission.py   AdmissionController / ShedError — queue-depth caps +
                   SLO breaker; shed at the door, keep admitted p99
                   bounded
    controller.py  AdaptiveWaitController — AIMD per-bucket max_wait_ms
                   tuning off the per-bucket latency breakdown
    rollout.py     RolloutManager — canary-then-promote version rollouts
                   gated on post-swap p95, rollback on breach
    tier.py        Fleet — the front door composing all of the above
    bench.py       benchmark_fleet — the gated soak bench

Workers communicate ONLY through the shared VersionStore on disk — no
in-memory channel — so the in-process topology used by tests and benches
is honestly the multi-process one.
"""
from repro.fleet.admission import AdmissionController, ShedError
from repro.fleet.bench import benchmark_fleet
from repro.fleet.controller import AdaptiveWaitController
from repro.fleet.rollout import RolloutManager, RolloutReport
from repro.fleet.router import Router
from repro.fleet.tier import Fleet
from repro.fleet.worker import FleetWorker

__all__ = [
    "AdaptiveWaitController",
    "AdmissionController",
    "Fleet",
    "FleetWorker",
    "RolloutManager",
    "RolloutReport",
    "Router",
    "ShedError",
    "benchmark_fleet",
]

"""Fleet soak bench: q/s vs p99 vs worker count, shed-rate, rollouts.

The "fleet" section of BENCH_serve.json — the deliverable that turns the
serving tier's three claims into gated numbers:

  sweep      sustained request traffic against 1..N-worker fleets (pump
             threads running, so replica flushes overlap in real
             threads): tier q/s and merged p50/p95/p99 per worker count;
  overload   a flood far past the per-worker admission caps: shed-rate
             MUST exceed zero while the ADMITTED requests' p99 stays
             within the SLO (both asserted here, then gated vs the
             baseline) — the whole point of shedding;
  rollout    a canary-then-promote to a fresh version under pending
             traffic (zero stranded futures asserted), then a rollout
             whose canary probe breaches the budget — rolled back, the
             prior version restored fleet-wide (asserted);
  adaptive   the wait controller's per-bucket adjustment trace + final
             deadlines, so the batching-vs-headroom loop is observable.

Like every bench here, compile cost is paid in a warmup pass per worker
(each replica owns its executables — that is what makes it a replica)
and wall numbers come from steady state.
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.fleet.admission import ShedError
from repro.fleet.tier import Fleet
from repro.serve.artifact import FittedModel
from repro.serve.versions import VersionStore


def _warm(fleet: Fleet, p: int, max_bucket: int) -> None:
    """Compile every reachable bucket executable on every replica."""
    for w in fleet.workers:
        batcher = w.scheduler().batcher
        bsz = batcher.min_bucket
        while bsz <= max_bucket:
            batcher.assign_batch(np.zeros((p, bsz), np.float32))
            bsz *= 2
        batcher.reset_stats()


def _drive(fleet: Fleet, queries: np.ndarray, widths: np.ndarray,
           control_every: int = 16) -> Dict:
    """Submit one request per width, cooperatively closing the control
    loops; returns wall time, completion and shed counts."""
    futures: List = []
    shed = 0
    off = 0
    t0 = time.perf_counter()
    for i, w in enumerate(widths):
        try:
            futures.append(fleet.submit(queries[:, off:off + int(w)]))
        except ShedError:
            shed += 1
        off += int(w)
        if (i + 1) % control_every == 0:
            fleet.control()
    fleet.flush()
    for f in futures:
        f.result()
    wall = time.perf_counter() - t0
    return {"futures": futures, "shed": shed, "wall_s": wall,
            "admitted": len(futures)}


def benchmark_fleet(model: FittedModel,
                    worker_counts: Sequence[int] = (1, 2),
                    n_requests: int = 192,
                    width_range: Sequence[int] = (1, 64),
                    max_wait_ms: float = 2.0,
                    slo_ms: float = 250.0,
                    overload_depth: int = 64,
                    key: Optional[jax.Array] = None,
                    max_bucket: int = 256,
                    **worker_kwargs) -> Dict:
    """Run the soak phases against a temporary VersionStore; returns the
    "fleet" bench dict (schema in the module docstring)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    rng = np.random.RandomState(
        int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    lo, hi = int(width_range[0]), int(width_range[1])
    widths = rng.randint(lo, hi + 1, size=int(n_requests))
    queries = rng.randn(model.spec.p, int(widths.sum())).astype(np.float32)
    p = model.spec.p

    out: Dict = {"mode": "fleet", "n_requests": int(n_requests),
                 "width_range": [lo, hi], "max_wait_ms": float(max_wait_ms),
                 "slo_ms": float(slo_ms), "routing": "least-loaded"}

    with tempfile.TemporaryDirectory() as tmp:
        store = VersionStore(tmp)
        store.publish(model)

        # -- sweep: q/s + merged percentiles per worker count ------------
        sweep = []
        for n_workers in worker_counts:
            fleet = Fleet(store, n_workers=int(n_workers),
                          slo_ms=slo_ms, max_wait_ms=max_wait_ms,
                          max_queue_depth=1 << 30,   # sweep never sheds
                          max_bucket=max_bucket, **worker_kwargs)
            _warm(fleet, p, max_bucket)
            for w in fleet.workers:          # replica flushes overlap in
                w.scheduler().start()        # real pump threads
            run = _drive(fleet, queries, widths)
            lat = fleet.latency()
            # Final controller step + per-bucket deadlines. Reported as
            # dicts/counters, never trace lists: median_benches merges
            # lists positionally, and a trace's length is timing-
            # dependent across passes.
            adjust = ([a for w_ in fleet.workers
                       for a in fleet.wait_controller.step(w_)]
                      if fleet.wait_controller is not None else [])
            waits = {w_.worker_id:
                     {str(b): w_.scheduler().bucket_wait(b)
                      for b in sorted(w_.latency.by_bucket)}
                     for w_ in fleet.workers}
            fleet.stop()
            assert run["shed"] == 0, "sweep fleet must not shed"
            sweep.append({
                "workers": int(n_workers),
                "queries": int(widths.sum()),
                "wall_s": run["wall_s"],
                "queries_per_sec": float(widths.sum()) / run["wall_s"],
                "p50_ms": lat.total.percentile(50.0),
                "p95_ms": lat.total.percentile(95.0),
                "p99_ms": lat.total.percentile(99.0),
                "slo_violations": lat.slo_violations,
                "adaptive_wait": {
                    "adjustments": len(adjust),
                    "decreases": sum(a["action"] == "decrease"
                                     for a in adjust),
                    "bucket_wait_ms": waits,
                },
            })
        out["sweep"] = sweep
        if len(sweep) > 1:
            out["scaling"] = {
                "workers_max": sweep[-1]["workers"],
                "qps_vs_1_worker": (sweep[-1]["queries_per_sec"] /
                                    sweep[0]["queries_per_sec"]),
            }

        # -- overload: flood past the caps -------------------------------
        fleet = Fleet(store, n_workers=int(worker_counts[-1]),
                      slo_ms=slo_ms, max_wait_ms=max_wait_ms,
                      max_queue_depth=int(overload_depth),
                      max_bucket=max_bucket, **worker_kwargs)
        _warm(fleet, p, max_bucket)
        futures: List = []
        shed = 0
        breaker_seen = False
        off = 0
        # No polling between submits: the flood outruns the drain — the
        # shape of a real overload spike — so queues hit the caps fast.
        for i, w in enumerate(widths):
            try:
                futures.append(fleet.submit(queries[:, off:off + int(w)]))
            except ShedError:
                shed += 1
            off += int(w)
            if (i + 1) % 32 == 0:
                ctl = fleet.control()
                breaker_seen = breaker_seen or ctl["breaker_open"]
        fleet.flush()
        for f in futures:
            f.result()
        lat = fleet.latency()
        admitted_p99 = lat.total.percentile(99.0)
        adm = fleet.admission.summary()
        fleet.stop()
        offered = len(futures) + shed
        assert shed > 0, (
            f"overload flood ({offered} requests vs depth "
            f"{overload_depth}/worker) shed nothing — admission is broken")
        assert admitted_p99 <= slo_ms, (
            f"admitted-request p99 {admitted_p99:.1f} ms breached the "
            f"{slo_ms:.0f} ms SLO under overload — the queue cap is not "
            f"bounding latency")
        out["overload"] = {
            "workers": int(worker_counts[-1]),
            "max_queue_depth": int(overload_depth),
            "offered": offered,
            "admitted": len(futures),
            "shed": shed,
            "shed_rate": shed / offered,
            "shed_by_reason": adm["shed_by_reason"],
            "admitted_p99_ms": admitted_p99,
            "slo_ms": float(slo_ms),
            "within_slo": bool(admitted_p99 <= slo_ms),
            "breaker_opened": bool(breaker_seen),
        }

        # -- rollout: canary-then-promote, then a gated rollback ---------
        v2 = store.publish(
            model._replace(centroids=model.centroids[::-1]))
        fleet = Fleet(store, n_workers=int(worker_counts[-1]),
                      version=1, slo_ms=slo_ms, max_wait_ms=max_wait_ms,
                      max_queue_depth=1 << 30, max_bucket=max_bucket,
                      **worker_kwargs)
        _warm(fleet, p, max_bucket)
        # Pending traffic across the rollout: the canary's swap must
        # drain these into the OLD model, stranding none.
        pend = [fleet.submit(queries[:, i * 4:(i + 1) * 4])
                for i in range(min(8, int(widths.sum()) // 4))]
        t0 = time.perf_counter()
        promote = fleet.rollout(v2)
        promote_s = time.perf_counter() - t0
        fleet.flush()
        stranded = sum(not f.done() for f in pend)
        assert promote is not None and promote.promoted, \
            f"canary-then-promote failed: {promote}"
        assert all(w.version == v2 for w in fleet.workers), \
            "promotion left workers on the old version"
        assert stranded == 0, f"rollout stranded {stranded} futures"

        # Rollback: v3's canary probe breaches the budget by fiat (the
        # gate is policy; the bench injects the breach verdict so the
        # ROLLBACK path — not the probe — is what's measured).
        v3 = store.publish(model._replace(centroids=model.centroids[::-1]))
        pend = [fleet.submit(queries[:, i * 4:(i + 1) * 4])
                for i in range(min(8, int(widths.sum()) // 4))]
        rollback = fleet.rollout(v3, probe=lambda w: float("inf"))
        fleet.flush()
        stranded_rb = sum(not f.done() for f in pend)
        fleet.stop()
        assert rollback is not None and rollback.state == "rolled-back", \
            f"breached canary did not roll back: {rollback}"
        assert all(w.version == v2 for w in fleet.workers), \
            "rollback did not restore the prior version fleet-wide"
        assert stranded_rb == 0, \
            f"rollback stranded {stranded_rb} futures"
        out["rollout"] = {
            "promote_s": promote_s,
            "promote": promote.to_dict(),
            "rollback": rollback.to_dict(),
            "stranded_futures": int(stranded + stranded_rb),
            "version_restored": True,
        }
    return out

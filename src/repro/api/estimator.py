"""KernelKMeans: the sklearn-shaped estimator over pluggable backends.

One front door for the paper's whole comparison surface:

    est = KernelKMeans(k=2, r=2, kernel="polynomial",
                       kernel_params={"gamma": 0.0, "degree": 2},
                       backend="onepass-srht").fit(X, key=0)
    est.labels_                   # training clustering
    est.predict(X_new)            # out-of-sample assignment
    est.embed(X_new)              # (r, b) linearized new points
    est.score(X_new)              # -sum of squared centroid distances
    est.save("artifacts/demo")    # servable FittedModel artifact

`fit` is spec-driven: every constructor argument lands in one frozen
`ClusteringSpec` (serve/artifact.py), the chosen backend
(repro.api.backends) produces the rank-r `Embedding`, standard K-means
clusters its columns, and the result is packaged as a `FittedModel` — so
a fit from ANY backend flows through the entire serving stack
(MicroBatcher / AsyncBatcher / ModelRegistry / VersionStore / hot-swap)
unchanged.

RNG contract: `fit(X, key)` splits the key once into (backend, kmeans)
sub-keys — exactly the split the historical `fit_model` /
`one_pass_kernel_kmeans` used, so the deprecation shims over this class
reproduce their old outputs bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import backends as be
from repro.core.kernels_fn import kernel_params_for
from repro.core.kmeans import kmeans
from repro.serve import extend
from repro.serve.artifact import (ClusteringSpec, FittedModel,
                                  _cached_kernel, load_model, save_model)

# fit_model's historical default for the paper's primary kernel.
_KERNEL_DEFAULTS = {"polynomial": {"gamma": 0.0, "degree": 2}}


def _as_key(key: Union[None, int, jax.Array]) -> jax.Array:
    if key is None:
        return jax.random.PRNGKey(0)
    if isinstance(key, (int, np.integer)):
        return jax.random.PRNGKey(int(key))
    return key


def _spec_safe(params: Dict) -> Dict:
    """The JSON-serializable subset of backend_params — runtime-only
    knobs (e.g. a fwht_fn callable for the TPU FWHT) are used by the fit
    but cannot land in the persisted spec. Numpy scalars (a caller
    passing m=np.int64(128) is routine) are real config, not runtime
    state — coerce them rather than dropping them."""
    out = {}
    for name, val in params.items():
        if isinstance(val, np.integer):
            val = int(val)
        elif isinstance(val, np.floating):
            val = float(val)
        elif isinstance(val, np.bool_):
            val = bool(val)
        try:
            json.dumps(val)
        except TypeError:
            continue
        out[name] = val
    return out


class KernelKMeans:
    """Kernel K-means at rank r through a pluggable approximation backend.

    Parameters mirror `ClusteringSpec` (the frozen config this estimator
    is driven by): `kernel` is a registry NAME (core/kernels_fn) so the
    fit is serializable; `backend` one of
    `repro.api.available_backends()`; `backend_params` its knobs
    (`oversampling` for one-pass, `m` for Nystrom — non-serializable
    values like `fwht_fn` are honoured at fit time but excluded from the
    persisted spec); `policy` an optional `serve.ComputePolicy` choosing
    the compute path end to end — `policy.mesh` shards the one-pass fit
    across devices (repro.distributed.fit), `fit_fused`/`embed_fused`/
    `assign_fused` route through the Pallas kernels. The policy is
    runtime state, not config: it never lands in the spec or artifact.

    Fitted attributes (sklearn convention, trailing underscore):
        labels_     (n,)   training cluster labels
        embedding_  (r, n) linearized training samples Y
        eigvals_    (r,)   eigenvalues of the approximation
        centroids_  (k, r) K-means centroids
        inertia_    float  K-means objective (sum of squared distances)
        spec_              the bound ClusteringSpec (n, p filled in)
        model_             the packaged FittedModel (servable artifact)
    """

    def __init__(self, k: int = 2, r: int = 2, *,
                 kernel: str = "polynomial",
                 kernel_params: Optional[Dict] = None,
                 backend: str = "onepass-srht",
                 backend_params: Optional[Dict] = None,
                 block: int = 512, n_restarts: int = 10,
                 max_iter: int = 20, policy=None):
        be.get_backend(backend)                      # fail fast
        valid = kernel_params_for(kernel)            # fail fast
        if kernel_params is None:
            kernel_params = dict(_KERNEL_DEFAULTS.get(kernel, {}))
        unknown = set(kernel_params) - valid
        if unknown:
            raise ValueError(
                f"unknown param(s) {sorted(unknown)} for kernel "
                f"{kernel!r}; valid params: {sorted(valid) or 'none'}")
        self.k = int(k)
        self.r = int(r)
        self.kernel = kernel
        self.kernel_params = dict(kernel_params)
        self.backend = backend
        self.backend_params = dict(backend_params or {})
        self.block = int(block)
        self.n_restarts = int(n_restarts)
        self.max_iter = int(max_iter)
        # policy (a serve.ComputePolicy) picks the compute path — fused
        # Pallas kernels, interpret mode, and (for one-pass fits) the
        # mesh-sharded fit engine. Runtime-only: never persisted.
        self.policy = policy
        self.model_: Optional[FittedModel] = None
        # Live streaming state (partial_fit); not part of the artifact —
        # resume from a loaded model_ rebuilds it on demand.
        self._acc = None
        self._k_km: Optional[jax.Array] = None
        # Training-side attributes; stay None on the from_model()/load()
        # path (they are not part of the artifact).
        self.labels_ = None
        self.embedding_ = None
        self.eigvals_ = None
        self.centroids_ = None
        self.inertia_: Optional[float] = None
        self.spec_ = None
        self._extender: Optional[extend.Extender] = None

    # -- fitting ---------------------------------------------------------

    def _make_spec(self, n: int, p: int) -> ClusteringSpec:
        return ClusteringSpec(
            kernel=self.kernel, kernel_params=dict(self.kernel_params),
            k=self.k, r=self.r, backend=self.backend,
            backend_params=_spec_safe(self.backend_params),
            block=self.block, n_restarts=self.n_restarts,
            max_iter=self.max_iter, n=int(n), p=int(p))

    def _kernel_fn(self):
        return _cached_kernel(self.kernel,
                              tuple(sorted(self.kernel_params.items())))

    def _policy_kwargs(self, spec: ClusteringSpec) -> Dict:
        """Backend kwargs the policy adds. Only the one-pass backends
        understand policy=/kernel_statics= — nystrom/exact have no
        sharded or fused fit path, so a policy is silently inert there
        (its serve-side knobs still apply through extender())."""
        if self.policy is None or not self.backend.startswith("onepass-"):
            return {}
        return {"policy": self.policy,
                "kernel_statics": extend._kernel_statics(spec)}

    def _package(self, spec: ClusteringSpec, X: jnp.ndarray, U, eigvals,
                 centroids, state: Dict, ref=None) -> FittedModel:
        return FittedModel(
            spec=spec, X_train=jnp.asarray(X, jnp.float32),
            U=U, eigvals=eigvals, centroids=centroids,
            sketch_signs=state.get("sketch_signs"),
            sketch_rows=state.get("sketch_rows"),
            sketch_omega=state.get("sketch_omega"),
            landmarks=ref,
            landmark_idx=state.get("landmark_idx"),
            stream_w=state.get("stream_w"),
            stream_row_norms2=state.get("stream_row_norms2"),
            stream_counts=state.get("stream_counts"))

    def fit(self, X: jnp.ndarray,
            key: Union[None, int, jax.Array] = None) -> "KernelKMeans":
        """Fit on X (p, n); `key` may be a PRNGKey, an int seed, or None
        (seed 0). Returns self."""
        key = _as_key(key)
        spec = self._make_spec(n=X.shape[1], p=X.shape[0])
        kern = self._kernel_fn()
        k_backend, k_km = jax.random.split(key)
        emb = be.get_backend(self.backend).fit(
            k_backend, kern, X, self.r, block=self.block,
            **self.backend_params, **self._policy_kwargs(spec))
        km = kmeans(k_km, emb.Y.T, self.k, n_restarts=self.n_restarts,
                    max_iter=self.max_iter)
        self.model_ = self._package(spec, X, emb.U, emb.eigvals,
                                    km.centroids, emb.arrays, ref=emb.ref)
        self.labels_ = km.labels
        self.embedding_ = emb.Y
        self.eigvals_ = emb.eigvals
        self.centroids_ = km.centroids
        self.inertia_ = float(km.objective)
        self.spec_ = spec
        self._extender = None
        self._acc = None          # a fresh fit retires live stream state
        self._k_km = k_km
        return self

    # -- streaming fit ---------------------------------------------------

    def partial_fit(self, X_chunk: jnp.ndarray,
                    key: Union[None, int, jax.Array] = None, *,
                    capacity: Optional[int] = None, reeig: bool = True,
                    kmeans_mode: str = "full", minibatch_size: int = 256,
                    minibatch_steps: int = 50) -> "KernelKMeans":
        """Fold one data chunk (p, b) into a streaming fit. Returns self.

        The first call fixes the RNG exactly as `fit` does (one split
        into backend/K-means sub-keys), so a chunked pass over X is
        bit-identical to `fit(X, key)` at the re-eig boundary — the test
        matrix is sized to `capacity` up front (required on the first
        call; `capacity=n` reproduces fit, larger leaves room to keep
        streaming). When the estimator holds a model with streaming
        state (a resumed artifact, an earlier fit/partial_fit), `key`
        seeds only the K-means step and accumulation resumes from the
        persisted sketch slab.

        reeig=False accumulates without refreshing the model — the cheap
        steady-state path; any later call with reeig=True (or
        `reeig_now()`) folds the staged tail in and re-eigs.
        kmeans_mode: "full" (restarted Lloyd, the fit-parity path) or
        "minibatch" (Sculley updates in r-space for huge n —
        repro.stream.minibatch).
        """
        X_chunk = jnp.asarray(X_chunk, jnp.float32)
        # Fail fast on malformed chunks — a transposed chunk or a policy
        # swap mid-stream would otherwise surface as a shape error (or
        # silent recompile) deep inside the accumulator.
        p_fit = None
        if self._acc is not None and self._acc._X is not None:
            p_fit = int(self._acc._X.shape[0])
        elif self.model_ is not None:
            p_fit = int(self.model_.spec.p)
        if X_chunk.ndim != 2:
            raise ValueError(
                f"partial_fit chunk must be 2-D (p, b); got shape "
                f"{tuple(X_chunk.shape)}")
        if p_fit is not None and int(X_chunk.shape[0]) != p_fit:
            raise ValueError(
                f"partial_fit chunk has {int(X_chunk.shape[0])} feature "
                f"rows but this fit holds p={p_fit} — chunks are (p, b) "
                f"column blocks over a fixed feature dimension")
        if self._acc is not None and self._acc.policy != self.policy:
            raise ValueError(
                f"ComputePolicy changed mid-stream: the streaming state "
                f"was built under {self._acc.policy!r} but the estimator "
                f"now holds {self.policy!r}. The fit compute path (mesh "
                f"sharding / fused kernels) is fixed at the first "
                f"partial_fit — keep the original policy, or start a "
                f"fresh fit()")
        if self._acc is None:
            sketch_type = self.backend.split("-", 1)[1] \
                if self.backend.startswith("onepass-") else None
            if sketch_type is None:
                raise ValueError(
                    f"partial_fit needs a one-pass backend (streaming "
                    f"sketch state); backend is {self.backend!r}")
            from repro.stream.accumulate import SketchAccumulator
            k_backend, self._k_km = jax.random.split(_as_key(key))
            fwht_fn = self.backend_params.get("fwht_fn")
            pk = self._policy_kwargs(
                self._make_spec(n=0, p=int(X_chunk.shape[0])))
            if self.model_ is not None \
                    and self.model_.stream_counts is not None:
                self._acc = SketchAccumulator.from_model(self.model_,
                                                         fwht_fn=fwht_fn,
                                                         **pk)
            else:
                if capacity is None:
                    raise ValueError(
                        "partial_fit needs capacity=<total columns> on "
                        "the first call — the sketch test matrix is "
                        "sized up front (capacity=n reproduces fit; "
                        "larger keeps room to stream). Alternatively "
                        "load a model with streaming state to resume.")
                self._acc = SketchAccumulator(
                    k_backend, self._kernel_fn(), capacity, self.r,
                    oversampling=int(self.backend_params.get(
                        "oversampling", 10)),
                    block=self.block, sketch_type=sketch_type,
                    fwht_fn=fwht_fn,
                    truncate_basis=bool(self.backend_params.get(
                        "truncate_basis", False)),
                    **pk)
        self._acc.add(X_chunk)
        if reeig:
            self.reeig_now(kmeans_mode=kmeans_mode,
                           minibatch_size=minibatch_size,
                           minibatch_steps=minibatch_steps)
        return self

    def reeig_now(self, kmeans_mode: str = "full",
                  minibatch_size: int = 256,
                  minibatch_steps: int = 50) -> "KernelKMeans":
        """Re-eig the accumulated sketch and refresh model_/centroids.

        Runs `one_pass_core` on the effective sketch (staged tail
        included, applied on a copy — the canonical chunk-invariant
        state is untouched) and re-clusters the fresh embedding."""
        if self._acc is None:
            raise RuntimeError("no streaming state; call partial_fit()")
        eig = self._acc.eig()
        if kmeans_mode == "full":
            km = kmeans(self._k_km, eig.Y.T, self.k,
                        n_restarts=self.n_restarts, max_iter=self.max_iter)
            labels, centroids, objective = (km.labels, km.centroids,
                                            km.objective)
        elif kmeans_mode == "minibatch":
            from repro.stream.minibatch import minibatch_kmeans
            mb = minibatch_kmeans(self._k_km, eig.Y.T, self.k,
                                  minibatch_size, minibatch_steps)
            labels, centroids, objective = (mb.labels, mb.centroids,
                                            mb.objective)
        else:
            raise ValueError(f"unknown kmeans_mode {kmeans_mode!r}; "
                             f"have 'full' | 'minibatch'")
        X_all = self._acc.X_all
        spec = self._make_spec(n=self._acc.n_added, p=X_all.shape[0])
        self.model_ = self._package(spec, X_all, eig.U, eig.eigvals,
                                    centroids, self._acc.state_arrays())
        self.labels_ = labels
        self.embedding_ = eig.Y
        self.eigvals_ = eig.eigvals
        self.centroids_ = centroids
        self.inertia_ = float(objective)
        self.spec_ = spec
        self._extender = None
        return self

    @property
    def stream_progress(self) -> Dict:
        """Streaming fit counters: columns added/applied/pending,
        capacity, re-eigs run, and the last free approx-error estimate."""
        if self._acc is None:
            return {}
        return {"n_added": self._acc.n_added,
                "n_applied": self._acc.n_applied,
                "n_pending": self._acc.n_pending,
                "capacity": self._acc.capacity,
                "reeigs": self._acc.reeigs,
                "approx_err_estimate": self._acc.last_approx_err}

    def fit_predict(self, X: jnp.ndarray,
                    key: Union[None, int, jax.Array] = None) -> np.ndarray:
        return np.asarray(self.fit(X, key=key).labels_)

    # -- inference -------------------------------------------------------

    def _require_fit(self) -> FittedModel:
        if self.model_ is None:
            raise RuntimeError("KernelKMeans is not fitted; call fit() "
                               "or load()")
        return self.model_

    def extender(self, **kwargs) -> extend.Extender:
        """The serving extension engine over the fitted model (cached for
        the no-kwargs call so repeated predict()s reuse executables)."""
        model = self._require_fit()
        if kwargs:
            kwargs.setdefault("policy", self.policy)
            return extend.Extender(model, **kwargs)
        if self._extender is None:
            self._extender = extend.Extender(model, policy=self.policy)
        return self._extender

    def embed(self, X: jnp.ndarray) -> jnp.ndarray:
        """Out-of-sample extension of X (p, b) -> (r, b)."""
        return self.extender().embed(jnp.asarray(X, jnp.float32))

    def predict(self, X: jnp.ndarray) -> np.ndarray:
        """Assign X (p, b) to the fitted clusters -> labels (b,)."""
        labels, _ = self.extender().assign(jnp.asarray(X, jnp.float32))
        return np.asarray(labels)

    def transform(self, X: jnp.ndarray) -> jnp.ndarray:
        """sklearn-style alias of `embed` (column-major: (r, b))."""
        return self.embed(X)

    def score(self, X: Optional[jnp.ndarray] = None) -> float:
        """Negative sum of squared distances to the assigned centroids
        (higher is better, sklearn convention). X=None scores the
        training fit (the negative K-means inertia)."""
        if X is None:
            self._require_fit()
            if self.inertia_ is None:
                raise RuntimeError(
                    "training-side attributes (inertia_/labels_) are not "
                    "part of the artifact; this estimator was loaded, not "
                    "fitted — pass X to score against data")
            return -self.inertia_
        _, d2 = self.extender().assign(jnp.asarray(X, jnp.float32))
        return -float(jnp.sum(d2))

    # -- persistence -----------------------------------------------------

    def save(self, artifact_dir: str, dtype: str = "f32") -> str:
        """Persist the fitted model as a servable artifact directory."""
        return save_model(self._require_fit(), artifact_dir, dtype=dtype)

    @classmethod
    def from_model(cls, model: FittedModel) -> "KernelKMeans":
        """Rebuild an estimator around an existing FittedModel (training
        labels/embedding are not part of the artifact and stay unset)."""
        spec = model.spec
        est = cls(k=spec.k, r=spec.r, kernel=spec.kernel,
                  kernel_params=dict(spec.kernel_params),
                  backend=spec.backend,
                  backend_params=dict(spec.backend_params),
                  block=spec.block, n_restarts=spec.n_restarts,
                  max_iter=spec.max_iter)
        est.model_ = model
        est.eigvals_ = model.eigvals
        est.centroids_ = model.centroids
        est.spec_ = spec
        return est

    @classmethod
    def load(cls, artifact_dir: str) -> "KernelKMeans":
        """Load a saved artifact back into a predict/embed-ready
        estimator."""
        return cls.from_model(load_model(artifact_dir))

    def __repr__(self) -> str:
        fitted = "fitted" if self.model_ is not None else "unfitted"
        args = {"k": self.k, "r": self.r, "kernel": self.kernel,
                "backend": self.backend}
        if self.backend_params:
            args["backend_params"] = self.backend_params
        body = ", ".join(f"{k}={v!r}" for k, v in args.items())
        return f"KernelKMeans({body}) <{fitted}>"


def spec_to_estimator(spec: ClusteringSpec) -> KernelKMeans:
    """An unfitted estimator configured exactly as `spec` records — the
    refit path: `spec_to_estimator(old.spec).fit(X_new, key)`."""
    d = dataclasses.asdict(spec)
    d.pop("n", None)
    d.pop("p", None)
    return KernelKMeans(**{k: v for k, v in d.items()})

"""KernelKMeans: the sklearn-shaped estimator over pluggable backends.

One front door for the paper's whole comparison surface:

    est = KernelKMeans(k=2, r=2, kernel="polynomial",
                       kernel_params={"gamma": 0.0, "degree": 2},
                       backend="onepass-srht").fit(X, key=0)
    est.labels_                   # training clustering
    est.predict(X_new)            # out-of-sample assignment
    est.embed(X_new)              # (r, b) linearized new points
    est.score(X_new)              # -sum of squared centroid distances
    est.save("artifacts/demo")    # servable FittedModel artifact

`fit` is spec-driven: every constructor argument lands in one frozen
`ClusteringSpec` (serve/artifact.py), the chosen backend
(repro.api.backends) produces the rank-r `Embedding`, standard K-means
clusters its columns, and the result is packaged as a `FittedModel` — so
a fit from ANY backend flows through the entire serving stack
(MicroBatcher / AsyncBatcher / ModelRegistry / VersionStore / hot-swap)
unchanged.

RNG contract: `fit(X, key)` splits the key once into (backend, kmeans)
sub-keys — exactly the split the historical `fit_model` /
`one_pass_kernel_kmeans` used, so the deprecation shims over this class
reproduce their old outputs bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import backends as be
from repro.core.kernels_fn import kernel_params_for
from repro.core.kmeans import kmeans
from repro.serve import extend
from repro.serve.artifact import (ClusteringSpec, FittedModel,
                                  _cached_kernel, load_model, save_model)

# fit_model's historical default for the paper's primary kernel.
_KERNEL_DEFAULTS = {"polynomial": {"gamma": 0.0, "degree": 2}}


def _as_key(key: Union[None, int, jax.Array]) -> jax.Array:
    if key is None:
        return jax.random.PRNGKey(0)
    if isinstance(key, (int, np.integer)):
        return jax.random.PRNGKey(int(key))
    return key


def _spec_safe(params: Dict) -> Dict:
    """The JSON-serializable subset of backend_params — runtime-only
    knobs (e.g. a fwht_fn callable for the TPU FWHT) are used by the fit
    but cannot land in the persisted spec. Numpy scalars (a caller
    passing m=np.int64(128) is routine) are real config, not runtime
    state — coerce them rather than dropping them."""
    out = {}
    for name, val in params.items():
        if isinstance(val, np.integer):
            val = int(val)
        elif isinstance(val, np.floating):
            val = float(val)
        elif isinstance(val, np.bool_):
            val = bool(val)
        try:
            json.dumps(val)
        except TypeError:
            continue
        out[name] = val
    return out


class KernelKMeans:
    """Kernel K-means at rank r through a pluggable approximation backend.

    Parameters mirror `ClusteringSpec` (the frozen config this estimator
    is driven by): `kernel` is a registry NAME (core/kernels_fn) so the
    fit is serializable; `backend` one of
    `repro.api.available_backends()`; `backend_params` its knobs
    (`oversampling` for one-pass, `m` for Nystrom — non-serializable
    values like `fwht_fn` are honoured at fit time but excluded from the
    persisted spec).

    Fitted attributes (sklearn convention, trailing underscore):
        labels_     (n,)   training cluster labels
        embedding_  (r, n) linearized training samples Y
        eigvals_    (r,)   eigenvalues of the approximation
        centroids_  (k, r) K-means centroids
        inertia_    float  K-means objective (sum of squared distances)
        spec_              the bound ClusteringSpec (n, p filled in)
        model_             the packaged FittedModel (servable artifact)
    """

    def __init__(self, k: int = 2, r: int = 2, *,
                 kernel: str = "polynomial",
                 kernel_params: Optional[Dict] = None,
                 backend: str = "onepass-srht",
                 backend_params: Optional[Dict] = None,
                 block: int = 512, n_restarts: int = 10,
                 max_iter: int = 20):
        be.get_backend(backend)                      # fail fast
        valid = kernel_params_for(kernel)            # fail fast
        if kernel_params is None:
            kernel_params = dict(_KERNEL_DEFAULTS.get(kernel, {}))
        unknown = set(kernel_params) - valid
        if unknown:
            raise ValueError(
                f"unknown param(s) {sorted(unknown)} for kernel "
                f"{kernel!r}; valid params: {sorted(valid) or 'none'}")
        self.k = int(k)
        self.r = int(r)
        self.kernel = kernel
        self.kernel_params = dict(kernel_params)
        self.backend = backend
        self.backend_params = dict(backend_params or {})
        self.block = int(block)
        self.n_restarts = int(n_restarts)
        self.max_iter = int(max_iter)
        self.model_: Optional[FittedModel] = None
        # Training-side attributes; stay None on the from_model()/load()
        # path (they are not part of the artifact).
        self.labels_ = None
        self.embedding_ = None
        self.eigvals_ = None
        self.centroids_ = None
        self.inertia_: Optional[float] = None
        self.spec_ = None
        self._extender: Optional[extend.Extender] = None

    # -- fitting ---------------------------------------------------------

    def fit(self, X: jnp.ndarray,
            key: Union[None, int, jax.Array] = None) -> "KernelKMeans":
        """Fit on X (p, n); `key` may be a PRNGKey, an int seed, or None
        (seed 0). Returns self."""
        key = _as_key(key)
        spec = ClusteringSpec(
            kernel=self.kernel, kernel_params=dict(self.kernel_params),
            k=self.k, r=self.r, backend=self.backend,
            backend_params=_spec_safe(self.backend_params),
            block=self.block, n_restarts=self.n_restarts,
            max_iter=self.max_iter, n=int(X.shape[1]), p=int(X.shape[0]))
        kern = _cached_kernel(spec.kernel,
                              tuple(sorted(spec.kernel_params.items())))
        k_backend, k_km = jax.random.split(key)
        emb = be.get_backend(self.backend).fit(
            k_backend, kern, X, self.r, block=self.block,
            **self.backend_params)
        km = kmeans(k_km, emb.Y.T, self.k, n_restarts=self.n_restarts,
                    max_iter=self.max_iter)
        state = emb.arrays
        self.model_ = FittedModel(
            spec=spec, X_train=jnp.asarray(X, jnp.float32),
            U=emb.U, eigvals=emb.eigvals, centroids=km.centroids,
            sketch_signs=state.get("sketch_signs"),
            sketch_rows=state.get("sketch_rows"),
            sketch_omega=state.get("sketch_omega"),
            landmarks=emb.ref,
            landmark_idx=state.get("landmark_idx"))
        self.labels_ = km.labels
        self.embedding_ = emb.Y
        self.eigvals_ = emb.eigvals
        self.centroids_ = km.centroids
        self.inertia_ = float(km.objective)
        self.spec_ = spec
        self._extender = None
        return self

    def fit_predict(self, X: jnp.ndarray,
                    key: Union[None, int, jax.Array] = None) -> np.ndarray:
        return np.asarray(self.fit(X, key=key).labels_)

    # -- inference -------------------------------------------------------

    def _require_fit(self) -> FittedModel:
        if self.model_ is None:
            raise RuntimeError("KernelKMeans is not fitted; call fit() "
                               "or load()")
        return self.model_

    def extender(self, **kwargs) -> extend.Extender:
        """The serving extension engine over the fitted model (cached for
        the no-kwargs call so repeated predict()s reuse executables)."""
        model = self._require_fit()
        if kwargs:
            return extend.Extender(model, **kwargs)
        if self._extender is None:
            self._extender = extend.Extender(model)
        return self._extender

    def embed(self, X: jnp.ndarray) -> jnp.ndarray:
        """Out-of-sample extension of X (p, b) -> (r, b)."""
        return self.extender().embed(jnp.asarray(X, jnp.float32))

    def predict(self, X: jnp.ndarray) -> np.ndarray:
        """Assign X (p, b) to the fitted clusters -> labels (b,)."""
        labels, _ = self.extender().assign(jnp.asarray(X, jnp.float32))
        return np.asarray(labels)

    def transform(self, X: jnp.ndarray) -> jnp.ndarray:
        """sklearn-style alias of `embed` (column-major: (r, b))."""
        return self.embed(X)

    def score(self, X: Optional[jnp.ndarray] = None) -> float:
        """Negative sum of squared distances to the assigned centroids
        (higher is better, sklearn convention). X=None scores the
        training fit (the negative K-means inertia)."""
        if X is None:
            self._require_fit()
            if self.inertia_ is None:
                raise RuntimeError(
                    "training-side attributes (inertia_/labels_) are not "
                    "part of the artifact; this estimator was loaded, not "
                    "fitted — pass X to score against data")
            return -self.inertia_
        _, d2 = self.extender().assign(jnp.asarray(X, jnp.float32))
        return -float(jnp.sum(d2))

    # -- persistence -----------------------------------------------------

    def save(self, artifact_dir: str, dtype: str = "f32") -> str:
        """Persist the fitted model as a servable artifact directory."""
        return save_model(self._require_fit(), artifact_dir, dtype=dtype)

    @classmethod
    def from_model(cls, model: FittedModel) -> "KernelKMeans":
        """Rebuild an estimator around an existing FittedModel (training
        labels/embedding are not part of the artifact and stay unset)."""
        spec = model.spec
        est = cls(k=spec.k, r=spec.r, kernel=spec.kernel,
                  kernel_params=dict(spec.kernel_params),
                  backend=spec.backend,
                  backend_params=dict(spec.backend_params),
                  block=spec.block, n_restarts=spec.n_restarts,
                  max_iter=spec.max_iter)
        est.model_ = model
        est.eigvals_ = model.eigvals
        est.centroids_ = model.centroids
        est.spec_ = spec
        return est

    @classmethod
    def load(cls, artifact_dir: str) -> "KernelKMeans":
        """Load a saved artifact back into a predict/embed-ready
        estimator."""
        return cls.from_model(load_model(artifact_dir))

    def __repr__(self) -> str:
        fitted = "fitted" if self.model_ is not None else "unfitted"
        args = {"k": self.k, "r": self.r, "kernel": self.kernel,
                "backend": self.backend}
        if self.backend_params:
            args["backend_params"] = self.backend_params
        body = ", ".join(f"{k}={v!r}" for k, v in args.items())
        return f"KernelKMeans({body}) <{fitted}>"


def spec_to_estimator(spec: ClusteringSpec) -> KernelKMeans:
    """An unfitted estimator configured exactly as `spec` records — the
    refit path: `spec_to_estimator(old.spec).fit(X_new, key)`."""
    d = dataclasses.asdict(spec)
    d.pop("n", None)
    d.pop("p", None)
    return KernelKMeans(**{k: v for k, v in d.items()})

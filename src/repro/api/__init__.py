"""repro.api: the estimator front door — one `KernelKMeans`, many backends.

The paper's central claim is a *comparison*: the one-pass randomized
approximation matches Nystrom and the exact eigendecomposition in
clustering accuracy at a fraction of the memory. This package makes that
comparison a first-class, servable axis instead of three incompatible
free functions:

  backends.py   `Approximator` protocol + registry. Four registered
                backends, each returning the same `Embedding`
                (Y, U, eigvals, extension reference points, state):
                  onepass-srht      Alg. 1, SRHT sketch (the paper)
                  onepass-gaussian  Alg. 1, dense Gaussian sketch
                  nystrom           classical m-landmark Nystrom
                                    [Williams & Seeger 2001]
                  exact             rank-r eigendecomposition (ceiling)
  estimator.py  `KernelKMeans`: sklearn-shaped fit / embed / predict /
                score driven by a single frozen `ClusteringSpec`; `fit`
                packages a `FittedModel`, so ANY backend's fit flows
                through the whole serving stack (repro.serve: artifact,
                extension, batching, registry, versioning, hot-swap)
                unchanged.

Quick use:

    from repro.api import KernelKMeans
    est = KernelKMeans(k=2, r=2, backend="nystrom",
                       backend_params={"m": 128}).fit(X, key=0)
    labels = est.predict(X_new)
    est.save("artifacts/demo")          # -> servable artifact dir

Legacy entry points (`repro.serve.fit_model`,
`repro.core.one_pass_kernel_kmeans`) are deprecation shims over this API.
"""
from repro.api.backends import (Approximator, Embedding,
                                available_backends, fit_memory_bytes,
                                get_backend, register_backend)
from repro.api.estimator import KernelKMeans
from repro.serve.artifact import ClusteringSpec

__all__ = [
    "Approximator", "Embedding", "available_backends", "fit_memory_bytes",
    "get_backend", "register_backend",
    "KernelKMeans",
    "ClusteringSpec",
]

"""Pluggable kernel-approximation backends behind one protocol.

Every backend linearizes the kernel matrix K = kappa(X, X) at rank r and
returns the SAME `Embedding` contract, so the estimator (`KernelKMeans`)
and the serving stack (repro.serve) are backend-agnostic:

    Y        (r, n)      linearized training samples: K_hat ~= Y^T Y —
                         standard K-means on the columns of Y is kernel
                         K-means under the approximation
    U        (n_ref, r)  orthonormal eigenvector basis of the extension
                         operator; rows index the training points
                         (one-pass / exact) or the Nystrom landmarks
    eigvals  (r,)        matching eigenvalues (descending, >= 0)
    ref      (p, m)|None extension reference points when they are NOT the
                         training set (Nystrom landmarks); None means
                         "extend against X_train"
    state    dict        backend-specific reproducibility state, persisted
                         verbatim into the FittedModel artifact (SRHT
                         signs/rows, Gaussian Omega, landmark indices)

The out-of-sample extension is the same formula for every backend:

    y(x) = eigvals^{-1/2} U^T kappa(ref, x)        (serve/extend.py)

— for one-pass/exact that is the usual Nystrom-style extension against
the training set; for the Nystrom backend U/eigvals are the eigenpairs of
the landmark gram W_m, so the identical formula against the m landmarks
reproduces the fitted Y exactly on training points AND serves at
O(m x block) kernel memory per stripe instead of O(n x block).

Memory model (`fit_memory_bytes`): the paper's comparison axis. One-pass
holds the (n, r') sketch, Nystrom the (n, m) landmark block C, exact the
full (n, n) gram.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp

from repro.core.exact import exact_eig
from repro.core.kernels_fn import KernelFn
from repro.core.nystrom import nystrom


class Embedding(NamedTuple):
    """What every backend's fit returns; see module docstring."""
    Y: jnp.ndarray
    U: jnp.ndarray
    eigvals: jnp.ndarray
    ref: Optional[jnp.ndarray] = None
    state: Optional[Dict[str, jnp.ndarray]] = None

    @property
    def arrays(self) -> Dict[str, jnp.ndarray]:
        """The state dict, never-None view."""
        return self.state or {}


class Approximator(Protocol):
    """Protocol every registered backend satisfies."""
    name: str

    def fit(self, key: jax.Array, kernel: KernelFn, X: jnp.ndarray,
            r: int, *, block: int = 512, **params) -> Embedding:
        """Linearize kappa(X, X) at rank r; X is (p, n)."""
        ...

    def fit_memory_bytes(self, n: int, r: int, **params) -> int:
        """Dominant fit-time working-set bytes (float32)."""
        ...


class _Backend:
    """Registry entry: a named (fit, fit_memory_bytes) pair."""

    def __init__(self, name: str, fit: Callable, memory: Callable):
        self.name = name
        self._fit = fit
        self._memory = memory

    def fit(self, key, kernel, X, r, *, block=512, **params) -> Embedding:
        return self._fit(key, kernel, X, r, block=block, **params)

    def fit_memory_bytes(self, n: int, r: int, **params) -> int:
        return int(self._memory(n, r, **params))

    def __repr__(self) -> str:
        return f"<Approximator {self.name!r}>"


_BACKENDS: Dict[str, _Backend] = {}


def register_backend(name: str, memory: Callable):
    """Decorator: register `fit` under `name` with its memory model."""

    def wrap(fit: Callable) -> Callable:
        _BACKENDS[name] = _Backend(name, fit, memory)
        return fit

    return wrap


def get_backend(name: str) -> _Backend:
    if name not in _BACKENDS:
        raise ValueError(f"unknown backend {name!r}; "
                         f"have {available_backends()}")
    return _BACKENDS[name]


def available_backends() -> list:
    return sorted(_BACKENDS)


def fit_memory_bytes(name: str, n: int, r: int, **params) -> int:
    """Dominant fit-time working set of `name` at (n, r) — the number the
    paper's Table 1 / Fig. 3 memory comparison is about."""
    return get_backend(name).fit_memory_bytes(n, r, **params)


def default_nystrom_m(n: int, r: int) -> int:
    """Default landmark count: the paper's point is that matching the
    one-pass accuracy needs m >> r' — 16r (floored at 64) tracks the
    m/r ratios of Table 1 / Fig. 3 without scaling past n."""
    return min(n, max(16 * r, 64))


# ---------------------------------------------------------------------------
# The four registered backends
# ---------------------------------------------------------------------------

def _onepass(sketch_type: str):
    def fit(key, kernel, X, r, *, block=512, oversampling=10,
            fwht_fn=None, truncate_basis=False, capacity=None,
            policy=None, kernel_statics=None) -> Embedding:
        # One-shot fit is a single-chunk pass through the streaming
        # accumulator (repro.stream.accumulate) — the SAME block-granular
        # update sequence partial_fit replays, so a chunked fit over a
        # full pass is bit-identical to this at the re-eig boundary. The
        # sketch draw matches the historical randomized_eig_with_state
        # contract (make_srht/make_gaussian on `key` at capacity=n).
        # capacity > n pre-sizes the sketch so partial_fit can keep
        # adding columns after this fit. Lazy import: repro.stream's
        # retrain layer imports repro.api back.
        from repro.stream.accumulate import SketchAccumulator
        # policy= (a serve.ComputePolicy) selects the fit compute path:
        # mesh -> the sharded engine (distributed/fit.py), fit_fused ->
        # the fit_sketch Pallas kernel; None is the canonical path.
        acc = SketchAccumulator(key, kernel, capacity or X.shape[1], r,
                                oversampling=oversampling, block=block,
                                sketch_type=sketch_type, fwht_fn=fwht_fn,
                                truncate_basis=truncate_basis,
                                policy=policy,
                                kernel_statics=kernel_statics)
        acc.add(X)
        eig = acc.eig()
        return Embedding(Y=eig.Y, U=eig.U, eigvals=eig.eigvals,
                         ref=None, state=acc.state_arrays())
    return fit


register_backend(
    "onepass-srht",
    memory=lambda n, r, oversampling=10, **_: 4 * n * (r + oversampling),
)(_onepass("srht"))

register_backend(
    "onepass-gaussian",
    # Sketch W plus the equally-sized dense Omega it is multiplied by.
    memory=lambda n, r, oversampling=10, **_: 8 * n * (r + oversampling),
)(_onepass("gaussian"))


@register_backend(
    "nystrom",
    memory=lambda n, r, m=None, **_: 4 * n * (m or default_nystrom_m(n, r)),
)
def _fit_nystrom(key, kernel, X, r, *, block=512, m=None,
                 eps=1e-8) -> Embedding:
    n = X.shape[1]
    m = m if m is not None else default_nystrom_m(n, r)
    res = nystrom(key, kernel, X, m=m, r=r, eps=eps)
    return Embedding(Y=res.Y, U=res.U, eigvals=res.eigvals,
                     ref=X[:, res.idx], state={"landmark_idx": res.idx})


@register_backend(
    "exact",
    memory=lambda n, r, **_: 4 * n * n,
)
def _fit_exact(key, kernel, X, r, *, block=512) -> Embedding:
    # Deterministic (key unused); materializes the full gram — the
    # accuracy ceiling, validation-scale n only.
    del key, block
    eig = exact_eig(kernel, X, r)
    return Embedding(Y=eig.Y, U=eig.U, eigvals=eig.eigvals, ref=None,
                     state={})

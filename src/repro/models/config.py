"""Architecture configuration dataclass shared by all 10 assigned archs."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # MLP / MoE
    activation: str = "swiglu"  # swiglu | relu2 | gelu
    n_experts: int = 0          # 0 -> dense MLP
    top_k: int = 0

    # Attention flavour
    attention: str = "full"     # full | sliding
    window: int = 4096
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # Hybrid (recurrentgemma): repeating layer pattern, 'R' = RG-LRU block,
    # 'A' = (local) attention block. Empty -> all 'A' (or all 'R' for ssm).
    layer_pattern: Tuple[str, ...] = ()

    # SSM (rwkv6)
    rwkv_head_dim: int = 64

    # Encoder-decoder (whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500

    # VLM (pixtral): number of prefix patch-embedding positions in train.
    n_patch_tokens: int = 0

    # Numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"   # AdamW moment dtype (bf16 for giants)
    remat: bool = True
    microbatches: int = 1              # gradient-accumulation steps

    # Sharding knobs (see distributed/sharding.py)
    fsdp: bool = True                  # shard weights over the data axis too
    shard_heads: bool = True
    zero1: bool = False                # ZeRO-1: params/grads TP-only
                                       # (contractions local), optimizer
                                       # moments fully sharded (fsdp x tp)
    pregather: bool = False            # all-gather FSDP weights once per
                                       # step (not per microbatch) — trades
                                       # peak memory for HBM/ICI traffic
    seq_shard_acts: bool = False       # sequence-parallel activations:
                                       # shard S over the model axis at
                                       # layer boundaries (reduce-scatter/
                                       # all-gather instead of all-reduce)
    rwkv_chunk: int = 64               # WKV chunk length (perf knob)
    attn_scores_f32: bool = True       # f32 softmax (False: bf16 scores —
                                       # halves attention HBM traffic)

    # Padded vocab for TP divisibility (0 -> auto: next multiple of 128*tp).
    padded_vocab: int = 0

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def vocab_padded(self, tp: int = 16) -> int:
        if self.padded_vocab:
            return self.padded_vocab
        mult = 128 * tp
        return -(-self.vocab_size // mult) * mult

    def param_count(self) -> int:
        """Total parameters (embedding + layers + head), for 6ND roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * hd * (nq + 2 * nkv) + nq * hd * d
        if self.activation in ("swiglu", "geglu"):
            mlp_dense = 3 * d * f
        else:
            mlp_dense = 2 * d * f
        mlp = mlp_dense * max(self.n_experts, 1)
        if self.n_experts:
            mlp += d * self.n_experts       # router
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            # rwkv6: r,k,v,g,w projections + output (~6 d^2) + ffn (2 d f)
            per_layer = 6 * d * d + 2 * d * f + 2 * d
        if self.family == "hybrid":
            # average over pattern: R blocks ~ (3 d^2 + gates) vs attn
            n_r = sum(1 for c in self._pattern() if c == "R")
            n_a = self.n_layers - n_r
            r_block = 3 * d * d + 2 * d * f
            a_block = attn + (3 * d * f if self.activation == "swiglu"
                              else 2 * d * f)
            return (v * d * 2 + n_r * r_block + n_a * a_block + 2 * d)
        total = v * d * 2 + self.n_layers * per_layer + d
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn.
            enc = self.n_encoder_layers * (attn + mlp_dense + 2 * d)
            dec = self.n_layers * (2 * attn + mlp_dense + 3 * d)
            total = v * d * 2 + enc + dec + d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts), for 6*N_act*D."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_dense = (3 if self.activation in ("swiglu", "geglu") else 2) * d * f
        inactive = (self.n_experts - self.top_k) * mlp_dense * self.n_layers
        return self.param_count() - inactive

    def _pattern(self) -> Tuple[str, ...]:
        """Full per-layer pattern of length n_layers."""
        if not self.layer_pattern:
            return tuple("A" * self.n_layers)
        reps = -(-self.n_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.n_layers]

"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

[arXiv:2402.19427] Griffin/RecurrentGemma: repeating pattern of two
residual RG-LRU blocks followed by one local(sliding-window) MQA block.
The RG-LRU recurrence
    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    log a_t = -c * softplus(Lambda) * r_t            (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is a diagonal linear RNN -> computed with `jax.lax.associative_scan`
(log-depth, parallel over time) in train/prefill, O(1) state in decode.
Train-time seq shapes stay (B, S, d_rnn); the scan is over S.

Layer stacking: the repeating (R, R, A) super-block is weight-stacked and
scanned; the remainder layers (26 % 3) run unstacked after the scan, so the
exact 26-layer pattern from the paper is preserved.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.distributed.sharding import maybe_shard

_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------

def init_rglru_block(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    dr = d                      # lru width == d_model for recurrentgemma-2b
    ks = jax.random.split(key, 8)
    return {
        "ln": L._norm_init(d),
        "w_in": L._dense_init(ks[0], (d, dr), dtype=dtype),      # x branch
        "w_gate": L._dense_init(ks[1], (d, dr), dtype=dtype),    # gelu gate
        "conv_w": L._dense_init(ks[2], (4, dr), scale_dim=4, dtype=dtype),
        "w_a": L._dense_init(ks[3], (dr, dr), dtype=dtype),      # recur gate
        "w_x": L._dense_init(ks[4], (dr, dr), dtype=dtype),      # input gate
        "lam": jnp.asarray(
            jax.random.uniform(ks[5], (dr,), jnp.float32, 0.0, 1.0)),
        "w_out": L._dense_init(ks[6], (dr, d), dtype=dtype),
        "ln2": L._norm_init(d),
        "mlp": L.init_mlp(ks[7], cfg, dtype),
    }


def _rglru_scan(a_log: jnp.ndarray, bx: jnp.ndarray,
                h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """h_t = exp(a_log_t) * h_{t-1} + bx_t over axis 1 (time).

    a_log, bx: (B, S, dr). Associative scan over the diagonal recurrence in
    (log-decay, value) form; returns h (B, S, dr). h0 folded into bx[0].
    """
    if h0 is not None:
        bx = bx.at[:, 0].add(jnp.exp(a_log[:, 0]) * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_log, bx), axis=1)
    return h


def _causal_conv4(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width 4. x: (B,S,dr), w: (4,dr).

    Returns (y, new_state) where state is the last 3 inputs (B,3,dr).
    """
    B, S, dr = x.shape
    pad = state if state is not None else jnp.zeros((B, 3, dr), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+3, dr)
    y = sum(xp[:, i:i + S] * w[i][None, None] for i in range(4))
    return y, xp[:, -3:]


def _rglru_core(p: Dict, x: jnp.ndarray, h0=None, conv0=None):
    """Shared train/decode core. x: (B,S,d) normed input; returns
    (branch_out (B,S,dr), h_last, conv_state)."""
    u = x @ p["w_in"]                                  # (B,S,dr)
    u, conv_state = _causal_conv4(u, p["conv_w"], conv0)
    r = jax.nn.sigmoid((u @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # (B,S,dr) f32, < 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    bx = beta * (i * u.astype(jnp.float32))
    h = _rglru_scan(log_a, bx, h0)                     # (B,S,dr) f32
    return h.astype(x.dtype), h[:, -1], conv_state


def apply_rglru_block(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
                      groups: int = 1) -> jnp.ndarray:
    xin = L.rms_norm(x, p["ln"])
    h, _, _ = _rglru_core(p, xin)
    gate = jax.nn.gelu((xin @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    x = x + (h * gate) @ p["w_out"]
    x = x + L.apply_mlp(p["mlp"], cfg, L.rms_norm(x, p["ln2"]), groups)
    return x


def decode_rglru_block(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
                       h0: jnp.ndarray, conv0: jnp.ndarray,
                       groups: int = 1):
    """x: (B,1,d); h0: (B,dr) f32; conv0: (B,3,dr). Returns (x, h, conv)."""
    xin = L.rms_norm(x, p["ln"])
    h, h_last, conv_state = _rglru_core(p, xin, h0, conv0)
    gate = jax.nn.gelu((xin @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    x = x + (h * gate) @ p["w_out"]
    x = x + L.apply_mlp(p["mlp"], cfg, L.rms_norm(x, p["ln2"]), groups)
    return x, h_last, conv_state


# ---------------------------------------------------------------------------
# Full model: embed -> scan[(R,R,A) x 8] -> (R,R) -> norm -> unembed
# ---------------------------------------------------------------------------

def _superblocks(cfg: ArchConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.layer_pattern or ("R", "R", "A")
    n_super = cfg.n_layers // len(pat)
    rem = cfg._pattern()[n_super * len(pat):]
    return n_super, rem


def init_rg(key: jax.Array, cfg: ArchConfig, tp: int = 16) -> Dict:
    V = cfg.vocab_padded(tp)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    pat = cfg.layer_pattern or ("R", "R", "A")
    n_super, rem = _superblocks(cfg)
    ks = jax.random.split(key, 4 + len(rem))

    def init_super(k):
        kk = jax.random.split(k, len(pat))
        return {
            f"{i}_{c}": (init_rglru_block(kk[i], cfg, dtype) if c == "R"
                         else L.init_block(kk[i], cfg, dtype))
            for i, c in enumerate(pat)
        }

    stacked = jax.vmap(init_super)(jax.random.split(ks[0], n_super))
    rem_params = [init_rglru_block(ks[4 + i], cfg, dtype) if c == "R"
                  else L.init_block(ks[4 + i], cfg, dtype)
                  for i, c in enumerate(rem)]
    return {"embed": L._dense_init(ks[1], (V, d), scale_dim=d, dtype=dtype),
            "supers": stacked, "rem": rem_params,
            "ln_f": L._norm_init(d),
            "unembed": L._dense_init(ks[2], (d, V), dtype=dtype)}


def forward_rg(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
               groups: int = 1) -> jnp.ndarray:
    x = maybe_shard(params["embed"][tokens])
    pat = cfg.layer_pattern or ("R", "R", "A")

    def body(x, sp):
        for i, c in enumerate(pat):
            p = sp[f"{i}_{c}"]
            if c == "R":
                x = apply_rglru_block(p, cfg, x, groups)
            else:
                x = L.apply_block(p, cfg, x, groups=groups,
                                  window=cfg.window)
        return maybe_shard(x), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["supers"])
    _, rem_pattern = _superblocks(cfg)
    for c, p in zip(rem_pattern, params["rem"]):
        if c == "R":
            x = apply_rglru_block(p, cfg, x, groups)
        else:
            x = L.apply_block(p, cfg, x, groups=groups, window=cfg.window)
    x = L.rms_norm(x, params["ln_f"])
    return (x @ params["unembed"]).astype(jnp.float32)


def _layer_list(params: Dict, cfg: ArchConfig):
    """Yield (kind, params) for all n_layers in order (decode path —
    python loop, no scan: per-layer states are heterogeneous)."""
    pat = cfg.layer_pattern or ("R", "R", "A")
    n_super, _ = _superblocks(cfg)
    for s in range(n_super):
        for i, c in enumerate(pat):
            p = jax.tree.map(lambda a: a[s], params["supers"][f"{i}_{c}"])
            yield c, p
    _, rem_pattern = _superblocks(cfg)
    for c, p in zip(rem_pattern, params["rem"]):
        yield c, p


def init_cache_rg(cfg: ArchConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    pat = cfg._pattern()
    n_r = sum(1 for c in pat if c == "R")
    n_a = len(pat) - n_r
    T = min(max_seq, cfg.window)
    return {
        "h": jnp.zeros((n_r, batch, d), jnp.float32),
        "conv": jnp.zeros((n_r, batch, 3, d), dtype),
        "k": jnp.zeros((n_a, batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((n_a, batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_rg(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
               cache: Dict, groups: int = 1):
    """Run the prompt, return (last logits, per-layer states + ring KV)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    T = cache["k"].shape[2]
    h_all, conv_all = cache["h"], cache["conv"]
    k_all, v_all = cache["k"], cache["v"]
    ri, ai = 0, 0
    for kind, p in _layer_list(params, cfg):
        if kind == "R":
            xin = L.rms_norm(x, p["ln"])
            h, h_last, conv_state = _rglru_core(p, xin)
            gate = jax.nn.gelu((xin @ p["w_gate"]).astype(jnp.float32)
                               ).astype(x.dtype)
            x = x + (h * gate) @ p["w_out"]
            x = x + L.apply_mlp(p["mlp"], cfg, L.rms_norm(x, p["ln2"]),
                                groups)
            h_all = h_all.at[ri].set(h_last)
            conv_all = conv_all.at[ri].set(conv_state.astype(conv_all.dtype))
            ri += 1
        else:
            h = L.rms_norm(x, p["ln1"])
            q, k, v = L._qkv(p["attn"], cfg, h, jnp.arange(S)[None, :])
            attn = L._sdpa(q, k, v, L.causal_mask(S, cfg.window),
                           cfg.q_per_kv) @ p["attn"]["wo"]
            x = x + attn
            x = x + L.apply_mlp(p["mlp"], cfg, L.rms_norm(x, p["ln2"]),
                                groups)
            if S > T:     # ring layout: position p -> slot p % T
                kc = jnp.roll(k[:, -T:], S % T, axis=1)
                vc = jnp.roll(v[:, -T:], S % T, axis=1)
            else:
                kc = jnp.zeros((B, T, cfg.n_kv_heads, cfg.head_dim), k.dtype)
                vc = jnp.zeros_like(kc)
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
            k_all = k_all.at[ai].set(kc.astype(k_all.dtype))
            v_all = v_all.at[ai].set(vc.astype(v_all.dtype))
            ai += 1
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, -1] @ params["unembed"]).astype(jnp.float32)
    return logits, {"h": h_all, "conv": conv_all, "k": k_all, "v": v_all,
                    "pos": jnp.asarray(S, jnp.int32)}


def decode_rg(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
              cache: Dict, groups: int = 1):
    x = params["embed"][tokens][:, None, :]
    pos = cache["pos"]
    h_all, conv_all = cache["h"], cache["conv"]
    k_all, v_all = cache["k"], cache["v"]
    ri, ai = 0, 0
    for kind, p in _layer_list(params, cfg):
        if kind == "R":
            x, h, conv = decode_rglru_block(p, cfg, x, h_all[ri],
                                            conv_all[ri], groups)
            h_all = h_all.at[ri].set(h)
            conv_all = conv_all.at[ri].set(conv)
            ri += 1
        else:
            x, kc, vc = L.decode_block(p, cfg, x, k_all[ai], v_all[ai], pos,
                                       groups=groups, window=cfg.window)
            k_all = k_all.at[ai].set(kc)
            v_all = v_all.at[ai].set(vc)
            ai += 1
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits, {"h": h_all, "conv": conv_all, "k": k_all, "v": v_all,
                    "pos": pos + 1}

"""Whisper-large-v3 backbone: encoder-decoder transformer.

[arXiv:2212.04356] 32 encoder + 32 decoder layers, d=1280, 20 MHA heads,
GELU MLPs. The conv audio frontend is a STUB per the assignment:
`input_specs()` supplies precomputed frame embeddings (B, 1500, 1280), i.e.
the output the two-conv downsampler would produce for 30 s of audio.

Decoder layers add cross-attention over the encoder output; at decode time
the cross K/V are projected once (at prefill) and cached.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.distributed.sharding import maybe_shard


def _sinusoid(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _sinusoid_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """Single-position sinusoidal embedding (dynamic pos, no table)."""
    dim = jnp.arange(d // 2).astype(jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_cross_attention(key, cfg: ArchConfig, dtype) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {"wq": L._dense_init(ks[0], (d, nq * hd), dtype=dtype),
            "wk": L._dense_init(ks[1], (d, nkv * hd), dtype=dtype),
            "wv": L._dense_init(ks[2], (d, nkv * hd), dtype=dtype),
            "wo": L._dense_init(ks[3], (nq * hd, d), dtype=dtype)}


def _cross_kv(p: Dict, cfg: ArchConfig, enc: jnp.ndarray):
    B, T, _ = enc.shape
    k = (enc @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (enc @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def apply_cross_attention(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
                          k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    B, S, _ = x.shape
    T = k.shape[1]
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    mask = jnp.ones((1, 1, S, T), bool)
    return L._sdpa(q, k, v, mask, cfg.q_per_kv) @ p["wo"]


def init_dec_block(key, cfg: ArchConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L._norm_init(cfg.d_model),
            "attn": L.init_attention(k1, cfg, dtype),
            "ln_x": L._norm_init(cfg.d_model),
            "xattn": init_cross_attention(k2, cfg, dtype),
            "ln2": L._norm_init(cfg.d_model),
            "mlp": L.init_mlp(k3, cfg, dtype)}


def init_whisper(key: jax.Array, cfg: ArchConfig, tp: int = 16) -> Dict:
    V = cfg.vocab_padded(tp)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: L.init_block(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(
        jax.random.split(ks[1], cfg.n_layers))
    return {"enc_layers": enc, "enc_ln": L._norm_init(d),
            "dec_layers": dec, "ln_f": L._norm_init(d),
            "embed": L._dense_init(ks[2], (V, d), scale_dim=d, dtype=dtype),
            "unembed": L._dense_init(ks[3], (d, V), dtype=dtype)}


def encode(params: Dict, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, n_frames, d) precomputed embeddings (frontend stub)."""
    x = maybe_shard(
        frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype))

    def body(x, lp):
        return maybe_shard(L.apply_block(lp, cfg, x, causal=False)), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_ln"])


def forward_whisper(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
                    frames: jnp.ndarray, groups: int = 1) -> jnp.ndarray:
    """Teacher-forced training forward: returns decoder logits (B,S,V)."""
    enc = encode(params, cfg, frames)
    x = maybe_shard(params["embed"][tokens])
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, lp):
        x = x + L.apply_attention(lp["attn"], cfg, L.rms_norm(x, lp["ln1"]))
        k, v = _cross_kv(lp["xattn"], cfg, enc)
        x = x + apply_cross_attention(lp["xattn"], cfg,
                                      L.rms_norm(x, lp["ln_x"]), k, v)
        x = x + L.apply_mlp(lp["mlp"], cfg, L.rms_norm(x, lp["ln2"]), groups)
        return maybe_shard(x), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["ln_f"])
    return (x @ params["unembed"]).astype(jnp.float32)


def init_cache_whisper(cfg: ArchConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16) -> Dict:
    Lb, F = cfg.n_layers, cfg.n_audio_frames
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((Lb, batch, max_seq, hkv, hd), dtype),
            "v": jnp.zeros((Lb, batch, max_seq, hkv, hd), dtype),
            "xk": jnp.zeros((Lb, batch, F, hkv, hd), dtype),
            "xv": jnp.zeros((Lb, batch, F, hkv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill_whisper(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
                    frames: jnp.ndarray, cache: Dict, groups: int = 1):
    """Encode audio, project cross-KV once, run the decoder prompt."""
    enc = encode(params, cfg, frames)
    B, S = tokens.shape
    T = cache["k"].shape[2]
    x = params["embed"][tokens]
    x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"])
        q, k, v = L._qkv(lp["attn"], cfg, h, jnp.arange(S)[None, :])
        attn = L._sdpa(q, k, v, L.causal_mask(S), cfg.q_per_kv) @ \
            lp["attn"]["wo"]
        x = x + attn
        xk, xv = _cross_kv(lp["xattn"], cfg, enc)
        x = x + apply_cross_attention(lp["xattn"], cfg,
                                      L.rms_norm(x, lp["ln_x"]), xk, xv)
        x = x + L.apply_mlp(lp["mlp"], cfg, L.rms_norm(x, lp["ln2"]), groups)
        kc = jnp.zeros((B, T, cfg.n_kv_heads, cfg.head_dim), k.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
        return x, (kc, vc, xk, xv)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (kc, vc, xk, xv) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, -1] @ params["unembed"]).astype(jnp.float32)
    dt = cache["k"].dtype
    return logits, {"k": kc.astype(dt), "v": vc.astype(dt),
                    "xk": xk.astype(dt), "xv": xv.astype(dt),
                    "pos": jnp.asarray(S, jnp.int32)}


def decode_whisper(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
                   cache: Dict, groups: int = 1):
    x = params["embed"][tokens][:, None, :]
    pos = cache["pos"]
    x = x + _sinusoid_at(pos, cfg.d_model)[None, None].astype(x.dtype)

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        a, kc, vc = L.decode_attention(lp["attn"], cfg,
                                       L.rms_norm(x, lp["ln1"]), kc, vc, pos)
        x = x + a
        x = x + apply_cross_attention(lp["xattn"], cfg,
                                      L.rms_norm(x, lp["ln_x"]),
                                      xk.astype(x.dtype), xv.astype(x.dtype))
        x = x + L.apply_mlp(lp["mlp"], cfg, L.rms_norm(x, lp["ln2"]), groups)
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"], cache["xk"],
                                         cache["xv"]))
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits, {"k": kc, "v": vc, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}

from repro.models.config import ArchConfig
from repro.models.registry import get_api, ModelAPI
__all__ = ["ArchConfig", "get_api", "ModelAPI"]

from repro.models.config import ArchConfig
from repro.models.registry import get_api, ModelAPI

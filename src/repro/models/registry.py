"""Uniform model API over the 10 assigned architectures."""
from __future__ import annotations

from typing import Callable, NamedTuple


from repro.models.config import ArchConfig
from repro.models import lm, rglru, rwkv6, whisper


class ModelAPI(NamedTuple):
    init: Callable          # (key, cfg, tp) -> params
    forward: Callable       # (params, cfg, batch, groups) -> logits (B,S,V)
    init_cache: Callable    # (cfg, batch, max_seq, dtype) -> cache
    prefill: Callable       # (params, cfg, batch, cache, groups) -> (logits, cache)
    decode: Callable        # (params, cfg, tokens, cache, groups) -> (logits, cache)
    has_decode: bool = True


def _lm_api() -> ModelAPI:
    return ModelAPI(
        init=lm.init_lm,
        forward=lambda p, c, b, g: lm.forward_lm(p, c, b["tokens"], g),
        init_cache=lm.init_cache_lm,
        prefill=lambda p, c, b, cache, g: lm.prefill_lm(p, c, b["tokens"],
                                                        cache, g),
        decode=lm.decode_lm,
    )


def _vlm_api() -> ModelAPI:
    return ModelAPI(
        init=lm.init_lm,
        forward=lambda p, c, b, g: lm.forward_lm(
            p, c, b["tokens"], g, prefix_embeds=b.get("patches")),
        init_cache=lm.init_cache_lm,
        # Serving prefill/decode operate on the text stream (vision prefix
        # enters as embeddings during prefill in a full deployment; the
        # assigned decode cells are text-decode against the KV cache).
        prefill=lambda p, c, b, cache, g: lm.prefill_lm(p, c, b["tokens"],
                                                        cache, g),
        decode=lm.decode_lm,
    )


def _rg_api() -> ModelAPI:
    return ModelAPI(
        init=rglru.init_rg,
        forward=lambda p, c, b, g: rglru.forward_rg(p, c, b["tokens"], g),
        init_cache=rglru.init_cache_rg,
        prefill=lambda p, c, b, cache, g: rglru.prefill_rg(p, c, b["tokens"],
                                                           cache, g),
        decode=rglru.decode_rg,
    )


def _rwkv_api() -> ModelAPI:
    return ModelAPI(
        init=rwkv6.init_rwkv,
        forward=lambda p, c, b, g: rwkv6.forward_rwkv(p, c, b["tokens"], g),
        init_cache=rwkv6.init_cache_rwkv,
        prefill=lambda p, c, b, cache, g: rwkv6.prefill_rwkv(
            p, c, b["tokens"], cache, g),
        decode=rwkv6.decode_rwkv,
    )


def _whisper_api() -> ModelAPI:
    return ModelAPI(
        init=whisper.init_whisper,
        forward=lambda p, c, b, g: whisper.forward_whisper(
            p, c, b["tokens"], b["frames"], g),
        init_cache=whisper.init_cache_whisper,
        prefill=lambda p, c, b, cache, g: whisper.prefill_whisper(
            p, c, b["tokens"], b["frames"], cache, g),
        decode=whisper.decode_whisper,
    )


_FAMILIES = {
    "dense": _lm_api,
    "moe": _lm_api,
    "vlm": _vlm_api,
    "hybrid": _rg_api,
    "ssm": _rwkv_api,
    "encdec": _whisper_api,
}


def get_api(cfg: ArchConfig) -> ModelAPI:
    return _FAMILIES[cfg.family]()

"""Decoder-only LM: dense / MoE / sliding-window / VLM-backbone variants.

Covers mixtral-8x7b, dbrx-132b, phi4-mini, nemotron-4-340b, qwen3-14b,
command-r-plus-104b and pixtral-12b (whose patch frontend is a stub per the
assignment: precomputed patch embeddings enter as a prefix).

Layers are weight-stacked and driven by `lax.scan` so HLO size / compile
time stay flat in depth; the scan body is rematerialized when cfg.remat.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.distributed.sharding import maybe_shard


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def init_lm(key: jax.Array, cfg: ArchConfig, tp: int = 16) -> Dict:
    V = cfg.vocab_padded(tp)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    dtype = _dtype(cfg)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: L.init_block(k, cfg, dtype))(layer_keys)
    return {
        "embed": L._dense_init(ks[1], (V, d), scale_dim=d, dtype=dtype),
        "layers": stacked,
        "ln_f": L._norm_init(d),
        "unembed": L._dense_init(ks[2], (d, V), dtype=dtype),
    }


def _window(cfg: ArchConfig) -> int:
    return cfg.window if cfg.attention == "sliding" else 0


def forward_lm(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
               groups: int = 1,
               prefix_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens: (B, S_text) int32; prefix_embeds: (B, S_img, d) (pixtral stub).

    Returns logits (B, S, vocab_padded) in f32.
    """
    x = maybe_shard(params["embed"][tokens])         # (B, S_text, d)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    win = _window(cfg)

    def body(x, layer_params):
        x = L.apply_block(layer_params, cfg, x, groups=groups, window=win)
        return maybe_shard(x), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"])
    return (x @ params["unembed"]).astype(jnp.float32)


def init_cache_lm(cfg: ArchConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> Dict:
    win = _window(cfg)
    T = min(max_seq, win) if win else max_seq
    shape = (cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill_lm(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
               cache: Dict, groups: int = 1) -> Tuple[jnp.ndarray, Dict]:
    """Run the full prompt, fill the KV cache, return last-position logits.

    Implemented as the train-mode forward plus cache writes: the lowered
    HLO is the standard prefill (compute-bound, no decode loop).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    win = _window(cfg)
    T = cache["k"].shape[2]

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"])
        positions = jnp.arange(S)[None, :]
        q, k, v = L._qkv(lp["attn"], cfg, h, positions)
        mask = L.causal_mask(S, win)
        attn = L._sdpa(q, k, v, mask, cfg.q_per_kv) @ lp["attn"]["wo"]
        x = x + attn
        x = x + L.apply_mlp(lp["mlp"], cfg, L.rms_norm(x, lp["ln2"]), groups)
        # Cache the last T positions. Ring layout: position p lives at slot
        # p % T, so decode_attention's ring arithmetic continues seamlessly.
        if win and S > T:
            k_keep, v_keep = k[:, -T:], v[:, -T:]
            kc = jnp.roll(k_keep, S % T, axis=1)
            vc = jnp.roll(v_keep, S % T, axis=1)
        else:
            kc = jnp.zeros((B, T, cfg.n_kv_heads, cfg.head_dim), k.dtype)
            vc = jnp.zeros_like(kc)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
        return x, (kc, vc)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (kc, vc) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, -1] @ params["unembed"]).astype(jnp.float32)
    new_cache = {"k": kc.astype(cache["k"].dtype),
                 "v": vc.astype(cache["v"].dtype),
                 "pos": jnp.asarray(S, jnp.int32)}
    return logits, new_cache


def decode_lm(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
              cache: Dict, groups: int = 1) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. tokens: (B,) int32. Returns (logits (B, V), cache)."""
    x = params["embed"][tokens][:, None, :]          # (B,1,d)
    win = _window(cfg)
    pos = cache["pos"]

    def body(x, xs):
        lp, kc, vc = xs
        x, kc, vc = L.decode_block(lp, cfg, x, kc, vc, pos, groups=groups,
                                   window=win)
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits, {"k": kc, "v": vc, "pos": pos + 1}

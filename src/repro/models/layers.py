"""Shared transformer building blocks (pure-functional JAX).

Conventions:
- params are plain dicts of jnp arrays; every block has init_* / apply_*.
- apply_* handles the full-sequence (train/prefill) path; decode_* handles
  one-token inference against a cache.
- dtype: computations run in cfg.dtype (bf16 at scale), norms/softmax in f32.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.distributed.sharding import maybe_shard

Params = Dict[str, jnp.ndarray]


def _norm_init(d):
    return jnp.ones((d,), jnp.float32)


def _dense_init(key, shape, scale_dim=None, dtype=jnp.bfloat16):
    scale = (scale_dim or shape[0]) ** -0.5
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # Variance in f32 for stability, but the normalizing multiply stays in
    # the input dtype: no f32 (B,S,d) tensor ever reaches HBM (the f32
    # residual-stream copies were a top HBM-traffic term — EXPERIMENTS.md
    # §Perf iteration B3).
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    scale = (jax.lax.rsqrt(var + eps) * w).astype(x.dtype)
    return x * scale


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (full / sliding window), train + decode paths
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, nq * hd), dtype=dtype),
        "wk": _dense_init(ks[1], (d, nkv * hd), dtype=dtype),
        "wv": _dense_init(ks[2], (d, nkv * hd), dtype=dtype),
        "wo": _dense_init(ks[3], (nq * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = _norm_init(hd)
        p["k_norm"] = _norm_init(hd)
    return p


def _qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray, positions):
    B = x.shape[0]
    S = x.shape[1]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, q_per_kv, scores_f32: bool = True):
    """q: (B,S,Hq,hd), k/v: (B,T,Hkv,hd), mask: (B|1, 1, S, T) bool."""
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    qg = q.reshape(B, S, Hkv, q_per_kv, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / (hd ** 0.5)
    if scores_f32:
        scores = scores.astype(jnp.float32)
    neg = jnp.asarray(-1e30 if scores_f32 else -3e38, scores.dtype)
    scores = jnp.where(mask[:, :, None], scores, neg)      # (B,1|k,1,S,T)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, Hq * hd)


def causal_mask(S: int, window: int = 0) -> jnp.ndarray:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window:
        m &= (i - j) < window
    return m[None, None]   # (1,1,S,S)


def apply_attention(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                    window: int = 0, causal: bool = True,
                    positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    if causal:
        mask = causal_mask(S, window)
    else:
        mask = jnp.ones((1, 1, S, S), bool)
    out = _sdpa(q, k, v, mask, cfg.q_per_kv, cfg.attn_scores_f32)
    return out @ p["wo"]


def decode_attention(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray, window: int = 0):
    """One-token decode. x: (B,1,d); caches: (B,T,Hkv,hd); pos: () int32.

    Full attention: T = max_seq, write at index pos, attend to slots <= pos.
    Sliding window: T = window ring buffer, write at pos % T, attend to
    valid slots (slot written and within the window).
    Returns (out (B,1,d), k_cache, v_cache).
    """
    B = x.shape[0]
    T = k_cache.shape[1]
    q, k, v = _qkv(p, cfg, x, pos[None, None] if pos.ndim == 0 else pos)
    slot = pos % T if window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
    idx = jnp.arange(T)
    if window:
        # Slot j holds absolute position: valid iff that position <= pos and
        # within the last `T` positions (ring semantics).
        age = (slot - idx) % T          # how long ago slot j was written
        valid = (idx <= slot) | (pos >= T)
        mask = valid & (age < T)
    else:
        mask = idx <= pos
    mask = jnp.broadcast_to(mask[None, None, None, :], (B, 1, 1, T))
    out = _sdpa(q, k_cache.astype(v.dtype), v_cache.astype(v.dtype),
                mask, cfg.q_per_kv)
    return out @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs: SwiGLU / squared-ReLU / GELU, dense and MoE (grouped capacity routing)
# ---------------------------------------------------------------------------

def _gated(cfg: ArchConfig) -> bool:
    return cfg.activation in ("swiglu", "geglu")


def init_mlp(key, cfg: ArchConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    if cfg.n_experts:
        E = cfg.n_experts
        p = {"router": _dense_init(ks[3], (d, E), dtype=jnp.float32),
             "w1": _dense_init(ks[0], (E, d, f), scale_dim=d, dtype=dtype),
             "w2": _dense_init(ks[1], (E, f, d), scale_dim=f, dtype=dtype)}
        if _gated(cfg):
            p["w3"] = _dense_init(ks[2], (E, d, f), scale_dim=d, dtype=dtype)
        return p
    p = {"w1": _dense_init(ks[0], (d, f), dtype=dtype),
         "w2": _dense_init(ks[1], (f, d), dtype=dtype)}
    if _gated(cfg):
        p["w3"] = _dense_init(ks[2], (d, f), dtype=dtype)
    return p


def _act(cfg: ArchConfig, a, b=None):
    if cfg.activation == "swiglu":
        return jax.nn.silu(a) * b
    if cfg.activation == "geglu":
        return jax.nn.gelu(a) * b
    if cfg.activation == "relu2":
        r = jax.nn.relu(a)
        return r * r
    return jax.nn.gelu(a)


def apply_dense_mlp(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    a = x @ p["w1"]
    b = x @ p["w3"] if _gated(cfg) else None
    return _act(cfg, a, b) @ p["w2"]


def apply_moe(p: Params, cfg: ArchConfig, x: jnp.ndarray, groups: int = 1,
              capacity_factor: float = 1.25) -> jnp.ndarray:
    """Grouped capacity-based top-k MoE (DESIGN.md §5).

    Tokens are split into `groups` independent routing groups (one per
    data-parallel shard at scale, so routing gathers stay device-local under
    GSPMD). Per group and expert, the top-C tokens by gate weight are
    gathered, run through the expert densely, and scattered back weighted.
    FLOPs = groups * E * C * mlp ~= top_k * T * mlp * capacity_factor —
    i.e. the true active-parameter FLOPs, not the E/top_k-inflated count.
    Dropped tokens (beyond capacity) fall through with zero MLP output —
    standard token-dropping semantics.
    """
    B, S, d = x.shape
    E, topk = cfg.n_experts, cfg.top_k
    T = B * S
    G = min(groups, T)
    Tg = T // G
    xg = maybe_shard(x.reshape(G, Tg, d), "moe_gtd")
    # Router matmul in activation dtype, THEN upcast: the cotangent toward
    # xg stays bf16 (upcasting xg itself made every MoE layer's backward
    # carry f32 (B,S,d) tensors — §Perf iteration B4).
    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, topk)           # (G,Tg,topk)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    # Gate weight per (token, expert): prob if selected else 0.
    gate = jnp.zeros((G, Tg, E), jnp.float32)
    gate = jax.vmap(lambda g, i, v: g.at[jnp.arange(Tg)[:, None], i].set(v)
                    )(gate, top_idx, top_vals)               # (G,Tg,E)
    C = max(1, int(topk * Tg * capacity_factor / E))
    sel_vals, sel_idx = jax.lax.top_k(gate.transpose(0, 2, 1), C)  # (G,E,C)
    xe = jnp.take_along_axis(xg[:, None], sel_idx[..., None], axis=2)
    xe = maybe_shard(xe, "moe_gecd")
    a = maybe_shard(jnp.einsum("gecd,edf->gecf", xe, p["w1"]), "moe_gecf")
    b = (maybe_shard(jnp.einsum("gecd,edf->gecf", xe, p["w3"]), "moe_gecf")
         if _gated(cfg) else None)
    h = _act(cfg, a, b)
    y = maybe_shard(jnp.einsum("gecf,efd->gecd", h, p["w2"]), "moe_gecd")
    y = y * sel_vals[..., None].astype(y.dtype)
    # Scatter-add back to token order (vmapped over groups).
    def scatter(yg, ig):
        return jnp.zeros((Tg, d), y.dtype).at[ig.reshape(-1)].add(
            yg.reshape(-1, d))
    out = jax.vmap(scatter)(y, sel_idx)                      # (G,Tg,d)
    return out.reshape(B, S, d)


def apply_mlp(p: Params, cfg: ArchConfig, x: jnp.ndarray,
              groups: int = 1) -> jnp.ndarray:
    if cfg.n_experts:
        return apply_moe(p, cfg, x, groups)
    return apply_dense_mlp(p, cfg, x)


# ---------------------------------------------------------------------------
# Standard pre-norm transformer block (attention + MLP)
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg.d_model), "attn": init_attention(k1, cfg, dtype),
            "ln2": _norm_init(cfg.d_model), "mlp": init_mlp(k2, cfg, dtype)}


def apply_block(p: Params, cfg: ArchConfig, x: jnp.ndarray, groups: int = 1,
                window: int = 0, causal: bool = True,
                positions=None) -> jnp.ndarray:
    x = x + apply_attention(p["attn"], cfg, rms_norm(x, p["ln1"]),
                            window=window, causal=causal, positions=positions)
    x = x + apply_mlp(p["mlp"], cfg, rms_norm(x, p["ln2"]), groups)
    return x


def decode_block(p: Params, cfg: ArchConfig, x: jnp.ndarray, k_cache,
                 v_cache, pos, groups: int = 1, window: int = 0):
    a, k_cache, v_cache = decode_attention(p["attn"], cfg,
                                           rms_norm(x, p["ln1"]),
                                           k_cache, v_cache, pos, window)
    x = x + a
    x = x + apply_mlp(p["mlp"], cfg, rms_norm(x, p["ln2"]), groups)
    return x, k_cache, v_cache

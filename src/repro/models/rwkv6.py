"""RWKV-6 "Finch": attention-free linear recurrence with data-dependent decay.

[arXiv:2404.05892] Per head (dk = dv = 64), matrix-valued state S:
    out_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t
with w_t = exp(-exp(x' W_w lora)) data-dependent per channel, plus
token-shift mixing on all projections and a squared-ReLU channel-mix FFN.

Training uses the chunked-parallel form (GLA-style): within a chunk of
length Lc the pairwise decay products are materialized as
exp(lp_{t-1} - lp_j) <= 1 (numerically safe because log-decay cumsums are
monotone decreasing), the cross-chunk state is carried by `lax.scan`.
Decode carries S (B, H, dk, dv) — O(1) per token, which is what makes the
long_500k cell runnable for this arch.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.distributed.sharding import maybe_shard

_CHUNK = 64
_LORA = 64


def init_rwkv_block(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    f = cfg.d_ff
    ks = jax.random.split(key, 12)
    return {
        "ln1": L._norm_init(d),
        # Token-shift mix coefficients (static part of RWKV6's ddlerp).
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "wr": L._dense_init(ks[0], (d, d), dtype=dtype),
        "wk": L._dense_init(ks[1], (d, d), dtype=dtype),
        "wv": L._dense_init(ks[2], (d, d), dtype=dtype),
        "wg": L._dense_init(ks[3], (d, d), dtype=dtype),
        # Data-dependent decay, low-rank: w0 + tanh(x Wa) Wb.
        "w0": jnp.full((d,), -0.6, jnp.float32),
        "wa": L._dense_init(ks[4], (d, _LORA), dtype=dtype),
        "wb": L._dense_init(ks[5], (_LORA, d), scale_dim=_LORA, dtype=dtype),
        "u": 0.5 * jax.random.normal(ks[6], (d,), jnp.float32),   # bonus
        "wo": L._dense_init(ks[7], (d, d), dtype=dtype),
        "ln_x": L._norm_init(d),
        # Channel mix.
        "ln2": L._norm_init(d),
        "mu_ck": jnp.full((d,), 0.5, jnp.float32),
        "mu_cr": jnp.full((d,), 0.5, jnp.float32),
        "ck": L._dense_init(ks[8], (d, f), dtype=dtype),
        "cv": L._dense_init(ks[9], (f, d), dtype=dtype),
        "cr": L._dense_init(ks[10], (d, d), dtype=dtype),
    }


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token shift: x_{t-1} (B,S,d); prev = last token of previous segment."""
    B, S, d = x.shape
    first = prev[:, None] if prev is not None else jnp.zeros((B, 1, d), x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _heads(x: jnp.ndarray, H: int) -> jnp.ndarray:
    B, S, d = x.shape
    return x.reshape(B, S, H, d // H)


def _wkv_chunked(r, k, v, logw, u, state0, chunk=_CHUNK):
    """Chunked linear-attention core.

    r,k,v: (B,S,H,dh) f32; logw: (B,S,H,dh) f32 (< 0); u: (H,dh);
    state0: (B,H,dk,dv). Returns (out (B,S,H,dh), state (B,H,dk,dv)).
    """
    B, S, H, dh = r.shape
    Lc = min(chunk, S)
    assert S % Lc == 0, f"seq {S} not divisible by chunk {Lc}"
    nC = S // Lc
    def resh(x):
        # -> (nC, B, H, Lc, dh)
        return x.reshape(B, nC, Lc, H, dh).transpose(1, 0, 3, 2, 4)

    r, k, v, logw = resh(r), resh(k), resh(v), resh(logw)

    def chunk(state, xs):
        rc, kc, vc, lwc = xs                       # (B,H,Lc,dh)
        lp = jnp.cumsum(lwc, axis=2)               # (B,H,Lc,dh), decreasing
        lp_prev = lp - lwc                         # lp_{t-1} (exclusive)
        # Intra-chunk scores_tj = sum_d r_t[d] k_j[d] exp(lp_{t-1,d}-lp_{j,d})
        # FACTORIZED two-sided form (§Perf iteration C2):
        #   r_s = r * exp(lp_prev)  (<= 1, safe)
        #   k_s = k * exp(-lp)      (bounded: per-chunk |lp| <= 60 via the
        #                            decay clamp in _time_mix)
        # — the naive O(Lc^2 * dh) pairwise-decay tensor was ~45% of this
        # arch's entire HBM traffic.
        r_s = rc * jnp.exp(lp_prev)
        k_s = kc * jnp.exp(-lp)
        scores = jnp.einsum("bhtd,bhjd->bhtj", r_s, k_s)
        tri = jnp.tril(jnp.ones((Lc, Lc)), k=-1)   # strictly lower (j < t)
        scores = scores * tri[None, None]
        out = jnp.einsum("bhtj,bhjd->bhtd", scores, vc)
        # Bonus diagonal term: r_t . (u * k_t) v_t.
        ub = u[None, :, None, :]                   # (1,H,1,dh)
        diag = jnp.sum(rc * ub * kc, axis=-1)      # (B,H,Lc)
        out = out + diag[..., None] * vc
        # Cross-chunk: contribution of carried state (reuses r_s).
        out = out + jnp.einsum("bhtd,bhde->bhte", r_s, state)
        # State update: S' = D(exp(lp_L)) S + sum_j (k_j exp(lp_L - lp_j)) v_j
        lp_end = lp[:, :, -1:, :]                  # (B,H,1,dh)
        kd = k_s * jnp.exp(lp_end)                 # (B,H,Lc,dh)
        state = state * jnp.exp(lp_end.squeeze(2))[..., None] + \
            jnp.einsum("bhtd,bhte->bhde", kd, vc)
        return state, out

    state, outs = jax.lax.scan(chunk, state0, (r, k, v, logw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dh)
    return out, state


def _time_mix(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
              state0, x_prev):
    """x: (B,S,d) normed. Returns (out, new_state, last_x)."""
    B, S, d = x.shape
    H = d // cfg.rwkv_head_dim
    xs = _shift(x, x_prev)
    r = _mix(x, xs, p["mu_r"]) @ p["wr"]
    k = _mix(x, xs, p["mu_k"]) @ p["wk"]
    v = _mix(x, xs, p["mu_v"]) @ p["wv"]
    g = _mix(x, xs, p["mu_g"]) @ p["wg"]
    xw = _mix(x, xs, p["mu_w"])
    loglog_w = p["w0"] + jnp.tanh(xw @ p["wa"]).astype(jnp.float32) @ \
        p["wb"].astype(jnp.float32)
    logw = -jnp.exp(loglog_w.astype(jnp.float32))          # < 0
    # Per-step decay clamp: per-chunk cumulative |log decay| <= 60, so the
    # factorized chunked form (exp(-lp) <= e^60 < f32 max) cannot overflow.
    # A clamped channel still decays to e^-60 within one chunk — fully
    # forgotten — so the recurrence semantics are unchanged in practice.
    logw = jnp.maximum(logw, -60.0 / max(cfg.rwkv_chunk, 1))

    def to_h(t):
        return _heads(t.astype(jnp.float32), H)

    u = p["u"].reshape(H, cfg.rwkv_head_dim)
    out, state = _wkv_chunked(to_h(r), to_h(k), to_h(v), _heads(logw, H),
                              u, state0, chunk=cfg.rwkv_chunk)
    out = out.reshape(B, S, d)
    out = L.rms_norm(out, p["ln_x"])                       # group-norm stand-in
    out = (out * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    return out @ p["wo"], state, x[:, -1]


def _channel_mix(p: Dict, x: jnp.ndarray, x_prev):
    xs = _shift(x, x_prev)
    k = _mix(x, xs, p["mu_ck"]) @ p["ck"]
    r = _mix(x, xs, p["mu_cr"]) @ p["cr"]
    kk = jax.nn.relu(k)
    return (jax.nn.sigmoid(r.astype(jnp.float32)).astype(x.dtype) *
            ((kk * kk) @ p["cv"])), x[:, -1]


def apply_rwkv_block(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
                     state0=None, tm_prev=None, cm_prev=None):
    B, _, d = x.shape
    H = d // cfg.rwkv_head_dim
    if state0 is None:
        state0 = jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                           jnp.float32)
    xin = L.rms_norm(x, p["ln1"])
    a, state, tm_last = _time_mix(p, cfg, xin, state0, tm_prev)
    x = x + a
    xin2 = L.rms_norm(x, p["ln2"])
    c, cm_last = _channel_mix(p, xin2, cm_prev)
    x = x + c
    return x, (state, tm_last, cm_last)


def init_rwkv(key: jax.Array, cfg: ArchConfig, tp: int = 16) -> Dict:
    V = cfg.vocab_padded(tp)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: init_rwkv_block(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_layers))
    return {"embed": L._dense_init(ks[1], (V, d), scale_dim=d, dtype=dtype),
            "layers": stacked, "ln_f": L._norm_init(d),
            "unembed": L._dense_init(ks[2], (d, V), dtype=dtype)}


def forward_rwkv(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
                 groups: int = 1) -> jnp.ndarray:
    x = maybe_shard(params["embed"][tokens])

    def body(x, lp):
        x, _ = apply_rwkv_block(lp, cfg, x)
        return maybe_shard(x), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"])
    return (x @ params["unembed"]).astype(jnp.float32)


def init_cache_rwkv(cfg: ArchConfig, batch: int, max_seq: int,
                    dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    Lb = cfg.n_layers
    return {"s": jnp.zeros((Lb, batch, H, cfg.rwkv_head_dim,
                            cfg.rwkv_head_dim), jnp.float32),
            "tm": jnp.zeros((Lb, batch, d), dtype),
            "cm": jnp.zeros((Lb, batch, d), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill_rwkv(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
                 cache: Dict, groups: int = 1):
    """Run the prompt, return (last logits, recurrent states)."""
    x = params["embed"][tokens]

    def body(x, lp):
        x, (s, tm, cm) = apply_rwkv_block(lp, cfg, x)
        return x, (s, tm, cm)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (s, tm, cm) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, -1] @ params["unembed"]).astype(jnp.float32)
    dt = cache["tm"].dtype
    return logits, {"s": s, "tm": tm.astype(dt), "cm": cm.astype(dt),
                    "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_rwkv(params: Dict, cfg: ArchConfig, tokens: jnp.ndarray,
                cache: Dict, groups: int = 1):
    x = params["embed"][tokens][:, None, :]

    def body(x, xs):
        lp, s0, tm0, cm0 = xs
        x, (s, tm, cm) = apply_rwkv_block(lp, cfg, x, s0,
                                          tm0.astype(x.dtype),
                                          cm0.astype(x.dtype))
        return x, (s, tm.astype(cm0.dtype), cm.astype(cm0.dtype))

    x, (s, tm, cm) = jax.lax.scan(body, x, (params["layers"], cache["s"],
                                            cache["tm"], cache["cm"]))
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits, {"s": s, "tm": tm, "cm": cm, "pos": cache["pos"] + 1}

"""Multi-model registry: one process serves several fitted models by name.

A registry row owns the FittedModel and lazily a MicroBatcher (sync) and
an AsyncBatcher (async, SLO-accounted) per model, so
`registry.batcher("segmentation").assign_batch(Xq)` or
`registry.scheduler("segmentation").submit(Xq)` is the whole serving
call, and `registry.latency_summary("segmentation")` is the monitoring
read-out. Loading is artifact-directory based; registering the same name
twice requires overwrite=True to avoid silently hot-swapping a live model.
"""
from __future__ import annotations

from typing import Dict, List

from repro.serve.artifact import FittedModel, load_model, save_model
from repro.serve.batcher import MicroBatcher
from repro.serve.scheduler import AsyncBatcher


class ModelRegistry:
    def __init__(self):
        self._models: Dict[str, FittedModel] = {}
        self._batchers: Dict[str, MicroBatcher] = {}
        self._schedulers: Dict[str, AsyncBatcher] = {}

    def register(self, name: str, model: FittedModel,
                 overwrite: bool = False) -> FittedModel:
        if name in self._models and not overwrite:
            raise ValueError(f"model {name!r} already registered "
                             f"(overwrite=True to replace)")
        self._models[name] = model
        self._batchers.pop(name, None)
        self._drop_scheduler(name)
        return model

    def get(self, name: str) -> FittedModel:
        if name not in self._models:
            raise KeyError(f"no model {name!r}; have {self.names()}")
        return self._models[name]

    def unregister(self, name: str) -> None:
        self._models.pop(name, None)
        self._batchers.pop(name, None)
        self._drop_scheduler(name)

    def _drop_scheduler(self, name: str) -> None:
        """Stop + flush a model's AsyncBatcher so no future is orphaned."""
        sched = self._schedulers.pop(name, None)
        if sched is not None:
            sched.stop()

    def names(self) -> List[str]:
        return sorted(self._models)

    def load(self, name: str, artifact_dir: str,
             overwrite: bool = False) -> FittedModel:
        return self.register(name, load_model(artifact_dir), overwrite)

    def save(self, name: str, artifact_dir: str) -> str:
        return save_model(self.get(name), artifact_dir)

    def batcher(self, name: str, **kwargs) -> MicroBatcher:
        """Per-model MicroBatcher, cached so its executable stats persist.

        kwargs are only honoured on first construction for a given name;
        they include the stripe-engine overrides (embed_fused=/interpret=
        force the fused extend_embed Pallas path, fused= the Pallas
        kmeans_assign argmin — see extend.resolve_pallas_path).
        """
        if name not in self._batchers:
            self._batchers[name] = MicroBatcher(self.get(name), **kwargs)
        return self._batchers[name]

    def scheduler(self, name: str, **kwargs) -> AsyncBatcher:
        """Per-model AsyncBatcher, cached so its LatencyStats accumulate
        across callers (the SLO read-out is per model, not per client).

        kwargs are only honoured on first construction for a given name;
        the caller owns start()/stop() of the pump thread.
        """
        if name not in self._schedulers:
            self._schedulers[name] = AsyncBatcher(self.get(name), **kwargs)
        return self._schedulers[name]

    def latency_summary(self, name: str) -> Dict:
        """LatencyStats summary of a model's async path (see
        serve/latency.py); raises KeyError until scheduler(name) exists."""
        if name not in self._schedulers:
            raise KeyError(f"no async scheduler for {name!r}; call "
                           f"scheduler({name!r}) first")
        return self._schedulers[name].latency.summary()


# Process-wide default registry (what the serve_cluster CLI drives).
DEFAULT_REGISTRY = ModelRegistry()


def register(name: str, model: FittedModel,
             overwrite: bool = False) -> FittedModel:
    return DEFAULT_REGISTRY.register(name, model, overwrite)


def get(name: str) -> FittedModel:
    return DEFAULT_REGISTRY.get(name)

"""Multi-model registry + model lifecycle: load, serve, warm hot-swap, GC.

A registry row owns a FittedModel's whole serving lifetime: the model
itself, lazily a MicroBatcher (sync) and an AsyncBatcher (async,
SLO-accounted) — each remembered together with its construction kwargs —
and the optional version tag it was published under, so
`registry.batcher("segmentation").assign_batch(Xq)` or
`registry.scheduler("segmentation").submit(Xq)` is the whole serving call
and `registry.latency_summary("segmentation")` the monitoring read-out.

Model replacement comes in two shapes:

  cold  `register(name, model, overwrite=True)` — drops the row's cached
        batchers (every compiled bucket executable with them) and stops
        the old scheduler. First query on the new row pays compile.
  warm  `swap(name, model)` — pre-builds the new row's batchers with the
        SAME construction kwargs, warms every bucket executable the old
        row ever compiled (replaying stats["bucket_hits"]), carries the
        old LatencyStats over, and only then atomically flips the row.
        The old AsyncBatcher is drained into the OLD model — requests it
        accepted resolve against the version that accepted them — and
        retired (post-flip submits on the stale handle raise). The
        returned SwapReport makes the downtime a measured number.

Versioned artifacts live in serve/versions.py (`<root>/v_<N>/` on the
checkpoint layer's atomic-rename commit); `publish()`/`load_version()`
connect a row to a store. Loading is artifact-directory based;
registering the same name twice requires overwrite=True to avoid
silently hot-swapping a live model.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

from repro.serve.artifact import FittedModel, load_model, save_model
from repro.serve.batcher import MicroBatcher
from repro.serve.scheduler import AsyncBatcher
from repro.serve.versions import VersionStore

_MISSING = object()


@dataclasses.dataclass
class SwapReport:
    """What a warm hot-swap measured (the "swap" section of
    BENCH_serve.json serializes this via to_dict()).

    warm_s is paid OFF the serving path (the old row keeps serving while
    the new one compiles); flip_ms is the only window in which neither
    row is authoritative — the measured swap downtime. p95_before_ms is
    the total-latency p95 of the surviving LatencyStats at flip time;
    p95_after_ms stays None until post-swap traffic has run (the swap
    bench fills it from the same surviving stats).
    """
    name: str
    old_version: Optional[int]
    new_version: Optional[int]
    buckets_warmed: List[int]
    warm_s: float
    flip_ms: float
    drain_s: float
    drained_requests: int
    requests_before: int
    p95_before_ms: float
    p95_after_ms: Optional[float] = None

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Row:
    """One model's serving state; construction kwargs are remembered so
    cache hits can detect conflicting overrides and a hot-swap can
    rebuild the row identically."""
    model: FittedModel
    version: Optional[int] = None
    batcher: Optional[MicroBatcher] = None
    batcher_kwargs: Dict = dataclasses.field(default_factory=dict)
    scheduler: Optional[AsyncBatcher] = None
    scheduler_kwargs: Dict = dataclasses.field(default_factory=dict)


class ModelRegistry:
    def __init__(self):
        self._rows: Dict[str, _Row] = {}      # guarded-by: _lock
        # One lock for row-map mutation AND lazy batcher construction:
        # swap() flips under it, so a flip is atomic against concurrent
        # batcher()/scheduler() lookups and other swaps. The guarded-by
        # annotation above is machine-checked by repro.analysis (L001):
        # any _rows mutation outside `with self._lock` fails the build.
        self._lock = threading.Lock()

    def register(self, name: str, model: FittedModel,
                 overwrite: bool = False,
                 version: Optional[int] = None) -> FittedModel:
        """Cold registration; see the module docstring for cold vs warm.

        The replaced row's scheduler (if any) is stopped and drained —
        its pending futures resolve against the model they were
        submitted to — and every cached executable is dropped.
        """
        with self._lock:
            if name in self._rows and not overwrite:
                raise ValueError(f"model {name!r} already registered "
                                 f"(overwrite=True to replace)")
            old = self._rows.get(name)
            self._rows[name] = _Row(model=model, version=version)
        self._retire(old)
        return model

    def get(self, name: str) -> FittedModel:
        return self._row(name).model

    def version(self, name: str) -> Optional[int]:
        """Version tag the row was registered/swapped under (None when
        the model never came from a version store)."""
        return self._row(name).version

    def unregister(self, name: str) -> None:
        with self._lock:
            old = self._rows.pop(name, None)
        self._retire(old)

    def names(self) -> List[str]:
        return sorted(self._rows)

    def _row(self, name: str) -> _Row:
        row = self._rows.get(name)
        if row is None:
            raise KeyError(f"no model {name!r}; have {self.names()}")
        return row

    @staticmethod
    def _retire(row: Optional[_Row]) -> None:
        """Stop + flush a dropped row's AsyncBatcher so no future is
        orphaned; its stale handle rejects later submits."""
        if row is not None and row.scheduler is not None:
            row.scheduler.stop()

    # -- artifact I/O ----------------------------------------------------

    def load(self, name: str, artifact_dir: str,
             overwrite: bool = False) -> FittedModel:
        return self.register(name, load_model(artifact_dir), overwrite)

    def save(self, name: str, artifact_dir: str) -> str:
        return save_model(self.get(name), artifact_dir)

    def publish(self, name: str, store_root: str,
                keep: Optional[int] = None) -> int:
        """Publish the row's model as the next version under store_root
        (keep-last-`keep` GC when set); returns the version number and
        tags the row with it."""
        version = VersionStore(store_root).publish(self.get(name),
                                                   keep=keep)
        self._row(name).version = version
        return version

    def load_version(self, name: str, store_root: str,
                     version: Optional[int] = None,
                     overwrite: bool = False) -> FittedModel:
        """Register a pinned `version` (latest when None) from a version
        store; the row remembers which version it serves."""
        store = VersionStore(store_root)
        v = version if version is not None else store.latest()
        return self.register(name, store.load(v), overwrite=overwrite,
                             version=v)

    # -- serving front-ends ----------------------------------------------

    @staticmethod
    def _check_kwargs(kind: str, name: str, recorded: Dict,
                      requested: Dict) -> None:
        """A cache hit must not silently ignore kwargs: a caller asking
        for e.g. interpret=True would get a cached non-interpret row with
        no signal. Every requested kwarg must match the recorded
        construction exactly (passing none always hits the cache)."""
        for key, val in requested.items():
            have = recorded.get(key, _MISSING)
            if have is val or (have is not _MISSING and have == val):
                continue
            raise ValueError(
                f"{kind}({name!r}) is cached with construction kwargs "
                f"{recorded}; conflicting override {key}={val!r} would be "
                f"silently ignored — match the cached construction, or "
                f"swap()/re-register the model to rebuild it")

    def batcher(self, name: str, **kwargs) -> MicroBatcher:
        """Per-model MicroBatcher, cached so its executable stats persist.

        kwargs are honoured on first construction for a given name and
        remembered; a later call passing DIFFERENT kwargs raises (they
        include the stripe-engine overrides — embed_fused=/interpret=
        force the fused extend_embed Pallas path, fused= the Pallas
        kmeans_assign argmin — see extend.resolve_pallas_path).
        """
        with self._lock:
            row = self._row(name)
            if row.batcher is None:
                row.batcher = MicroBatcher(row.model, **kwargs)
                row.batcher_kwargs = dict(kwargs)
            else:
                self._check_kwargs("batcher", name, row.batcher_kwargs,
                                   kwargs)
            return row.batcher

    def scheduler(self, name: str, **kwargs) -> AsyncBatcher:
        """Per-model AsyncBatcher, cached so its LatencyStats accumulate
        across callers (the SLO read-out is per model, not per client).

        Same kwargs contract as batcher(): remembered at construction,
        conflicting later overrides raise. The caller owns start()/stop()
        of the pump thread.
        """
        with self._lock:
            row = self._row(name)
            if row.scheduler is None:
                row.scheduler = AsyncBatcher(row.model, **kwargs)
                row.scheduler_kwargs = dict(kwargs)
            else:
                self._check_kwargs("scheduler", name, row.scheduler_kwargs,
                                   kwargs)
            return row.scheduler

    def latency_summary(self, name: str) -> Dict:
        """LatencyStats summary of a model's async path (see
        serve/latency.py); raises KeyError until scheduler(name) exists."""
        row = self._row(name)
        if row.scheduler is None:
            raise KeyError(f"no async scheduler for {name!r}; call "
                           f"scheduler({name!r}) first")
        return row.scheduler.latency.summary()

    # -- warm hot-swap ---------------------------------------------------

    def swap(self, name: str, model: FittedModel,
             version: Optional[int] = None) -> SwapReport:
        """Warm hot-swap `name` to `model`; returns the measured SwapReport.

        Ordering — everything expensive happens BEFORE the flip, while
        the old row keeps serving:

          1. build the new row's MicroBatcher / AsyncBatcher with the old
             row's recorded construction kwargs (same engines, same mesh,
             same clock); the new AsyncBatcher inherits the old row's
             LatencyStats object, so p50/p95 history and SLO counters
             survive the swap;
          2. warm every bucket executable the old row ever compiled by
             replaying its stats["bucket_hits"] widths through the new
             row (both the sync batcher's and the scheduler's inner one);
          3. atomically flip the row under the registry lock — the
             measured flip window, the only downtime there is;
          4. restart the pump iff the old one was running, then drain the
             old AsyncBatcher into the OLD model (its accepted requests
             resolve against the version that accepted them) and retire
             it: submits on the stale handle now raise instead of
             stranding futures in a pump-less queue.
        """
        with self._lock:
            old = self._row(name)
            old_batcher, old_scheduler = old.batcher, old.scheduler
        new = _Row(model=model, version=version)
        t0 = time.perf_counter()
        warmed: List[int] = []
        if old_batcher is not None:
            new.batcher = MicroBatcher(model, **old.batcher_kwargs)
            new.batcher_kwargs = dict(old.batcher_kwargs)
            warmed += new.batcher.warm(old_batcher.executables)
        resume_pump = False
        if old_scheduler is not None:
            kwargs = dict(old.scheduler_kwargs)
            kwargs["latency"] = old_scheduler.latency   # survives the swap
            new.scheduler = AsyncBatcher(model, **kwargs)
            new.scheduler_kwargs = dict(old.scheduler_kwargs)
            warmed += new.scheduler.batcher.warm(
                old_scheduler.batcher.executables)
            resume_pump = old_scheduler.running
        warm_s = time.perf_counter() - t0
        stats = old_scheduler.latency if old_scheduler is not None else None
        p95_before = (stats.total.percentile(95.0)
                      if stats is not None else 0.0)
        requests_before = stats.requests if stats is not None else 0

        t1 = time.perf_counter()
        with self._lock:
            # The warm phase ran unlocked (the old row kept serving); the
            # flip only commits if nothing about the row changed meanwhile
            # — not the row itself (a concurrent register/swap) and not
            # its serving state (a concurrent first batcher()/scheduler()
            # call would otherwise be silently discarded and retired).
            if (self._rows.get(name) is not old
                    or old.batcher is not old_batcher
                    or old.scheduler is not old_scheduler):
                raise RuntimeError(
                    f"model {name!r} changed concurrently during swap; "
                    f"retry against the current row")
            self._rows[name] = new
        flip_ms = (time.perf_counter() - t1) * 1e3

        if resume_pump:
            new.scheduler.start()
        t2 = time.perf_counter()
        drained = self._drain(old)
        return SwapReport(
            name=name, old_version=old.version, new_version=version,
            buckets_warmed=sorted(set(warmed)), warm_s=warm_s,
            flip_ms=flip_ms, drain_s=time.perf_counter() - t2,
            drained_requests=drained, requests_before=requests_before,
            p95_before_ms=p95_before)

    @staticmethod
    def _drain(row: _Row) -> int:
        """Retire a flipped-out row; returns requests its stop() flushed."""
        if row.scheduler is None:
            return 0
        return row.scheduler.stop()


# Process-wide default registry (what the serve_cluster CLI drives).
DEFAULT_REGISTRY = ModelRegistry()


def register(name: str, model: FittedModel,
             overwrite: bool = False) -> FittedModel:
    return DEFAULT_REGISTRY.register(name, model, overwrite)


def get(name: str) -> FittedModel:
    return DEFAULT_REGISTRY.get(name)

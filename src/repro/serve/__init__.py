"""repro.serve: fit once, assign millions — out-of-sample inference.

The training side (repro.core) produces a compact linearization
Y = Sigma^{1/2} U^T of the kernel matrix; this package turns that fit into
a deployable service:

  artifact.py   FittedModel pytree + atomic save/load (ModelSpec sidecar,
                arrays via repro.distributed.checkpoint)
  extend.py     streaming Nystrom-style out-of-sample extension
                y(x) = Sigma^{-1/2} U^T kappa(X_train, x) and cluster
                assignment (jnp or fused Pallas kmeans_assign path)
  batcher.py    micro-batching with power-of-two shape buckets so variable
                query traffic never retraces; coalescing request queue
  registry.py   multi-model registry: one process, many fitted models
  bench.py      assignments/sec measurement -> BENCH_serve.json

CLI: `python -m repro.launch.serve_cluster --smoke` round-trips
fit -> save -> load -> query and reports throughput.
"""
from repro.serve.artifact import (FittedModel, ModelSpec, fit_model,
                                  load_model, save_model)
from repro.serve.batcher import MicroBatcher, bucket_size
from repro.serve.bench import benchmark_assign, write_bench
from repro.serve.extend import assign, embed
from repro.serve.registry import DEFAULT_REGISTRY, ModelRegistry

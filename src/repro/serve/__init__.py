"""repro.serve: fit once, assign millions — out-of-sample inference.

The fitting side (repro.api.KernelKMeans over the pluggable approximation
backends — one-pass SRHT/Gaussian, Nystrom, exact) produces a compact
rank-r linearization of the kernel matrix; this package turns that fit —
WHICHEVER backend produced it — into a deployable service:

  artifact.py   FittedModel pytree + atomic save/load (ClusteringSpec
                sidecar, arrays via repro.distributed.checkpoint,
                optional bf16 storage); backend-specific extension state
                (sketch state, Nystrom landmarks) rides along
  extend.py     streaming Nystrom-style out-of-sample extension
                y(x) = Sigma^{-1/2} U^T kappa(ref, x) — ref being the
                training set or the Nystrom landmarks — and cluster
                assignment; Extender runs each stripe either through the
                fused gram->projection Pallas kernel
                (kernels/extend_embed, the off-CPU default — the
                (n, block) block never leaves VMEM) or the two-pass
                gram+projection executables, plus the jnp / fused Pallas
                kmeans_assign argmin; ShardedExtender shards the
                extension matmul over a mesh
  policy.py     ComputePolicy: the one frozen object carrying every
                compute-path knob (embed_fused / assign_fused /
                fit_fused / interpret / mesh / mesh_axis), accepted
                uniformly by the serving front doors AND the one-pass
                fit; absorbs resolve_pallas_path
  batcher.py    micro-batching with power-of-two shape buckets so variable
                query traffic never retraces; coalescing request queue
  scheduler.py  AsyncBatcher: futures per request, deadline-driven flush
                (max_wait_ms or full bucket), SLO-accounted; stop()
                retires it (post-stop submits raise, never strand)
  latency.py    streaming latency histogram: p50/p95/p99, SLO violations
  registry.py   multi-model registry + lifecycle: one process, many
                fitted models; warm hot-swap (swap() pre-warms the new
                row's executables, flips atomically, drains the old
                scheduler — SwapReport measures the flip)
  versions.py   versioned artifact store: <root>/v_<N>/ on the atomic
                checkpoint commit; publish / pinned loads / keep-last-K GC
  bench.py      sync/async/sharded/swap benchmarks -> BENCH_serve.json

CLI: `python -m repro.launch.serve_cluster --smoke` round-trips
fit -> save -> load -> query; `--bench async` reports latency percentiles.
Docs: docs/SERVING.md (serving semantics), docs/ARCHITECTURE.md (layers).
"""
from repro.serve.artifact import (ClusteringSpec, FittedModel, ModelSpec,
                                  fit_model, load_model, save_model)
from repro.serve.batcher import MicroBatcher, bucket_size
from repro.serve.bench import (benchmark_assign, benchmark_async,
                               benchmark_backends, benchmark_fit_scaling,
                               benchmark_fused, benchmark_swap,
                               format_bench, median_benches, run_benches,
                               write_bench)
from repro.serve.extend import (Extender, ShardedExtender, assign, embed,
                                embed_sharded)
from repro.serve.policy import (ComputePolicy, merge_legacy_kwargs,
                                resolve_pallas_path)
from repro.serve.latency import LatencyStats
from repro.serve.registry import (DEFAULT_REGISTRY, ModelRegistry,
                                  SwapReport)
from repro.serve.scheduler import AsyncBatcher
from repro.serve.versions import (VersionStore, gc_versions,
                                  latest_version, load_version,
                                  publish_version)

__all__ = [
    "ClusteringSpec", "FittedModel", "ModelSpec", "fit_model",
    "load_model", "save_model",
    "MicroBatcher", "bucket_size",
    "benchmark_assign", "benchmark_async", "benchmark_backends",
    "benchmark_fit_scaling", "benchmark_fused", "benchmark_swap",
    "format_bench", "median_benches", "run_benches", "write_bench",
    "Extender", "ShardedExtender", "assign", "embed", "embed_sharded",
    "ComputePolicy", "merge_legacy_kwargs", "resolve_pallas_path",
    "LatencyStats",
    "DEFAULT_REGISTRY", "ModelRegistry", "SwapReport",
    "AsyncBatcher",
    "VersionStore", "gc_versions", "latest_version", "load_version",
    "publish_version",
]

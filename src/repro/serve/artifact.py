"""FittedModel: the deployable artifact of a kernel-clustering fit.

A fit — whatever approximation backend produced it (see
`repro.api.backends`) — collapses to a small set of arrays that fully
determine the serving-time behaviour:

    X_train    (p, n)     training data
    U          (n_ref, r) orthonormal eigenvector basis of the
                          approximation's extension operator: rows index
                          the training points (one-pass / exact) or the
                          Nystrom landmarks
    eigvals    (r,)       matching eigenvalues (descending, >= 0)
    centroids  (k, r)     K-means centroids in the linearized space
    sketch_*              one-pass state: SRHT signs/rows or the dense
                          Gaussian Omega — not needed to serve, but
                          persisted so the fit is reproducible from the
                          artifact alone
    landmarks  (p, m)     Nystrom backend: the sampled reference points;
    landmark_idx (m,)     the extension evaluates kappa(landmarks, x)
                          against them (O(m * block) per stripe instead
                          of O(n * block)) — `extension_ref` picks the
                          right reference set per backend

plus a static `ClusteringSpec` (kernel name/params, dimensions, backend).
`ModelSpec` is a legacy alias for `ClusteringSpec` — the spec is now the
single frozen config shared by the estimator API (`repro.api.KernelKMeans`)
and the artifact.

On-disk artifact format (built on repro.distributed.checkpoint):

    <dir>/spec.json        ClusteringSpec (static metadata)
    <dir>/leaves.json      explicit leaf names of the array state, in
                           checkpoint leaf order (sorted dict keys), plus
                           the quantization map when saved with
                           dtype="bf16" ({"quantized": {leaf: "bf16"}})
    <dir>/step_0/          atomic checkpoint of the array state
        manifest.json      flat-dict paths, shapes, dtypes
        leaf_<i>.npy       one file per array

save/load reuse the checkpoint layer's atomic-rename commit, so a reader
never observes a half-written artifact, and `read_manifest` rebuilds the
restore skeleton without guessing shapes. `save_model(..., dtype="bf16")`
halves the float payload by storing bfloat16 bit patterns
(distributed/compression.py codec); load transparently restores float32.
Versioned deployments layer `serve/versions.py` on top of this format
(one artifact dir per v_<N>).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import warnings
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn, make_kernel
from repro.distributed import compression
from repro.distributed import checkpoint as ckpt


@dataclasses.dataclass(frozen=True)
class ClusteringSpec:
    """The single frozen config of a kernel-clustering fit.

    Drives `repro.api.KernelKMeans` and is persisted verbatim in the
    artifact (spec.json), so a fit is reproducible from its spec + key.
    `backend` names a registered approximation backend
    (repro.api.backends: onepass-srht | onepass-gaussian | nystrom |
    exact); `backend_params` carries its knobs (oversampling for
    one-pass, m for Nystrom). n/p are bound at fit time from the data.

    Subsumes the pre-estimator-API `ModelSpec` (which hard-coded the
    one-pass backend as oversampling/sketch_type fields); `from_json`
    still reads those legacy artifacts.
    """
    kernel: str = "polynomial"          # registry name (core/kernels_fn)
    kernel_params: Dict = dataclasses.field(default_factory=dict)
    k: int = 2                          # clusters
    r: int = 2                          # target rank (= serving embed dim)
    backend: str = "onepass-srht"       # approximation backend
    backend_params: Dict = dataclasses.field(default_factory=dict)
    block: int = 512                    # streaming stripe width
    n_restarts: int = 10                # K-means restarts
    max_iter: int = 20                  # K-means Lloyd iterations
    n: Optional[int] = None             # training points (bound at fit)
    p: Optional[int] = None             # input dimension (bound at fit)

    # -- legacy views (pre-backend ModelSpec fields) ---------------------

    @property
    def sketch_type(self) -> Optional[str]:
        """'srht' | 'gaussian' for one-pass backends, else None."""
        if self.backend.startswith("onepass-"):
            return self.backend.split("-", 1)[1]
        return None

    @property
    def oversampling(self) -> int:
        return int(self.backend_params.get("oversampling", 10))

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ClusteringSpec":
        d = json.loads(text)
        # Legacy ModelSpec schema: oversampling/sketch_type at top level,
        # no backend fields, no K-means params.
        if "backend" not in d:
            d["backend"] = f"onepass-{d.pop('sketch_type', 'srht')}"
            d["backend_params"] = {"oversampling": d.pop("oversampling", 10)}
        d.pop("sketch_type", None)
        return cls(**d)


# Legacy alias: every pre-estimator-API call site (and pickle/json of
# the old name) keeps working.
ModelSpec = ClusteringSpec


class FittedModel(NamedTuple):
    """Deployable fit artifact; see module docstring for the field model."""
    spec: ClusteringSpec
    X_train: jnp.ndarray               # (p, n)
    U: jnp.ndarray                     # (n_ref, r)
    eigvals: jnp.ndarray               # (r,)
    centroids: jnp.ndarray             # (k, r)
    sketch_signs: Optional[jnp.ndarray] = None   # (n_pad,)  srht only
    sketch_rows: Optional[jnp.ndarray] = None    # (r',)     srht only
    sketch_omega: Optional[jnp.ndarray] = None   # (n, r')   gaussian only
    landmarks: Optional[jnp.ndarray] = None      # (p, m)    nystrom only
    landmark_idx: Optional[jnp.ndarray] = None   # (m,)      nystrom only
    # Streaming accumulation state (repro.stream.accumulate): the applied
    # sketch slab, streamed row norms of K, and [n_applied, capacity] —
    # what partial_fit needs to resume from a published artifact. Columns
    # of X_train past n_applied are the staged (pending) tail.
    stream_w: Optional[jnp.ndarray] = None           # (capacity, r')
    stream_row_norms2: Optional[jnp.ndarray] = None  # (capacity,)
    stream_counts: Optional[jnp.ndarray] = None      # (2,) int32

    @property
    def extension_ref(self) -> jnp.ndarray:
        """Reference points the out-of-sample extension evaluates the
        kernel against: the Nystrom landmarks when present, else the
        full training set. Shape (p, n_ref)."""
        return self.landmarks if self.landmarks is not None else self.X_train

    @property
    def n_ref(self) -> int:
        """Columns of `extension_ref` — the per-stripe kernel height the
        serving path pays (m for Nystrom, n otherwise)."""
        return int(self.extension_ref.shape[1])

    @property
    def Y(self) -> jnp.ndarray:
        """Fitted linearization Sigma^{1/2} U^T in R^{r x n} (recomputed).

        Only defined when U spans the training points (one-pass / exact
        backends). A landmark-based (Nystrom) fit does not persist its
        training linearization — embed the training data through the
        extension instead (exact on training points by construction).
        """
        if self.landmarks is not None:
            raise AttributeError(
                f"backend {self.spec.backend!r} is landmark-based: U spans "
                f"the {self.n_ref} landmarks, not the training set — use "
                f"serve.extend.embed(model, model.X_train) for the "
                f"training linearization")
        return jnp.sqrt(self.eigvals)[:, None] * self.U.T

    def kernel_fn(self) -> KernelFn:
        return _cached_kernel(self.spec.kernel,
                              tuple(sorted(self.spec.kernel_params.items())))


# gram_stripe jit-caches on the kernel *callable's identity*, so serving must
# hand it the same callable every call — memoize construction per spec.
_KERNEL_CACHE: Dict[tuple, KernelFn] = {}


def _cached_kernel(name: str, params: tuple) -> KernelFn:
    key = (name, params)
    if key not in _KERNEL_CACHE:
        _KERNEL_CACHE[key] = make_kernel(name, **dict(params))
    return _KERNEL_CACHE[key]


def fit_model(key: jax.Array, X: jnp.ndarray, k: int, r: int,
              kernel: str = "polynomial",
              kernel_params: Optional[Dict] = None,
              oversampling: int = 10, block: int = 512,
              sketch_type: str = "srht",
              n_restarts: int = 10, max_iter: int = 20) -> FittedModel:
    """DEPRECATED shim — use `repro.api.KernelKMeans`.

    Delegates to the estimator front door with the matching one-pass
    backend; same key split and sub-calls as the historical function, so
    the returned FittedModel is bit-identical.
    """
    warnings.warn(
        "fit_model is deprecated; use repro.api.KernelKMeans(k=..., r=..., "
        "backend='onepass-srht', ...).fit(X, key).model_",
        DeprecationWarning, stacklevel=2)
    from repro.api import KernelKMeans   # lazy: api builds on serve
    est = KernelKMeans(k=k, r=r, kernel=kernel, kernel_params=kernel_params,
                       backend=f"onepass-{sketch_type}",
                       backend_params={"oversampling": oversampling},
                       block=block, n_restarts=n_restarts, max_iter=max_iter)
    return est.fit(X, key=key).model_


# ---------------------------------------------------------------------------
# save / load on top of repro.distributed.checkpoint
# ---------------------------------------------------------------------------

_OPTIONAL_LEAVES = ("sketch_signs", "sketch_rows", "sketch_omega",
                    "landmarks", "landmark_idx",
                    "stream_w", "stream_row_norms2", "stream_counts")


def _array_state(model: FittedModel) -> Dict[str, jnp.ndarray]:
    state = {"X_train": model.X_train, "U": model.U,
             "eigvals": model.eigvals, "centroids": model.centroids}
    for name in _OPTIONAL_LEAVES:
        val = getattr(model, name)
        if val is not None:
            state[name] = val
    return state


def save_model(model: FittedModel, artifact_dir: str,
               dtype: str = "f32") -> str:
    """Persist atomically; returns the artifact directory.

    dtype="bf16" stores every floating leaf as its bfloat16 bit pattern
    (half the bytes; ~3 decimal digits of mantissa — assignment-grade,
    see tests/test_serve.py) via the distributed/compression.py codec;
    dtype="int8" stores absmax-scaled int8 with one scale per leaf in
    leaves.json (a quarter of the bytes — what keeps the retrain loop's
    repeated VersionStore publishes cheap). Integer leaves and the spec
    are untouched and load_model transparently restores float32 arrays.
    """
    base = pathlib.Path(artifact_dir)
    base.mkdir(parents=True, exist_ok=True)
    state = _array_state(model)
    quantized: Dict[str, str] = {}
    if dtype not in ("f32", "float32"):
        state, quantized = compression.quantize_state(state, dtype)
    ckpt.save_checkpoint(str(base), step=0, state=state, blocking=True)
    # Explicit leaf names, in checkpoint leaf order (jax flattens a dict
    # in sorted-key order) — load_model must not have to reverse-engineer
    # names out of jax.tree_util.keystr formatting.
    (base / "leaves.json").write_text(
        json.dumps({"names": sorted(state), "quantized": quantized}))
    (base / "spec.json").write_text(model.spec.to_json())
    return str(base)


# Pre-leaves.json artifacts only carry keystr-formatted paths like
# "['X_train']"; match the quoted dict key rather than strip()ing
# characters off both ends (which also eats legitimate quote/bracket
# characters inside a name).
_KEYSTR_RE = re.compile(r"\['([^\]]+)'\]")


def _leaf_names(base: pathlib.Path, manifest: Dict) -> tuple:
    """(leaf names, quantized map) of the artifact's flat array dict.

    Names come from leaves.json when present (in leaf order); legacy
    artifacts (written before names were persisted) fall back to parsing
    the manifest's keystr paths. The quantized map records which leaves
    were stored as bf16 bit patterns (empty for f32 artifacts)."""
    names_file = base / "leaves.json"
    quantized: Dict[str, str] = {}
    if names_file.exists():
        meta = json.loads(names_file.read_text())
        names: List[str] = meta["names"]
        quantized = meta.get("quantized", {})
    else:
        names = []
        for path in manifest["paths"]:
            m = _KEYSTR_RE.fullmatch(path)
            names.append(m.group(1) if m else path)
    missing = {"X_train", "U", "eigvals", "centroids"} - set(names)
    if missing:
        raise ValueError(f"artifact at {base} lacks required leaves "
                         f"{sorted(missing)}; found {names}")
    return names, quantized


def load_model(artifact_dir: str) -> FittedModel:
    base = pathlib.Path(artifact_dir)
    spec = ClusteringSpec.from_json((base / "spec.json").read_text())
    manifest = ckpt.read_manifest(str(base), step=0)
    names, quantized = _leaf_names(base, manifest)
    state_like = {}
    for name, shape, dtype in zip(names, manifest["shapes"],
                                  manifest["dtypes"]):
        state_like[name] = jnp.zeros(shape, dtype=dtype)
    state, _ = ckpt.restore_checkpoint(str(base), state_like, step=0)
    if quantized:
        state = compression.dequantize_state(state, quantized)
    return FittedModel(spec=spec, X_train=state["X_train"], U=state["U"],
                       eigvals=state["eigvals"],
                       centroids=state["centroids"],
                       sketch_signs=state.get("sketch_signs"),
                       sketch_rows=state.get("sketch_rows"),
                       sketch_omega=state.get("sketch_omega"),
                       landmarks=state.get("landmarks"),
                       landmark_idx=state.get("landmark_idx"),
                       stream_w=state.get("stream_w"),
                       stream_row_norms2=state.get("stream_row_norms2"),
                       stream_counts=state.get("stream_counts"))

"""FittedModel: the deployable artifact of a one-pass kernel-clustering fit.

A fit (Alg. 1) collapses to a small set of arrays that fully determine the
serving-time behaviour:

    X_train    (p, n)     training data — the extension path evaluates
                          kappa(X_train, x_new) against it in stripes
    U          (n, r)     orthonormal eigenvector basis of K_hat = U S U^T
    eigvals    (r,)       eigenvalues S (descending, >= 0)
    centroids  (k, r)     K-means centroids in the linearized space
    sketch_*              the SRHT state (signs of D, sampled rows of R) or
                          the dense Gaussian Omega — not needed to serve,
                          but persisted so the fit is reproducible from the
                          artifact alone

plus a static `ModelSpec` (kernel name/params, dimensions, sketch type).

On-disk artifact format (built on repro.distributed.checkpoint):

    <dir>/spec.json        ModelSpec (static metadata)
    <dir>/leaves.json      explicit leaf names of the array state, in
                           checkpoint leaf order (sorted dict keys)
    <dir>/step_0/          atomic checkpoint of the array state
        manifest.json      flat-dict paths, shapes, dtypes
        leaf_<i>.npy       one file per array

save/load reuse the checkpoint layer's atomic-rename commit, so a reader
never observes a half-written artifact, and `read_manifest` rebuilds the
restore skeleton without guessing shapes. Versioned deployments layer
`serve/versions.py` on top of this format (one artifact dir per v_<N>).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn, make_kernel
from repro.core.kmeans import kmeans
from repro.core.sketch import SRHT, randomized_eig_with_state
from repro.distributed import checkpoint as ckpt


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static (non-array) metadata of a fitted model."""
    kernel: str                  # registry name: polynomial | rbf | linear
    kernel_params: Dict          # e.g. {"gamma": 0.0, "degree": 2}
    n: int                       # training points
    p: int                       # input dimension
    r: int                       # target rank (= serving embed dim)
    k: int                       # clusters
    oversampling: int            # l; r' = r + l
    block: int                   # streaming stripe width (memory budget)
    sketch_type: str             # srht | gaussian

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ModelSpec":
        return cls(**json.loads(text))


class FittedModel(NamedTuple):
    """Deployable fit artifact; see module docstring for the field model."""
    spec: ModelSpec
    X_train: jnp.ndarray               # (p, n)
    U: jnp.ndarray                     # (n, r)
    eigvals: jnp.ndarray               # (r,)
    centroids: jnp.ndarray             # (k, r)
    sketch_signs: Optional[jnp.ndarray] = None   # (n_pad,)  srht only
    sketch_rows: Optional[jnp.ndarray] = None    # (r',)     srht only
    sketch_omega: Optional[jnp.ndarray] = None   # (n, r')   gaussian only

    @property
    def Y(self) -> jnp.ndarray:
        """Fitted linearization Sigma^{1/2} U^T in R^{r x n} (recomputed)."""
        return jnp.sqrt(self.eigvals)[:, None] * self.U.T

    def kernel_fn(self) -> KernelFn:
        return _cached_kernel(self.spec.kernel,
                              tuple(sorted(self.spec.kernel_params.items())))


# gram_stripe jit-caches on the kernel *callable's identity*, so serving must
# hand it the same callable every call — memoize construction per spec.
_KERNEL_CACHE: Dict[tuple, KernelFn] = {}


def _cached_kernel(name: str, params: tuple) -> KernelFn:
    key = (name, params)
    if key not in _KERNEL_CACHE:
        _KERNEL_CACHE[key] = make_kernel(name, **dict(params))
    return _KERNEL_CACHE[key]


def fit_model(key: jax.Array, X: jnp.ndarray, k: int, r: int,
              kernel: str = "polynomial",
              kernel_params: Optional[Dict] = None,
              oversampling: int = 10, block: int = 512,
              sketch_type: str = "srht",
              n_restarts: int = 10, max_iter: int = 20) -> FittedModel:
    """Fit once: Alg. 1 (linearize + K-means) packaged as a FittedModel."""
    if kernel_params is None:
        kernel_params = ({"gamma": 0.0, "degree": 2}
                         if kernel == "polynomial" else {})
    spec = ModelSpec(kernel=kernel, kernel_params=dict(kernel_params),
                     n=int(X.shape[1]), p=int(X.shape[0]), r=r, k=k,
                     oversampling=oversampling, block=block,
                     sketch_type=sketch_type)
    kern = _cached_kernel(kernel, tuple(sorted(kernel_params.items())))
    k_sketch, k_km = jax.random.split(key)
    fit = randomized_eig_with_state(k_sketch, kern, X, r, oversampling,
                                    block, sketch_type)
    km = kmeans(k_km, fit.eig.Y.T, k, n_restarts=n_restarts,
                max_iter=max_iter)
    sketch = fit.sketch
    srht = isinstance(sketch, SRHT)
    return FittedModel(
        spec=spec, X_train=jnp.asarray(X, jnp.float32),
        U=fit.eig.U, eigvals=fit.eig.eigvals, centroids=km.centroids,
        sketch_signs=sketch.signs if srht else None,
        sketch_rows=sketch.rows if srht else None,
        sketch_omega=None if srht else sketch.omega)


# ---------------------------------------------------------------------------
# save / load on top of repro.distributed.checkpoint
# ---------------------------------------------------------------------------

def _array_state(model: FittedModel) -> Dict[str, jnp.ndarray]:
    state = {"X_train": model.X_train, "U": model.U,
             "eigvals": model.eigvals, "centroids": model.centroids}
    for name in ("sketch_signs", "sketch_rows", "sketch_omega"):
        val = getattr(model, name)
        if val is not None:
            state[name] = val
    return state


def save_model(model: FittedModel, artifact_dir: str) -> str:
    """Persist atomically; returns the artifact directory."""
    base = pathlib.Path(artifact_dir)
    base.mkdir(parents=True, exist_ok=True)
    state = _array_state(model)
    ckpt.save_checkpoint(str(base), step=0, state=state, blocking=True)
    # Explicit leaf names, in checkpoint leaf order (jax flattens a dict
    # in sorted-key order) — load_model must not have to reverse-engineer
    # names out of jax.tree_util.keystr formatting.
    (base / "leaves.json").write_text(
        json.dumps({"names": sorted(state)}))
    (base / "spec.json").write_text(model.spec.to_json())
    return str(base)


# Pre-leaves.json artifacts only carry keystr-formatted paths like
# "['X_train']"; match the quoted dict key rather than strip()ing
# characters off both ends (which also eats legitimate quote/bracket
# characters inside a name).
_KEYSTR_RE = re.compile(r"\['([^\]]+)'\]")


def _leaf_names(base: pathlib.Path, manifest: Dict) -> List[str]:
    """Leaf names of the artifact's flat array dict, in leaf order.

    Read from leaves.json when present; legacy artifacts (written before
    names were persisted) fall back to parsing the manifest's keystr
    paths."""
    names_file = base / "leaves.json"
    if names_file.exists():
        names = json.loads(names_file.read_text())["names"]
    else:
        names = []
        for path in manifest["paths"]:
            m = _KEYSTR_RE.fullmatch(path)
            names.append(m.group(1) if m else path)
    missing = {"X_train", "U", "eigvals", "centroids"} - set(names)
    if missing:
        raise ValueError(f"artifact at {base} lacks required leaves "
                         f"{sorted(missing)}; found {names}")
    return names


def load_model(artifact_dir: str) -> FittedModel:
    base = pathlib.Path(artifact_dir)
    spec = ModelSpec.from_json((base / "spec.json").read_text())
    manifest = ckpt.read_manifest(str(base), step=0)
    state_like = {}
    for name, shape, dtype in zip(_leaf_names(base, manifest),
                                  manifest["shapes"],
                                  manifest["dtypes"]):
        state_like[name] = jnp.zeros(shape, dtype=dtype)
    state, _ = ckpt.restore_checkpoint(str(base), state_like, step=0)
    return FittedModel(spec=spec, X_train=state["X_train"], U=state["U"],
                       eigvals=state["eigvals"],
                       centroids=state["centroids"],
                       sketch_signs=state.get("sketch_signs"),
                       sketch_rows=state.get("sketch_rows"),
                       sketch_omega=state.get("sketch_omega"))

"""Serving benchmarks: sync throughput, async latency percentiles, sharded.

Three modes, all landing in BENCH_serve.json:

  sync     `benchmark_assign` — bucketed assignments/sec per batch size
           through MicroBatcher (one warmup call per size pays compile);
  async    `benchmark_async` — request traffic through AsyncBatcher with
           deadline-driven flushing; reports the LatencyStats summary
           (p50/p95/p99, queue wait, SLO violations) plus throughput;
  sharded  either of the above with mesh= set — the extension matmul runs
           through serve.extend.ShardedExtender on the given mesh.

Schema (write_bench):

    {"model": {...spec...}, "backend": "cpu",
     "batch_sizes": [...],
     "results": [{"batch_size": b, "bucket": B, "calls": c, "wall_s": t,
                  "assignments_per_sec": qps}, ...],
     "bucket_executables": [...],
     "sharded": false | {"shards": s, "axis": "data"},
     "async": {"max_wait_ms": ..., "wall_s": ..., "queries_per_sec": ...,
               "latency": <LatencyStats.summary()>}}       # async mode only
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.artifact import FittedModel
from repro.serve.batcher import MicroBatcher, bucket_size
from repro.serve.scheduler import AsyncBatcher


def benchmark_assign(model: FittedModel,
                     batch_sizes: Sequence[int] = (64, 512),
                     repeats: int = 5,
                     key: Optional[jax.Array] = None,
                     block: Optional[int] = None,
                     fused: Optional[bool] = None,
                     max_bucket: int = 1024,
                     mesh=None, mesh_axis: str = "data") -> Dict:
    """Drive synthetic query load through a MicroBatcher; returns the dict
    documented in the module docstring. mesh != None measures the
    mesh-sharded extension path on the same bucketing policy."""
    key = key if key is not None else jax.random.PRNGKey(0)
    batcher = MicroBatcher(model, block=block, fused=fused,
                           max_bucket=max_bucket, mesh=mesh,
                           mesh_axis=mesh_axis)
    results = []
    for b in batch_sizes:
        Xq = jax.random.normal(key, (model.spec.p, b), jnp.float32)
        batcher.assign_batch(Xq)                    # warmup / compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            # assign_batch returns host numpy arrays, so the wall time
            # includes device sync — honest throughput.
            batcher.assign_batch(Xq)
        wall = time.perf_counter() - t0
        results.append({
            "batch_size": int(b),
            "bucket": bucket_size(b, batcher.min_bucket, batcher.max_bucket),
            "calls": int(repeats),
            "wall_s": wall,
            "assignments_per_sec": b * repeats / wall,
        })
    return {
        "model": dataclasses.asdict(model.spec),
        "backend": jax.default_backend(),
        "batch_sizes": [int(b) for b in batch_sizes],
        "results": results,
        "bucket_executables": batcher.executables,
        "sharded": ({"shards": batcher.extender.shards, "axis": mesh_axis}
                    if mesh is not None else False),
    }


def benchmark_async(model: FittedModel,
                    n_requests: int = 256,
                    width_range: Sequence[int] = (1, 64),
                    max_wait_ms: float = 2.0,
                    slo_ms: float = 250.0,
                    key: Optional[jax.Array] = None,
                    block: Optional[int] = None,
                    fused: Optional[bool] = None,
                    max_bucket: int = 1024,
                    mesh=None, mesh_axis: str = "data") -> Dict:
    """Request traffic through AsyncBatcher; returns latency percentiles.

    Submits n_requests of uniformly random widths in width_range, polling
    the deadline between submits (cooperative mode — the bench IS the
    event loop, so numbers are not polluted by pump-thread jitter), then
    flushes the tail. Every pow-2 bucket the traffic can hit is compiled
    during a warmup pass first: steady-state percentiles, not compile
    spikes, which on CPU would otherwise dominate p99 by ~3 orders of
    magnitude.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    rng = np.random.RandomState(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))
    lo, hi = int(width_range[0]), int(width_range[1])
    widths = rng.randint(lo, hi + 1, size=n_requests)
    queries = rng.randn(model.spec.p, int(widths.sum())).astype(np.float32)

    async_batcher = AsyncBatcher(model, max_wait_ms=max_wait_ms,
                                 slo_ms=slo_ms, block=block, fused=fused,
                                 max_bucket=max_bucket, mesh=mesh,
                                 mesh_axis=mesh_axis)
    # Warmup: compile every bucket in [min_bucket, max_bucket] once.
    bsz = async_batcher.batcher.min_bucket
    while bsz <= max_bucket:
        async_batcher.batcher.assign_batch(
            jnp.zeros((model.spec.p, bsz), jnp.float32))
        bsz *= 2
    async_batcher.batcher.reset_stats()

    futures = []
    off = 0
    t0 = time.perf_counter()
    for w in widths:
        futures.append(async_batcher.submit(queries[:, off:off + w]))
        off += w
        async_batcher.poll()
    async_batcher.flush()
    for fut in futures:
        fut.result()                              # all resolved by flush
    wall = time.perf_counter() - t0
    total_q = int(widths.sum())
    return {
        "mode": "async",
        "n_requests": int(n_requests),
        "width_range": [lo, hi],
        "max_wait_ms": float(max_wait_ms),
        "wall_s": wall,
        "queries_per_sec": total_q / wall,
        "latency": async_batcher.latency.summary(),
        "bucket_executables": async_batcher.batcher.executables,
        "sharded": ({"shards": async_batcher.batcher.extender.shards,
                     "axis": mesh_axis} if mesh is not None else False),
    }


def run_benches(model: FittedModel, modes: Sequence[str] = ("sync", "async"),
                batch_sizes: Sequence[int] = (64, 512), repeats: int = 5,
                key: Optional[jax.Array] = None,
                block: Optional[int] = None, fused: Optional[bool] = None,
                max_bucket: int = 1024,
                mesh=None, mesh_axis: str = "data",
                n_requests: int = 256, max_wait_ms: float = 2.0,
                slo_ms: float = 250.0) -> Dict:
    """Run the requested bench modes into ONE BENCH_serve.json dict.

    The shared driver behind benchmarks/bench_serve.py and the
    serve_cluster CLI: only the modes asked for run (and land in the
    dict), so `modes=("async",)` pays no synchronous warmup/timing.
    """
    bench: Dict = {
        "model": dataclasses.asdict(model.spec),
        "backend": jax.default_backend(),
        "sharded": ({"shards": dict(mesh.shape)[mesh_axis],
                     "axis": mesh_axis} if mesh is not None else False),
    }
    if "sync" in modes:
        bench.update(benchmark_assign(
            model, batch_sizes=batch_sizes, repeats=repeats, key=key,
            block=block, fused=fused, max_bucket=max_bucket, mesh=mesh,
            mesh_axis=mesh_axis))
    if "async" in modes:
        bench["async"] = benchmark_async(
            model, n_requests=n_requests, max_wait_ms=max_wait_ms,
            slo_ms=slo_ms, key=key, block=block, fused=fused,
            max_bucket=max_bucket, mesh=mesh, mesh_axis=mesh_axis)
    return bench


def format_bench(bench: Dict) -> str:
    """Human-readable lines for a run_benches dict (CLI output)."""
    lines = []
    for row in bench.get("results", []):
        lines.append(f"batch {row['batch_size']:>6d} "
                     f"(bucket {row['bucket']:>5d}): "
                     f"{row['assignments_per_sec']:>12.0f} assignments/sec")
    if "async" in bench:
        a = bench["async"]
        lat = a["latency"]["latency_ms"]
        lines.append(f"async: {a['queries_per_sec']:>12.0f} queries/sec  "
                     f"p50 {lat['p50']:.2f} ms  p95 {lat['p95']:.2f} ms  "
                     f"p99 {lat['p99']:.2f} ms  SLO violations "
                     f"{a['latency']['slo_violations']}")
    return "\n".join(lines)


def write_bench(path: str, bench: Dict) -> str:
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    return path

"""Serving benchmarks: sync/async/fused/swap/backends, 1-device or sharded.

Eight modes, all landing in BENCH_serve.json:

  sync     `benchmark_assign` — bucketed assignments/sec per batch size
           through MicroBatcher (one warmup call per size pays compile);
  async    `benchmark_async` — request traffic through AsyncBatcher with
           deadline-driven flushing; reports the LatencyStats summary
           (p50/p95/p99, queue wait, SLO violations) plus throughput;
  fused    `benchmark_fused` — the extension stripe through the fused
           gram->projection Pallas kernel vs the two-pass gram+projection
           executables, plus the per-stripe HBM-traffic delta (two-pass
           measured by launch/hlo_analysis, fused from the kernel's
           static memory contract);
  swap     `benchmark_swap` — async traffic with a warm hot-swap
           (registry.swap) in the middle: measured flip duration plus
           p95 before/after from the surviving LatencyStats, so swap
           downtime is a number, not a claim;
  backends `benchmark_backends` — the paper's comparison as a gated
           number: every registered approximation backend (onepass-srht /
           onepass-gaussian / nystrom / exact) fitted through the
           unified KernelKMeans front door on the same data; accuracy,
           streaming kernel-approx error, fit wall/memory, artifact
           bytes, and bucketed serving throughput per backend;
  stream   `benchmark_stream` — the streaming-fit path (repro.stream):
           partial_fit accumulation throughput (chunks/sec, cols/sec),
           the re-eig cadence cost, and the detection-to-swap latency of
           one full drift rollout (trigger -> refit -> publish -> warm
           swap) against a real VersionStore + ModelRegistry;
  fit_scaling `benchmark_fit_scaling` — the mesh-sharded one-pass fit
           (distributed/fit.ShardedFitEngine) vs the single-host
           accumulator on an n sweep: partial_fit cols/sec each, plus a
           per-block bytes-moved model (canonical executables measured
           by launch/hlo_analysis, fused fit_sketch from its static
           memory contract) with roofline flops/byte coverage;
  fleet    `repro.fleet.benchmark_fleet` — the multi-worker soak: q/s +
           merged p99 per worker count (pump threads running), an
           overload flood asserting shed-rate > 0 with admitted p99
           within the SLO, and a canary-then-promote rollout plus a
           probe-breached rollback (zero stranded futures asserted);
  sharded  sync/async with mesh= set — the extension matmul runs through
           serve.extend.ShardedExtender on the given mesh.

Schema (write_bench):

    {"model": {...spec...}, "backend": "cpu",
     "batch_sizes": [...],
     "results": [{"batch_size": b, "bucket": B, "calls": c, "wall_s": t,
                  "assignments_per_sec": qps}, ...],
     "bucket_executables": [...],
     "sharded": false | {"shards": s, "axis": "data"},
     "async": {"max_wait_ms": ..., "wall_s": ..., "queries_per_sec": ...,
               "latency": <LatencyStats.summary()>},       # async mode only
     "fused": {"fused": {...}, "two_pass": {...}, "speedup": ...,
               "hbm": {"two_pass_bytes": ..., "fused_bytes": ...,
                       "saved_bytes": ..., "saved_ratio": ...}},
     "swap": {"flip_ms": ..., "warm_s": ..., "drain_s": ...,
              "buckets_warmed": [...], "drained_requests": ...,
              "p95_before_ms": ..., "p95_after_ms": ...,
              "stranded_futures": 0},
     "backends": {"per_backend": {"onepass-srht": {"accuracy": ...,
                  "kernel_approx_error": ..., "fit_s": ...,
                  "fit_memory_bytes": ..., "artifact_bytes": ...,
                  "n_ref": ..., "assignments_per_sec": ...}, ...}},
     "stream": {"partial_fit_chunks_per_sec": ...,
                "partial_fit_cols_per_sec": ..., "reeig_s": ...,
                "rollout": {"detect_to_swap_s": ..., "refit_s": ...,
                            "publish_s": ..., "swap_s": ...,
                            "stranded_futures": 0, "retrains": 1}},
     "fit_scaling": {"shards": s, "rows": [{"n": ...,
                     "single_cols_per_sec": ..., "sharded_cols_per_sec":
                     ..., "bytes": {"two_pass_bytes": ..., "fused_bytes":
                     ..., "flops": ..., ...}}, ...]}}
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.artifact import FittedModel
from repro.serve.batcher import MicroBatcher, bucket_size
from repro.serve.extend import Extender
from repro.serve.registry import ModelRegistry
from repro.serve.scheduler import AsyncBatcher


def _min_call_time(fn, repeats: int, min_total_s: float = 0.25,
                   max_calls: int = 1000):
    """(best per-call seconds, calls made, total wall seconds).

    Throughput from the BEST of an auto-calibrated number of calls
    (timeit's estimator): serving calls here finish in ~ms, where a
    mean over a fixed handful of calls is dominated by scheduler/GC
    outliers and flaps the CI regression gate by ±30%. `repeats` is the
    floor; the count is raised until ~min_total_s of samples back the
    minimum. The caller must have warmed up / compiled `fn` already.
    """
    t0 = time.perf_counter()
    fn()
    est = time.perf_counter() - t0
    calls = max(int(repeats),
                min(max_calls, int(min_total_s / max(est, 1e-9)) + 1))
    times = [est]
    for _ in range(calls - 1):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times), calls, sum(times)


def benchmark_assign(model: FittedModel,
                     batch_sizes: Sequence[int] = (64, 512),
                     repeats: int = 5,
                     key: Optional[jax.Array] = None,
                     block: Optional[int] = None,
                     fused: Optional[bool] = None,
                     embed_fused: Optional[bool] = None,
                     interpret: Optional[bool] = None,
                     max_bucket: int = 1024,
                     mesh=None, mesh_axis: str = "data") -> Dict:
    """Drive synthetic query load through a MicroBatcher; returns the dict
    documented in the module docstring. mesh != None measures the
    mesh-sharded extension path on the same bucketing policy;
    embed_fused/interpret pick the extension stripe engine."""
    key = key if key is not None else jax.random.PRNGKey(0)
    batcher = MicroBatcher(model, block=block, fused=fused,
                           embed_fused=embed_fused, interpret=interpret,
                           max_bucket=max_bucket, mesh=mesh,
                           mesh_axis=mesh_axis)
    results = []
    for b in batch_sizes:
        Xq = jax.random.normal(key, (model.spec.p, b), jnp.float32)
        batcher.assign_batch(Xq)                    # warmup / compile
        # assign_batch returns host numpy arrays, so the timed calls
        # include device sync — honest throughput.
        best, calls, wall = _min_call_time(
            lambda: batcher.assign_batch(Xq), repeats)
        results.append({
            "batch_size": int(b),
            "bucket": bucket_size(b, batcher.min_bucket, batcher.max_bucket),
            "calls": int(calls),
            "wall_s": wall,
            "assignments_per_sec": b / best,
        })
    return {
        "model": dataclasses.asdict(model.spec),
        "backend": jax.default_backend(),
        "batch_sizes": [int(b) for b in batch_sizes],
        "results": results,
        "bucket_executables": batcher.executables,
        "sharded": ({"shards": batcher.extender.shards, "axis": mesh_axis}
                    if mesh is not None else False),
    }


def benchmark_async(model: FittedModel,
                    n_requests: int = 256,
                    width_range: Sequence[int] = (1, 64),
                    max_wait_ms: float = 2.0,
                    slo_ms: float = 250.0,
                    key: Optional[jax.Array] = None,
                    block: Optional[int] = None,
                    fused: Optional[bool] = None,
                    embed_fused: Optional[bool] = None,
                    interpret: Optional[bool] = None,
                    max_bucket: int = 1024,
                    mesh=None, mesh_axis: str = "data") -> Dict:
    """Request traffic through AsyncBatcher; returns latency percentiles.

    Submits n_requests of uniformly random widths in width_range, polling
    the deadline between submits (cooperative mode — the bench IS the
    event loop, so numbers are not polluted by pump-thread jitter), then
    flushes the tail. Every pow-2 bucket the traffic can hit is compiled
    during a warmup pass first: steady-state percentiles, not compile
    spikes, which on CPU would otherwise dominate p99 by ~3 orders of
    magnitude.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    rng = np.random.RandomState(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))
    lo, hi = int(width_range[0]), int(width_range[1])
    widths = rng.randint(lo, hi + 1, size=n_requests)
    queries = rng.randn(model.spec.p, int(widths.sum())).astype(np.float32)

    async_batcher = AsyncBatcher(model, max_wait_ms=max_wait_ms,
                                 slo_ms=slo_ms, block=block, fused=fused,
                                 embed_fused=embed_fused,
                                 interpret=interpret,
                                 max_bucket=max_bucket, mesh=mesh,
                                 mesh_axis=mesh_axis)
    # Warmup: compile every bucket in [min_bucket, max_bucket] once.
    bsz = async_batcher.batcher.min_bucket
    while bsz <= max_bucket:
        async_batcher.batcher.assign_batch(
            jnp.zeros((model.spec.p, bsz), jnp.float32))
        bsz *= 2
    async_batcher.batcher.reset_stats()

    futures = []
    off = 0
    t0 = time.perf_counter()
    for w in widths:
        futures.append(async_batcher.submit(queries[:, off:off + w]))
        off += w
        async_batcher.poll()
    async_batcher.flush()
    for fut in futures:
        fut.result()                              # all resolved by flush
    wall = time.perf_counter() - t0
    total_q = int(widths.sum())
    return {
        "mode": "async",
        "n_requests": int(n_requests),
        "width_range": [lo, hi],
        "max_wait_ms": float(max_wait_ms),
        "wall_s": wall,
        "queries_per_sec": total_q / wall,
        "latency": async_batcher.latency.summary(),
        "bucket_executables": async_batcher.batcher.executables,
        "sharded": ({"shards": async_batcher.batcher.extender.shards,
                     "axis": mesh_axis} if mesh is not None else False),
    }


def benchmark_swap(model: FittedModel,
                   new_model: Optional[FittedModel] = None,
                   n_requests: int = 128,
                   width_range: Sequence[int] = (1, 64),
                   max_wait_ms: float = 2.0,
                   slo_ms: float = 250.0,
                   key: Optional[jax.Array] = None,
                   block: Optional[int] = None,
                   fused: Optional[bool] = None,
                   embed_fused: Optional[bool] = None,
                   interpret: Optional[bool] = None,
                   max_bucket: int = 1024) -> Dict:
    """Async traffic with a warm hot-swap in the middle; measures the flip.

    Half the requests run against the original model, registry.swap()
    flips to `new_model` (default: a re-wrap of the same fit — the
    same-spec refresh case every real redeploy hits), the other half run
    against the swapped-in row. All timing comes from the surviving
    LatencyStats, so p95_before/p95_after are directly comparable — the
    after number includes the before samples (cumulative histogram): a
    swap that stalled traffic shows up as p95_after >> p95_before.
    Every future is checked resolved; `stranded_futures` must be 0.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    rng = np.random.RandomState(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))
    lo, hi = int(width_range[0]), int(width_range[1])
    widths = rng.randint(lo, hi + 1, size=n_requests)
    queries = rng.randn(model.spec.p, int(widths.sum())).astype(np.float32)

    reg = ModelRegistry()
    reg.register("swap-bench", model, version=1)
    sched = reg.scheduler("swap-bench", max_wait_ms=max_wait_ms,
                          slo_ms=slo_ms, block=block, fused=fused,
                          embed_fused=embed_fused, interpret=interpret,
                          max_bucket=max_bucket)
    # Warmup as in benchmark_async: compile every reachable bucket so the
    # percentiles measure steady-state serving (and the swap's warm phase
    # has a full bucket history to replay).
    bsz = sched.batcher.min_bucket
    while bsz <= max_bucket:
        sched.batcher.assign_batch(
            jnp.zeros((model.spec.p, bsz), jnp.float32))
        bsz *= 2

    half = n_requests // 2
    pend_n = min(4, half)
    futures = []
    off = 0

    def drive(target, lo_i, hi_i, flush=True):
        nonlocal off
        for w in widths[lo_i:hi_i]:
            futures.append(target.submit(queries[:, off:off + w]))
            off += w
            if flush:
                target.poll()
        if flush:
            target.flush()

    t0 = time.perf_counter()
    drive(sched, 0, half - pend_n)
    # The last pre-swap requests stay PENDING at flip time: the swap's
    # drain — not a client flush — must resolve them through the old
    # model, so drained_requests measures the real pending-at-flip path.
    drive(sched, half - pend_n, half, flush=False)
    report = reg.swap("swap-bench",
                      new_model if new_model is not None
                      else model._replace(), version=2)
    sched2 = reg.scheduler("swap-bench")
    drive(sched2, half, n_requests)
    wall = time.perf_counter() - t0
    report.p95_after_ms = sched2.latency.total.percentile(95.0)
    stranded = sum(not f.done() for f in futures)
    out = {"mode": "swap", "n_requests": int(n_requests),
           "width_range": [lo, hi], "max_wait_ms": float(max_wait_ms),
           "wall_s": wall, "stranded_futures": int(stranded)}
    out.update({k: v for k, v in report.to_dict().items()
                if k not in ("name", "old_version", "new_version")})
    return out


def _stripe_hbm_traffic(model: FittedModel, width: int) -> Dict:
    """Per-stripe HBM traffic: two-pass measured vs fused kernel contract.

    Two-pass is the sum of `launch.hlo_analysis.analyze` over the two real
    executables (gram stripe, projection matmul) — the (n, width) stripe
    is written by the first and re-read by the second. The fused Pallas
    kernel is a custom call, opaque to HLO analysis, but its memory
    contract is static and exact: each operand tile crosses HBM once and
    the (r, width) output is written once (the accumulator is revisited in
    VMEM), so its bytes are computed from the padded operand shapes.
    """
    from repro.launch.hlo_analysis import analyze

    spec = model.spec
    # n here is the extension height: the landmark count for Nystrom
    # fits, the training count otherwise.
    p, n, r = spec.p, model.n_ref, spec.r
    kern = model.kernel_fn()
    f32 = jnp.float32
    gram_txt = jax.jit(lambda X, xb: kern(X, xb)).lower(
        jax.ShapeDtypeStruct((p, n), f32),
        jax.ShapeDtypeStruct((p, width), f32)).compile().as_text()
    proj_txt = jax.jit(lambda pr, s: pr @ s).lower(
        jax.ShapeDtypeStruct((r, n), f32),
        jax.ShapeDtypeStruct((n, width), f32)).compile().as_text()
    two_pass = (analyze(gram_txt)["traffic_bytes"] +
                analyze(proj_txt)["traffic_bytes"])
    # Single source of truth: the kernel package's own declared model,
    # which repro.analysis cross-checks against the BlockSpecs (C001).
    from repro.kernels.extend_embed.ops import memory_contract
    fused = memory_contract(p, n, r, width)["hbm_bytes"]
    return {
        "two_pass_bytes": float(two_pass),
        "two_pass_source": "launch.hlo_analysis over gram + projection "
                           "executables",
        "fused_bytes": float(fused),
        "fused_source": "extend_embed kernel memory contract (Pallas "
                        "custom call is opaque to HLO analysis)",
        "stripe_roundtrip_bytes": float(2 * 4 * n * width),
        "saved_bytes": float(two_pass - fused),
        "saved_ratio": float((two_pass - fused) / two_pass)
        if two_pass else 0.0,
    }


def benchmark_fused(model: FittedModel, width: int = 512, repeats: int = 5,
                    key: Optional[jax.Array] = None,
                    block: Optional[int] = None,
                    interpret: Optional[bool] = None) -> Dict:
    """Fused extend_embed stripe vs two-pass gram+projection, same load.

    Embeds a (p, width) query batch through both engines (warmup paid
    outside the timed loop; np.asarray forces device sync) and reports
    throughput each plus the per-stripe HBM delta. On CPU the fused
    engine runs the Pallas kernel in interpret mode — throughput there
    measures the interpreter, not the TPU lowering, but the parity and
    the HBM model are backend-independent.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    block_w = min(block or model.spec.block, width)
    cpu = jax.default_backend() == "cpu"
    interp = interpret if interpret is not None else (True if cpu else None)
    engines = {
        "fused": Extender(model, block_w, fused=True, interpret=interp),
        "two_pass": Extender(model, block_w, fused=False),
    }
    Xq = jax.random.normal(key, (model.spec.p, width), jnp.float32)
    out: Dict = {"mode": "fused", "width": int(width),
                 "block": int(block_w), "repeats": int(repeats),
                 "backend": jax.default_backend(),
                 "interpret": bool(engines["fused"]._interpret)}
    for name, ext in engines.items():
        np.asarray(ext.embed(Xq))                   # warmup / compile
        best, calls, wall = _min_call_time(
            lambda: np.asarray(ext.embed(Xq)), repeats)
        out[name] = {"wall_s": wall, "calls": int(calls),
                     "queries_per_sec": width / best}
    out["speedup"] = (out["fused"]["queries_per_sec"] /
                      out["two_pass"]["queries_per_sec"])
    out["hbm"] = _stripe_hbm_traffic(model, block_w)
    return out


def benchmark_backends(X, labels, k: int, r: int,
                       backends: Optional[Sequence[str]] = None,
                       kernel: str = "polynomial",
                       kernel_params: Optional[Dict] = None,
                       block: int = 512, batch_size: int = 256,
                       repeats: int = 3,
                       key: Optional[jax.Array] = None,
                       interpret: Optional[bool] = None,
                       max_n: int = 4000) -> Dict:
    """The paper's comparison as a bench section: fit every registered
    approximation backend through the unified `KernelKMeans` front door
    on the SAME data and report, per backend:

      accuracy            best-permutation clustering accuracy vs labels
      kernel_approx_error streaming ||K - Y^T Y||_F / ||K||_F
      fit_s               fit wall time (backend + K-means)
      fit_memory_bytes    the backend's dominant fit working set (the
                          paper's memory axis: O(r'n) one-pass vs O(mn)
                          Nystrom vs O(n^2) exact)
      artifact_bytes      persisted FittedModel array payload
      n_ref               serving extension height (m for Nystrom, n else)
      assignments_per_sec bucketed serving throughput at `batch_size`
                          through MicroBatcher (compile paid in warmup)

    This is the section that makes "a Nystrom-fitted model serves through
    the full stack" a gated number rather than a claim.

    Note on accuracy: K-means on a rank-r linearization can have several
    basins (on blob+ring at r=2 the best-objective split is not always
    the class split), so per-backend accuracy reflects the (key,
    n_restarts) basin — deterministic run to run, which is what the CI
    gate needs (it tracks per-backend drift, not the cross-backend
    ranking; a genuinely broken backend craters to ~1/k).

    Fits are cached per (data fingerprint, config) within the process —
    one sweep at a time: every per-backend number except the serve
    throughput is deterministic for a fixed key, so the K median passes
    of serve_cluster --smoke refit nothing (no K exact
    eigendecompositions) and only re-time the serving loop — the one
    pass-varying gated metric.

    The sweep includes the exact backend — a full (n, n) gram + dense
    eigh — so X is truncated to its first `max_n` columns (a uniform
    subsample for the pre-shuffled synthetic sets) before fitting; the
    dict records `subsampled_from` when that happened. A sync/async
    throughput bench at huge --n must not hide minutes of O(n^3)
    eigendecomposition behind it.
    """
    from repro.api import KernelKMeans, available_backends, fit_memory_bytes
    from repro.core.metrics import (clustering_accuracy,
                                    kernel_approx_error_streaming)

    key = key if key is not None else jax.random.PRNGKey(0)
    backends = list(backends) if backends else available_backends()
    full_n = int(X.shape[1])
    if full_n > max_n:
        X = X[:, :max_n]
        labels = np.asarray(labels)[:max_n]
    n = int(X.shape[1])
    per_backend: Dict[str, Dict] = {}
    data_print = (tuple(np.asarray(X).shape), float(jnp.sum(X)),
                  float(jnp.sum(jnp.square(X))))
    cfg = (data_print, n, int(k), int(r), kernel,
           tuple(sorted((kernel_params or {}).items())), int(block),
           _key_bits(key))
    if _BACKEND_FIT_CACHE.get("cfg") != cfg:
        _BACKEND_FIT_CACHE.clear()
        _BACKEND_FIT_CACHE["cfg"] = cfg
    for name in backends:
        cached = _BACKEND_FIT_CACHE.get((cfg, name))
        if cached is None:
            est = KernelKMeans(k=k, r=r, kernel=kernel,
                               kernel_params=kernel_params, backend=name,
                               block=block)
            t0 = time.perf_counter()
            est.fit(X, key=key)
            jax.block_until_ready(est.centroids_)
            fit_s = time.perf_counter() - t0
            model = est.model_
            err = kernel_approx_error_streaming(model.kernel_fn(), X,
                                                est.embedding_, block=block)
            acc = clustering_accuracy(labels, est.labels_, k)
            from repro.serve.artifact import _array_state
            artifact_bytes = sum(int(np.asarray(v).nbytes)
                                 for v in _array_state(model).values())
            cached = {
                "model": model,
                "row": {
                    "accuracy": float(acc),
                    "kernel_approx_error": float(err),
                    "fit_s": float(fit_s),
                    "fit_memory_bytes": int(
                        fit_memory_bytes(name, n, r, **est.backend_params)),
                    "artifact_bytes": artifact_bytes,
                    "n_ref": model.n_ref,
                },
            }
            _BACKEND_FIT_CACHE[(cfg, name)] = cached
        model = cached["model"]
        batcher = MicroBatcher(model, interpret=interpret)
        Xq = jax.random.normal(key, (model.spec.p, batch_size), jnp.float32)
        batcher.assign_batch(Xq)                     # warmup / compile
        best, calls, wall = _min_call_time(
            lambda: batcher.assign_batch(Xq), repeats)
        per_backend[name] = dict(cached["row"],
                                 assignments_per_sec=batch_size / best,
                                 calls=int(calls), wall_s=wall)
    out = {"mode": "backends", "n": n, "k": int(k), "r": int(r),
           "batch_size": int(batch_size), "per_backend": per_backend}
    if full_n > n:
        out["subsampled_from"] = full_n
    return out


# benchmark_backends fit cache; see its docstring. Keyed by a cheap data
# fingerprint (shape + first two moments) plus the full fit config and
# key bits — everything the deterministic fit depends on. Bounded to ONE
# sweep: a new (data, config) evicts the previous sweep's fitted models,
# so a long-lived process sweeping many datasets never accumulates them.
_BACKEND_FIT_CACHE: Dict = {}


def _key_bits(key) -> tuple:
    """Hashable bit content of a PRNG key, raw uint32 or typed."""
    try:
        arr = jax.random.key_data(key)      # typed keys
    except (TypeError, ValueError, AttributeError):
        arr = key                           # raw uint32 keys
    return tuple(np.asarray(arr).ravel().tolist())


def benchmark_stream(model: FittedModel, n_chunks: int = 8,
                     chunk_cols: int = 128, repeats: int = 3,
                     key: Optional[jax.Array] = None,
                     block: Optional[int] = None,
                     max_wait_ms: float = 2.0) -> Dict:
    """The streaming-fit path (repro.stream) as bench numbers.

    Three read-outs:

      partial_fit_*_per_sec  accumulation throughput: chunks folded with
                             reeig=False (the steady-state ingest path) —
                             best pass of `repeats`, each on a fresh
                             accumulator so every pass pays the same
                             per-block kernel-stripe work;
      reeig_s                re-eig cadence cost at full capacity
                             (one_pass_core + full K-means re-cluster),
                             best of `repeats` after a warmup call;
      rollout                detection-to-swap latency of one REAL drift
                             rollout — drifted async traffic observed by
                             a DriftMonitor, RetrainWorker.step() doing
                             refit -> VersionStore.publish -> warm
                             registry.swap — with the zero-stranded-
                             futures invariant re-checked. Wall numbers
                             here include a full refit, so the gate
                             treats detect_to_swap_s as info-only.

    The accumulation/re-eig section streams random data through the
    passed model's spec (coerced to a one-pass backend — streaming needs
    sketch state); the rollout is a self-contained 1-d drift demo, so the
    numbers are comparable across --backend choices.
    """
    import tempfile

    from repro.api import KernelKMeans
    from repro.serve.versions import VersionStore
    from repro.stream import DriftMonitor, RetrainWorker

    key = key if key is not None else jax.random.PRNGKey(0)
    spec = model.spec
    backend = (spec.backend if spec.backend.startswith("onepass-")
               else "onepass-srht")
    blk = min(block or spec.block, chunk_cols)
    capacity = int(n_chunks) * int(chunk_cols)
    X = jax.random.normal(key, (spec.p, capacity), jnp.float32)

    def one_pass():
        est = KernelKMeans(k=spec.k, r=spec.r, kernel=spec.kernel,
                           kernel_params=spec.kernel_params,
                           backend=backend, block=blk)
        est.partial_fit(X[:, :chunk_cols], key=key, capacity=capacity,
                        reeig=False)               # warmup chunk
        t0 = time.perf_counter()
        for i in range(1, n_chunks):
            est.partial_fit(X[:, i * chunk_cols:(i + 1) * chunk_cols],
                            reeig=False)
        jax.block_until_ready(est._acc.W)
        return time.perf_counter() - t0, est

    walls = []
    for _ in range(max(int(repeats), 1)):
        wall, est = one_pass()
        walls.append(wall)
    accum_best = min(walls)

    est.reeig_now()                                # compile / warmup
    reeig_times = []
    for _ in range(max(int(repeats), 1)):
        t0 = time.perf_counter()
        est.reeig_now()
        jax.block_until_ready(est.centroids_)
        reeig_times.append(time.perf_counter() - t0)

    # One full drift rollout against a real store + registry.
    rng = np.random.RandomState(0)

    def blobs(xs, n_per=80):
        cols = []
        for x0 in xs:
            c = np.zeros((2, n_per), np.float32)
            c[0] = x0 + 0.25 * rng.randn(n_per)
            c[1] = 0.25 * rng.randn(n_per)
            cols.append(c)
        return np.concatenate(cols, axis=1)

    X0, Xd = blobs((-2.0, 2.0)), blobs((3.0, 8.0))
    demo = KernelKMeans(k=2, r=2, kernel="linear",
                        backend="onepass-srht", block=64)
    demo.partial_fit(X0, key=key, capacity=X0.shape[1] + Xd.shape[1])
    with tempfile.TemporaryDirectory() as tmp:
        store = VersionStore(tmp, keep=2)
        reg = ModelRegistry()
        reg.register("stream-bench", demo.model_,
                     version=store.publish(demo.model_))
        sched = reg.scheduler("stream-bench", max_wait_ms=max_wait_ms)
        mon = DriftMonitor(demo.model_, ref_labels=demo.labels_,
                           min_queries=64)
        worker = RetrainWorker("stream-bench", reg, store, mon,
                               lambda rep: demo.partial_fit(Xd).model_)
        chunks = [Xd[:, i * 20:(i + 1) * 20] for i in range(8)]
        futures = [sched.submit(ch) for ch in chunks]
        sched.flush()
        for ch, fut in zip(chunks, futures):
            mon.observe(ch, fut.result()[0])
        pending = sched.submit(Xd[:, :8])          # drained by the swap
        rollout = worker.step()
        assert rollout is not None, "drift rollout did not fire"
        stranded = sum(not f.done() for f in futures + [pending])
        reg.unregister("stream-bench")             # retire the new pump

    return {
        "mode": "stream",
        "stream_backend": backend,
        "chunk_cols": int(chunk_cols),
        "n_chunks": int(n_chunks),
        "capacity": capacity,
        "block": int(blk),
        "partial_fit_chunks_per_sec": (n_chunks - 1) / accum_best,
        "partial_fit_cols_per_sec":
            (n_chunks - 1) * chunk_cols / accum_best,
        "reeig_s": min(reeig_times),
        "rollout": {
            "detect_to_swap_s": float(rollout.detect_to_swap_s),
            "refit_s": float(rollout.refit_s),
            "publish_s": float(rollout.publish_s),
            "swap_s": float(rollout.swap_s),
            "drift_chi2": float(rollout.drift.chi2),
            "drained_requests": int(rollout.swap.drained_requests),
            "stranded_futures": int(stranded),
            "retrains": int(worker.retrains),
        },
    }


def _fit_block_traffic(model: FittedModel, n: int, block: int) -> Dict:
    """Per-block HBM bytes of the one-pass fit update at capacity n.

    Canonical path measured over its three real executables (gram
    stripe, normalized FWHT of the zero-padded stripe, cross-term
    matmul) via `launch.hlo_analysis.analyze`; the fused fit_sketch
    Pallas kernel is a custom call opaque to HLO analysis, so its bytes
    come from the static memory contract (every padded operand and
    output crosses HBM once, the accumulator is revisited in VMEM).
    Flops are the analyzer's dot-op count (the FWHT's adds are not dots;
    the roofline ratio is therefore a floor for the canonical path).
    """
    from repro.core.sketch import fwht
    from repro.kernels.fit_sketch.ops import memory_contract
    from repro.launch.hlo_analysis import analyze

    spec = model.spec
    p, rp = spec.p, spec.r + spec.oversampling
    b = min(block, n)
    n_pad = 1 if n <= 1 else 1 << (n - 1).bit_length()
    kern = model.kernel_fn()
    f32 = jnp.float32
    texts = [
        jax.jit(lambda X, c: kern(X, c)).lower(
            jax.ShapeDtypeStruct((p, n), f32),
            jax.ShapeDtypeStruct((p, b), f32)).compile().as_text(),
        jax.jit(lambda M: fwht(M)).lower(
            jax.ShapeDtypeStruct((n_pad, b), f32)).compile().as_text(),
        jax.jit(lambda K, c: K @ c).lower(
            jax.ShapeDtypeStruct((n, b), f32),
            jax.ShapeDtypeStruct((b, rp), f32)).compile().as_text(),
    ]
    parts = [analyze(t) for t in texts]
    two_pass = sum(a["traffic_bytes"] for a in parts)
    flops = sum(a["flops"] for a in parts)
    # Single source of truth: the kernel package's own declared model,
    # which repro.analysis cross-checks against the BlockSpecs (C001).
    fused = memory_contract(p, n, b, rp)["hbm_bytes"]
    return {
        "two_pass_bytes": float(two_pass),
        "two_pass_source": "launch.hlo_analysis over gram + fwht + "
                           "cross executables",
        "fused_bytes": float(fused),
        "fused_source": "fit_sketch kernel memory contract (Pallas "
                        "custom call is opaque to HLO analysis)",
        "flops": float(flops),
        "flops_per_byte_two_pass": float(flops / two_pass)
        if two_pass else 0.0,
        "flops_per_byte_fused": float(flops / fused) if fused else 0.0,
        "saved_bytes": float(two_pass - fused),
    }


def benchmark_fit_scaling(model: FittedModel, ns: Sequence[int] = (128, 256,
                                                                   512),
                          repeats: int = 3,
                          key: Optional[jax.Array] = None,
                          block: Optional[int] = None,
                          policy=None) -> Dict:
    """Sharded one-pass fit vs single-host accumulator on an n sweep.

    For each n: stream n columns chunk-by-chunk through
    `KernelKMeans.partial_fit` with `reeig=False` (the steady-state
    ingest path) twice — once single-host, once with a mesh
    ComputePolicy over all local devices (distributed/fit engine) — and
    report cols/sec each (best pass of `repeats`, fresh estimator per
    pass; the warmup chunk pays compile outside the timed loop). On a
    1-process CPU run the mesh has one device, so "sharded" measures
    the engine's overhead over the canonical path at parity (the paths
    are bit-identical there); real scaling numbers come from
    multi-device runs (tests/fit_dist_checks.py, the CI 2-device
    smoke). Each row carries the `_fit_block_traffic` bytes-moved model,
    which is backend-independent.
    """
    from jax.sharding import Mesh

    from repro.api import KernelKMeans
    from repro.serve.policy import ComputePolicy

    key = key if key is not None else jax.random.PRNGKey(0)
    spec = model.spec
    backend = (spec.backend if spec.backend.startswith("onepass-")
               else "onepass-srht")
    chunk = min(block or spec.block, min(int(n) for n in ns))
    if policy is None:
        policy = ComputePolicy(
            mesh=Mesh(np.asarray(jax.devices()), ("data",)))

    def one_pass(n_chunks, capacity, X, pol):
        est = KernelKMeans(k=spec.k, r=spec.r, kernel=spec.kernel,
                           kernel_params=spec.kernel_params,
                           backend=backend, block=chunk, policy=pol)
        est.partial_fit(X[:, :chunk], key=key, capacity=capacity,
                        reeig=False)               # warmup chunk
        t0 = time.perf_counter()
        for i in range(1, n_chunks):
            est.partial_fit(X[:, i * chunk:(i + 1) * chunk], reeig=False)
        jax.block_until_ready(est._acc.W)
        return time.perf_counter() - t0

    rows = []
    seen = set()
    for n in ns:
        n_chunks = max(int(n) // chunk, 2)
        capacity = n_chunks * chunk
        if capacity in seen:    # small n collapse onto the same capacity
            continue            # when chunk > n/2; one row per capacity
        seen.add(capacity)
        X = jax.random.normal(key, (spec.p, capacity), jnp.float32)
        single = min(one_pass(n_chunks, capacity, X, None)
                     for _ in range(max(int(repeats), 1)))
        sharded = min(one_pass(n_chunks, capacity, X, policy)
                      for _ in range(max(int(repeats), 1)))
        cols = (n_chunks - 1) * chunk
        rows.append({
            "n": int(capacity), "chunk_cols": int(chunk),
            "single_cols_per_sec": cols / single,
            "sharded_cols_per_sec": cols / sharded,
            "sharded_over_single": single / sharded,
            "bytes": _fit_block_traffic(model, capacity, chunk),
        })
    return {"mode": "fit_scaling", "fit_backend": backend,
            "shards": int(policy.shards), "chunk_cols": int(chunk),
            "repeats": int(repeats), "rows": rows}


def machine_calibration() -> Dict:
    """Machine-speed probe: best-call time of a fixed jitted matmul.

    Stored in every BENCH_serve.json so the CI regression gate can
    normalize wall-clock metrics by relative machine speed before
    diffing — the committed baseline and the CI runner are different
    (and burstable-CPU) machines, so raw absolute numbers drift with
    hardware state even when the serving code is unchanged.
    """
    x = jnp.ones((512, 512), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    np.asarray(f(x))                                # compile
    best, _, _ = _min_call_time(lambda: np.asarray(f(x)), 10,
                                min_total_s=0.2)
    return {"matmul512_ms": best * 1e3}


def run_benches(model: FittedModel, modes: Sequence[str] = ("sync", "async"),
                batch_sizes: Sequence[int] = (64, 512), repeats: int = 5,
                key: Optional[jax.Array] = None,
                block: Optional[int] = None, fused: Optional[bool] = None,
                embed_fused: Optional[bool] = None,
                interpret: Optional[bool] = None,
                max_bucket: int = 1024,
                mesh=None, mesh_axis: str = "data",
                n_requests: int = 256, max_wait_ms: float = 2.0,
                slo_ms: float = 250.0,
                data: Optional[Tuple] = None) -> Dict:
    """Run the requested bench modes into ONE BENCH_serve.json dict.

    The shared driver behind benchmarks/bench_serve.py and the
    serve_cluster CLI: only the modes asked for run (and land in the
    dict), so `modes=("async",)` pays no synchronous warmup/timing.

    `data=(X, labels)` enables the "backends" mode — the per-backend
    accuracy/memory/throughput sweep needs the raw training data and
    ground truth, not just a fitted model; without it the mode is skipped
    with a note in the dict.
    """
    bench: Dict = {
        "model": dataclasses.asdict(model.spec),
        "backend": jax.default_backend(),
        "calibration": machine_calibration(),
        "sharded": ({"shards": dict(mesh.shape)[mesh_axis],
                     "axis": mesh_axis} if mesh is not None else False),
    }
    if "sync" in modes:
        bench.update(benchmark_assign(
            model, batch_sizes=batch_sizes, repeats=repeats, key=key,
            block=block, fused=fused, embed_fused=embed_fused,
            interpret=interpret, max_bucket=max_bucket, mesh=mesh,
            mesh_axis=mesh_axis))
    if "async" in modes:
        bench["async"] = benchmark_async(
            model, n_requests=n_requests, max_wait_ms=max_wait_ms,
            slo_ms=slo_ms, key=key, block=block, fused=fused,
            embed_fused=embed_fused, interpret=interpret,
            max_bucket=max_bucket, mesh=mesh, mesh_axis=mesh_axis)
    if "fused" in modes:
        # The fused-vs-two-pass stripe section is single-device by
        # construction (the sharded engines are compared in dist_checks).
        bench["fused"] = benchmark_fused(
            model, repeats=repeats, key=key, block=block,
            interpret=interpret)
    if "swap" in modes:
        # Single-device: the swap path itself is mesh-agnostic (the new
        # row is rebuilt with the old row's kwargs, mesh included), and
        # the flip/drain numbers are what this section is for.
        bench["swap"] = benchmark_swap(
            model, n_requests=max(n_requests // 2, 32),
            max_wait_ms=max_wait_ms, slo_ms=slo_ms, key=key, block=block,
            fused=fused, embed_fused=embed_fused, interpret=interpret,
            max_bucket=max_bucket)
    if "stream" in modes:
        # Single-device by construction: the streaming accumulate/re-eig
        # path and the drift rollout are fit-side, not extension-side.
        bench["stream"] = benchmark_stream(
            model, repeats=repeats, key=key, block=block,
            max_wait_ms=max_wait_ms)
    if "fit_scaling" in modes:
        # The mesh here is every LOCAL device; multi-host meshes go
        # through the library API (pass policy= to benchmark_fit_scaling
        # directly) rather than the CLI driver.
        bench["fit_scaling"] = benchmark_fit_scaling(
            model, repeats=repeats, key=key, block=block)
    if "fleet" in modes:
        # Imported here, not at module top: repro.fleet composes the
        # serve layer, so a top-level import would be circular via
        # repro.serve.__init__.
        from repro.fleet import benchmark_fleet
        bench["fleet"] = benchmark_fleet(
            model, max_wait_ms=max_wait_ms, slo_ms=slo_ms, key=key,
            block=block, fused=fused, embed_fused=embed_fused,
            interpret=interpret)
    if "backends" in modes:
        if data is None:
            bench["backends"] = {"skipped": "no (X, labels) data passed"}
        else:
            X, labels = data
            spec = model.spec
            bench["backends"] = benchmark_backends(
                X, labels, k=spec.k, r=spec.r, kernel=spec.kernel,
                kernel_params=spec.kernel_params,
                block=block or spec.block, repeats=repeats, key=key,
                interpret=interpret)
    return bench


def median_benches(benches: Sequence[Dict]) -> Dict:
    """Per-leaf median across K same-shape run_benches dicts.

    The CI regression gate diffs absolute wall-clock numbers; a single
    bench pass's async latency section moves ±50% with transient machine
    state even after min-of-N per-call timing, so serve_cluster --smoke
    runs the benches K times (warm jit caches after pass 1) and commits
    the element-wise median. Non-numeric leaves (and bools/strings) take
    the first pass's value.
    """
    import statistics

    def merge(vals):
        v0 = vals[0]
        if isinstance(v0, dict):
            # Timing-dependent sections (the async per-bucket breakdown)
            # can legitimately differ in keys across passes — a request
            # that coalesced into bucket 512 on pass 1 may land in 1024
            # on pass 2. Median over the passes that saw the key.
            return {k: merge([v[k] for v in vals
                              if isinstance(v, dict) and k in v])
                    for k in v0}
        if isinstance(v0, list):
            return [merge([v[i] for v in vals]) for i in range(len(v0))]
        if isinstance(v0, bool) or not isinstance(v0, (int, float)):
            return v0
        med = statistics.median(vals)
        # Even pass counts give float midpoints; round (not truncate)
        # integer leaves like calls / slo_violations.
        return round(med) if isinstance(v0, int) else float(med)

    benches = list(benches)
    return benches[0] if len(benches) == 1 else merge(benches)


def format_bench(bench: Dict) -> str:
    """Human-readable lines for a run_benches dict (CLI output)."""
    lines = []
    for row in bench.get("results", []):
        lines.append(f"batch {row['batch_size']:>6d} "
                     f"(bucket {row['bucket']:>5d}): "
                     f"{row['assignments_per_sec']:>12.0f} assignments/sec")
    if "async" in bench:
        a = bench["async"]
        lat = a["latency"]["latency_ms"]
        lines.append(f"async: {a['queries_per_sec']:>12.0f} queries/sec  "
                     f"p50 {lat['p50']:.2f} ms  p95 {lat['p95']:.2f} ms  "
                     f"p99 {lat['p99']:.2f} ms  SLO violations "
                     f"{a['latency']['slo_violations']}")
    if "swap" in bench:
        s = bench["swap"]
        after = (f"{s['p95_after_ms']:.2f}"
                 if s.get("p95_after_ms") is not None else "—")
        lines.append(
            f"swap: flip {s['flip_ms']:.3f} ms  warm {s['warm_s']:.3f} s "
            f"(buckets {s['buckets_warmed']})  p95 {s['p95_before_ms']:.2f}"
            f" -> {after} ms  stranded futures {s['stranded_futures']}")
    if "backends" in bench and "per_backend" in bench["backends"]:
        for name, row in sorted(bench["backends"]["per_backend"].items()):
            lines.append(
                f"backend {name:>16s}: acc {row['accuracy']:.3f}  "
                f"err {row['kernel_approx_error']:.3f}  "
                f"fit {row['fit_s']:6.2f} s / "
                f"{row['fit_memory_bytes'] / 1e6:8.2f} MB  "
                f"serve {row['assignments_per_sec']:>10.0f} q/s "
                f"(n_ref {row['n_ref']})")
    if "stream" in bench:
        st = bench["stream"]
        ro = st["rollout"]
        lines.append(
            f"stream: partial_fit {st['partial_fit_cols_per_sec']:>10.0f} "
            f"cols/sec ({st['partial_fit_chunks_per_sec']:.1f} chunks/sec "
            f"@ {st['chunk_cols']} cols)  re-eig {st['reeig_s'] * 1e3:.1f}"
            f" ms @ n={st['capacity']}")
        lines.append(
            f"  drift rollout: detect->swap {ro['detect_to_swap_s']:.3f} s"
            f" (refit {ro['refit_s']:.3f} s, publish {ro['publish_s']:.3f}"
            f" s, swap {ro['swap_s']:.3f} s)  stranded futures "
            f"{ro['stranded_futures']}")
    if "fleet" in bench:
        fl = bench["fleet"]
        for row in fl["sweep"]:
            lines.append(
                f"fleet {row['workers']} worker"
                f"{'s' if row['workers'] != 1 else ''}: "
                f"{row['queries_per_sec']:>10.0f} q/s  "
                f"p50 {row['p50_ms']:.2f} ms  p95 {row['p95_ms']:.2f} ms  "
                f"p99 {row['p99_ms']:.2f} ms")
        ov = fl["overload"]
        lines.append(
            f"  overload (depth {ov['max_queue_depth']}): shed "
            f"{ov['shed']}/{ov['offered']} ({ov['shed_rate']:.0%})  "
            f"admitted p99 {ov['admitted_p99_ms']:.2f} ms "
            f"{'<=' if ov['within_slo'] else '>'} SLO {ov['slo_ms']:.0f} ms")
        ro = fl["rollout"]
        lines.append(
            f"  rollout: promote v{ro['promote']['version']} in "
            f"{ro['promote']['wall_s']:.3f} s (canary p95 "
            f"{ro['promote']['canary_p95_ms']:.2f} ms)  rollback "
            f"v{ro['rollback']['version']} -> {ro['rollback']['state']}  "
            f"stranded futures {ro['stranded_futures']}")
    if "fit_scaling" in bench:
        fs = bench["fit_scaling"]
        for row in fs["rows"]:
            by = row["bytes"]
            lines.append(
                f"fit n={row['n']:>6d} ({fs['shards']} shard"
                f"{'s' if fs['shards'] != 1 else ''}): single "
                f"{row['single_cols_per_sec']:>9.0f} cols/sec  sharded "
                f"{row['sharded_cols_per_sec']:>9.0f} cols/sec  "
                f"block HBM {by['two_pass_bytes'] / 1e6:.2f} MB -> fused "
                f"{by['fused_bytes'] / 1e6:.2f} MB "
                f"({by['flops_per_byte_fused']:.1f} flops/B)")
    if "fused" in bench:
        f = bench["fused"]
        hbm = f["hbm"]
        interp = " (interpret)" if f["interpret"] else ""
        lines.append(
            f"fused stripe{interp}: "
            f"{f['fused']['queries_per_sec']:>10.0f} q/s  vs two-pass "
            f"{f['two_pass']['queries_per_sec']:>10.0f} q/s  "
            f"(speedup {f['speedup']:.2f}x)")
        lines.append(
            f"  stripe HBM: two-pass {hbm['two_pass_bytes'] / 1e6:.2f} MB"
            f" -> fused {hbm['fused_bytes'] / 1e6:.2f} MB  "
            f"(saves {hbm['saved_ratio']:.0%})")
    return "\n".join(lines)


def write_bench(path: str, bench: Dict) -> str:
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    return path

"""Serving throughput measurement: assignments/sec per query batch size.

One warmup call per batch size pays the compile; timed calls then measure
the steady-state bucketed path (the number the ROADMAP north star cares
about). Results serialize to BENCH_serve.json:

    {"model": {...spec...},
     "backend": "cpu",
     "batch_sizes": [...],
     "results": [{"batch_size": b, "bucket": B, "calls": c,
                  "wall_s": t, "assignments_per_sec": qps}, ...]}
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.serve.artifact import FittedModel
from repro.serve.batcher import MicroBatcher, bucket_size


def benchmark_assign(model: FittedModel,
                     batch_sizes: Sequence[int] = (64, 512),
                     repeats: int = 5,
                     key: Optional[jax.Array] = None,
                     block: Optional[int] = None,
                     fused: Optional[bool] = None,
                     max_bucket: int = 1024) -> Dict:
    """Drive synthetic query load through a MicroBatcher; returns the dict
    documented in the module docstring."""
    key = key if key is not None else jax.random.PRNGKey(0)
    batcher = MicroBatcher(model, block=block, fused=fused,
                           max_bucket=max_bucket)
    results = []
    for b in batch_sizes:
        Xq = jax.random.normal(key, (model.spec.p, b), jnp.float32)
        batcher.assign_batch(Xq)                    # warmup / compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            # assign_batch returns host numpy arrays, so the wall time
            # includes device sync — honest throughput.
            batcher.assign_batch(Xq)
        wall = time.perf_counter() - t0
        results.append({
            "batch_size": int(b),
            "bucket": bucket_size(b, batcher.min_bucket, batcher.max_bucket),
            "calls": int(repeats),
            "wall_s": wall,
            "assignments_per_sec": b * repeats / wall,
        })
    return {
        "model": dataclasses.asdict(model.spec),
        "backend": jax.default_backend(),
        "batch_sizes": [int(b) for b in batch_sizes],
        "results": results,
        "bucket_executables": batcher.executables,
    }


def write_bench(path: str, bench: Dict) -> str:
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    return path

"""Versioned artifact store: publish -> pinned reads -> retention GC.

The paper's one-pass method makes a fitted kernel-clustering model a
small, cheap-to-hold artifact, so a deployment keeps MANY of them: every
refit publishes a new immutable version and serving picks one (usually
the latest) to hot-swap in. This module is that store:

    <root>/v_1/            one full artifact dir per version
    <root>/v_2/               (spec.json + leaves.json + step_0/ — the
    <root>/v_3/                serve/artifact.py format, unchanged)
    <root>/v_4.<pid>.tmp/  a publish in flight (never read)

Commit protocol mirrors the checkpoint layer (distributed/checkpoint.py):
a publish writes the complete artifact into a writer-unique
`v_<N>.<pid>.tmp` and os.replace()s it to `v_<N>`, so a reader never
observes a half-written version — a version directory either does not
exist or is complete. Readers additionally require spec.json (written
last inside the tmp dir) before counting a directory as a version,
mirroring `latest_step`'s manifest.json guard. Concurrent publishers are
safe: the commit rename refuses to land on an existing (non-empty)
directory, so a publisher that lost the number-allocation race — or hit
leftover junk at its target — bumps to the next free number rather than
replacing a committed version.

Retention is keep-last-K, same policy as CheckpointManager._gc: `gc(keep)`
removes all but the K highest version numbers, plus .tmp dirs from
CRASHED publishes only (stale by more than _TMP_TTL_S; a live publish
takes seconds, so a concurrent writer's in-flight tmp is never swept).
Version numbers are monotonic and never reused within a store's life —
GC removes directories, not the counter, because `latest()` scans
surviving dirs and publish allocates past them.

Pins are the fleet-tier guard on top: a serving worker that loaded
`v_<N>` records `pin(N, owner)` — one file per owner under
`<root>/v_<N>.pins/` — and gc() NEVER removes a version any owner still
pins, however old, so a fleet worker lagging a canary rollout can't have
its serving artifact deleted out from under a rollback. `unpin` releases
the refcount; pin dirs of fully-unpinned, already-GC'ed versions are
swept by the next gc().

ModelRegistry (serve/registry.py) layers the serving side on top:
`registry.load_version(name, root)` for pinned/latest reads and
`registry.swap(name, store.load())` for the warm hot-swap.
"""
from __future__ import annotations

import os
import pathlib
import re
import shutil
import time
from typing import List, Optional

from repro.serve.artifact import FittedModel, load_model, save_model

_VERSION_RE = re.compile(r"^v_(\d+)$")
# A .tmp dir older than this is a crashed publish (a live one finishes in
# seconds); gc() only sweeps these, never a concurrent in-flight write.
_TMP_TTL_S = 3600.0


class VersionStore:
    """Keep-last-K store of immutable FittedModel versions under one root.

    keep=None (the default) disables automatic GC; a keep passed to the
    constructor applies to every publish, a keep passed to publish()
    overrides it for that call.
    """

    def __init__(self, root: str, keep: Optional[int] = None):
        self.root = pathlib.Path(root)
        self.keep = keep

    def versions(self) -> List[int]:
        """Committed version numbers, ascending ([] for an empty/new
        store). In-flight .tmp publishes and spec-less directories (a
        crashed pre-atomic-rename state that cannot exist under the
        commit protocol, but cheap to guard) are not versions."""
        if not self.root.exists():
            return []
        out = []
        for p in self.root.iterdir():
            m = _VERSION_RE.match(p.name)
            if m and p.is_dir() and (p / "spec.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        vs = self.versions()
        return vs[-1] if vs else None

    def path(self, version: Optional[int] = None) -> str:
        """Artifact directory of `version` (default: latest). Raises
        FileNotFoundError for a missing/GC'ed version — a pinned reader
        finds out loudly, not via a stale-shape restore error."""
        version = version if version is not None else self.latest()
        if version is None:
            raise FileNotFoundError(f"no versions under {self.root}")
        p = self.root / f"v_{version}"
        if not (p / "spec.json").exists():
            raise FileNotFoundError(
                f"no version {version} under {self.root} "
                f"(have {self.versions()}; GC'ed or never published)")
        return str(p)

    def publish(self, model: FittedModel, keep: Optional[int] = None) -> int:
        """Commit `model` as the next version; returns its number.

        Atomic: the artifact is fully written into a writer-unique
        v_<N>.<pid>.tmp and renamed into place, so a concurrent reader
        sees either the old latest or the complete new version, never a
        partial one. The rename fails on an existing non-empty target,
        so losing a number-allocation race against another publisher
        means taking the next number — never replacing a committed
        version another publisher already handed out.
        """
        self.root.mkdir(parents=True, exist_ok=True)
        vs = self.versions()
        version = vs[-1] + 1 if vs else 1
        tmp = self.root / f"v_{version}.{os.getpid()}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        save_model(model, str(tmp))
        while True:
            try:
                os.replace(tmp, self.root / f"v_{version}")
                break
            except OSError:
                version += 1                    # target taken: next number
        keep = keep if keep is not None else self.keep
        if keep is not None:
            self.gc(keep)
        return version

    def load(self, version: Optional[int] = None) -> FittedModel:
        """Load a pinned `version`, or the latest when None."""
        return load_model(self.path(version))

    # -- pin refcounts (fleet workers vs GC) -----------------------------

    def _pin_dir(self, version: int) -> pathlib.Path:
        # ".pins" does not match _VERSION_RE and does not end in ".tmp",
        # so pin dirs are invisible to versions() and the tmp sweep.
        return self.root / f"v_{int(version)}.pins"

    def pin(self, version: int, owner: str) -> int:
        """Record that `owner` (e.g. a fleet worker id) serves `version`.

        One file per owner — refcount by directory listing, so pins from
        separate worker processes compose without any shared lock.
        Idempotent per (version, owner). Raises FileNotFoundError for a
        version that does not exist (nothing to protect). Returns the
        version pinned (convenient for `pin(store.latest(), ...)`)."""
        version = int(version)
        self.path(version)                      # loud on missing version
        d = self._pin_dir(version)
        d.mkdir(parents=True, exist_ok=True)
        (d / str(owner)).touch()
        return version

    def unpin(self, version: int, owner: str) -> None:
        """Release `owner`'s pin on `version`; idempotent (a worker may
        unpin during teardown after GC already swept the pin dir)."""
        try:
            (self._pin_dir(int(version)) / str(owner)).unlink()
        except FileNotFoundError:
            pass

    def pins(self, version: int) -> List[str]:
        """Owners currently pinning `version` (sorted; [] when none)."""
        d = self._pin_dir(int(version))
        if not d.is_dir():
            return []
        return sorted(p.name for p in d.iterdir())

    def gc(self, keep: Optional[int] = None) -> List[int]:
        """Keep the last `keep` versions, remove the rest (and .tmp dirs
        from CRASHED publishes — stale by > _TMP_TTL_S; an in-flight
        concurrent publish is left alone); returns the versions removed.

        A version with live pins (see pin()) is NEVER removed, whatever
        its age: a fleet worker still serving v_2 must be able to roll
        back to it after the canary of v_5 breaches. Pin dirs of
        versions that are gone and fully unpinned are swept here too."""
        keep = keep if keep is not None else self.keep
        if keep is None or keep < 1:
            raise ValueError(f"gc needs keep >= 1, got {keep!r}")
        removed = []
        for v in self.versions()[:-keep]:
            if self.pins(v):                     # a worker still serves it
                continue
            shutil.rmtree(self.root / f"v_{v}", ignore_errors=True)
            removed.append(v)
        # Sweep pin dirs whose version is gone and whose refcount is zero
        # (a worker unpinning after GC leaves an empty dir behind).
        live = set(self.versions())
        if self.root.exists():
            for p in self.root.iterdir():
                m = re.match(r"^v_(\d+)\.pins$", p.name)
                if m and int(m.group(1)) not in live and not self.pins(
                        int(m.group(1))):
                    shutil.rmtree(p, ignore_errors=True)
        if self.root.exists():
            now = time.time()
            for p in self.root.iterdir():
                if p.is_dir() and p.name.endswith(".tmp"):
                    try:
                        stale = now - p.stat().st_mtime > _TMP_TTL_S
                    except OSError:              # swept concurrently
                        continue
                    if stale:
                        shutil.rmtree(p, ignore_errors=True)
        return removed


# -- module-level conveniences (one-shot callers, CLI) ----------------------

def publish_version(root: str, model: FittedModel,
                    keep: Optional[int] = None) -> int:
    """Publish `model` as the next version under `root`; see VersionStore."""
    return VersionStore(root).publish(model, keep=keep)


def latest_version(root: str) -> Optional[int]:
    return VersionStore(root).latest()


def load_version(root: str, version: Optional[int] = None) -> FittedModel:
    """Pinned (or latest, when version=None) read from the store."""
    return VersionStore(root).load(version)


def gc_versions(root: str, keep: int) -> List[int]:
    """Keep-last-`keep` retention sweep; returns the versions removed."""
    return VersionStore(root).gc(keep)

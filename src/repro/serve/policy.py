"""ComputePolicy: one frozen object for every compute-path knob.

Before this module, the Pallas/mesh knobs were scattered kwargs with
per-callsite naming drift: `Extender(fused=...)` meant the embed kernel,
`MicroBatcher(fused=...)` meant the assign kernel, `mesh=` appeared on
some front doors and not others, and the fit path had no knobs at all.
A `ComputePolicy` collapses all of them into one value-compared frozen
dataclass accepted uniformly by Extender, ShardedExtender, MicroBatcher,
AsyncBatcher, ModelRegistry (via the recorded front-end kwargs),
serve_cluster, and — new with the sharded fit — SketchAccumulator /
KernelKMeans.fit / KernelKMeans.partial_fit.

Fields (all tri-state: None = auto, True/False = explicit):

    embed_fused   extend_embed Pallas stripe engine (serving embed).
    assign_fused  kmeans_assign Pallas argmin (serving assign).
    fit_fused     fit_sketch Pallas accumulate kernel (training).
    interpret     Pallas interpret-mode override, applied to whichever
                  of the three kernels resolves on.
    mesh          jax Mesh; not None routes BOTH serving (ShardedExtender)
                  and the one-pass fit (distributed/fit.py) through the
                  mesh-sharded path.
    mesh_axis     mesh axis name the data dimension shards over.

`resolve_pallas_path` (formerly serve/extend.py) lives here now; the
policy's `resolve_*` methods are thin wrappers over it, so the explicit
CPU-override contract is unchanged. Old per-callsite kwargs keep working
through `merge_legacy_kwargs` shims that emit a DeprecationWarning and
build the equivalent policy — behavior is bit-identical because the shim
feeds the exact same resolved values down the exact same code paths.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Tuple

import jax


def resolve_pallas_path(fused: Optional[bool], interpret: Optional[bool],
                        what: str) -> Tuple[bool, bool]:
    """Resolve a (fused, interpret) request into a concrete path choice.

    Contract (the fix for the old silently-ignored CPU override):

      fused=None       Pallas off-CPU; on CPU only when interpret=True
                       explicitly opts in (how CI forces the Pallas path).
      fused=True, CPU  honoured — runs in interpret mode, warning unless
                       interpret=True was passed explicitly.
      fused=True, interpret=False, CPU   ValueError: Pallas cannot lower
                       natively on CPU; the settings conflict.
      fused=False, interpret set         ValueError: interpret only
                       applies to the Pallas path; the settings conflict.
    """
    cpu = jax.default_backend() == "cpu"
    if fused is False:
        if interpret is not None:
            raise ValueError(
                f"{what}: fused=False conflicts with interpret="
                f"{interpret} — the interpret flag only applies to the "
                f"Pallas path")
        return False, False
    if fused is None:
        fused = (not cpu) or interpret is True
        if not fused:
            return False, False
    if cpu:
        if interpret is False:
            raise ValueError(
                f"{what}: the Pallas path was requested with "
                f"interpret=False on the CPU backend, where Pallas "
                f"cannot lower natively — drop interpret=False or run "
                f"on an accelerator")
        if interpret is None:
            warnings.warn(
                f"{what}: Pallas path requested on the CPU backend; "
                f"running in interpret mode (pass interpret=True to "
                f"acknowledge, or fused=False for the jnp path)",
                stacklevel=3)
        return True, True
    return True, bool(interpret) if interpret is not None else False


@dataclasses.dataclass(frozen=True)
class ComputePolicy:
    """Frozen compute-path selection, shared by fit and serve.

    Frozen + eq=True on purpose: ModelRegistry records the front-end
    kwargs per model row and replays/compares them by value equality on
    warm swaps, so a policy must compare by value (jax Mesh already
    does). Construct once, pass everywhere.
    """

    embed_fused: Optional[bool] = None
    assign_fused: Optional[bool] = None
    fit_fused: Optional[bool] = None
    interpret: Optional[bool] = None
    mesh: Any = None
    mesh_axis: str = "data"

    def __post_init__(self):
        if self.mesh is not None and \
                self.mesh_axis not in self.mesh.axis_names:
            raise ValueError(f"mesh has no axis {self.mesh_axis!r}; "
                             f"have {self.mesh.axis_names}")

    # -- resolution (the old resolve_pallas_path call sites) -------------

    def resolve_embed(self, where: str = "fused extend_embed stripe"
                      ) -> Tuple[bool, bool]:
        return resolve_pallas_path(self.embed_fused, self.interpret, where)

    def resolve_assign(self, where: str = "Pallas kmeans_assign"
                       ) -> Tuple[bool, bool]:
        return resolve_pallas_path(self.assign_fused, self.interpret, where)

    def resolve_fit(self, where: str = "fused fit_sketch accumulate"
                    ) -> Tuple[bool, bool]:
        return resolve_pallas_path(self.fit_fused, self.interpret, where)

    def replace(self, **changes) -> "ComputePolicy":
        return dataclasses.replace(self, **changes)

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def shards(self) -> int:
        """Device count along the data axis (1 when unsharded)."""
        if self.mesh is None:
            return 1
        return dict(self.mesh.shape)[self.mesh_axis]


def merge_legacy_kwargs(policy: Optional[ComputePolicy],
                        legacy: Dict[str, Any], where: str) -> ComputePolicy:
    """Fold deprecated per-callsite kwargs into a ComputePolicy.

    `legacy` maps ComputePolicy FIELD names (callers translate their
    local spelling first, e.g. MicroBatcher's `fused` -> `assign_fused`)
    to the values the caller received. A kwarg counts as "set" when it
    differs from the policy default (None; "data" for mesh_axis) — the
    defaults carry no information, so folding them is lossless and old
    call sites that never passed the kwargs stay warning-free.

    Rules: legacy kwargs set AND policy given -> ValueError (ambiguous);
    legacy kwargs set, no policy -> DeprecationWarning + equivalent
    policy; nothing set -> the given policy, or the default one.
    """
    defaults = {"mesh_axis": "data"}
    set_keys = sorted(k for k, v in legacy.items()
                      if v is not None and v != defaults.get(k))
    if not set_keys:
        return policy if policy is not None else ComputePolicy()
    if policy is not None:
        raise ValueError(
            f"{where}: both policy= and legacy kwarg(s) {set_keys} were "
            f"given — move the legacy values into the ComputePolicy")
    warnings.warn(
        f"{where}: kwarg(s) {set_keys} are deprecated; pass "
        f"policy=ComputePolicy(...) instead (same fields, same defaults, "
        f"bit-identical behavior)", DeprecationWarning, stacklevel=3)
    return ComputePolicy(**legacy)

"""Micro-batching for the query path: pow-2 shape buckets, no retracing.

jit specializes on shapes, so serving raw variable-size batches would
compile a fresh executable per distinct batch size — unbounded compile
cache, latency cliffs on first-seen sizes. Policy here:

  - a batch of b queries is zero-padded up to bucket(b), the next power of
    two clamped to [min_bucket, max_bucket]; results for the padded columns
    are computed and discarded (columns are independent, so real queries
    are bit-identical to an unpadded run at the same padded width);
  - batches wider than max_bucket are chunked into full max_bucket pieces
    (the steady-state shape) plus one bucketed remainder;
  - at most log2(max_bucket / min_bucket) + 1 executables ever exist per
    model, all tracked in `stats` so tests can assert the no-retrace
    property.

`MicroBatcher` also provides a coalescing request queue: `submit()` enqueues
any number of independent requests, `drain()` runs them as ONE concatenated
bucketed batch and scatters labels back per request — the standard
GPU/TPU-serving micro-batch pattern, deterministic and thread-free so the
behaviour is exactly testable.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.sketch import next_pow2
from repro.serve import extend
from repro.serve.artifact import FittedModel
from repro.serve.policy import ComputePolicy, merge_legacy_kwargs


def bucket_size(b: int, min_bucket: int = 8, max_bucket: int = 1024) -> int:
    """Next power of two >= b, clamped to [min_bucket, max_bucket]."""
    if b < 1:
        raise ValueError(f"batch size must be positive, got {b}")
    return max(min_bucket, min(next_pow2(b), max_bucket))


class MicroBatcher:
    """Bucketed assignment front-end for one FittedModel.

    policy: ComputePolicy selecting the compute paths — assign_fused is
    the Pallas kmeans_assign argmin (None = off-CPU default), embed_fused
    the fused extend_embed stripe engine (same default), interpret the
    Pallas interpret-mode override for both (the knob CI uses to force
    the Pallas serving path on CPU; see serve/policy.py for the conflict
    rules), and mesh/mesh_axis the mesh-sharded extension. The old
    fused=/embed_fused=/interpret=/mesh= kwargs are the deprecated
    spelling of the same fields.
    """

    def __init__(self, model: FittedModel, block: Optional[int] = None,
                 min_bucket: int = 8, max_bucket: int = 1024,
                 fused: Optional[bool] = None,
                 embed_fused: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 mesh=None, mesh_axis: str = "data",
                 policy: Optional[ComputePolicy] = None):
        policy = merge_legacy_kwargs(
            policy, {"assign_fused": fused, "embed_fused": embed_fused,
                     "interpret": interpret, "mesh": mesh,
                     "mesh_axis": mesh_axis}, "MicroBatcher")
        self.model = model
        self.policy = policy
        self.block = block or model.spec.block
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.fused = policy.assign_fused
        # policy.mesh != None routes every bucketed assignment through the
        # mesh-sharded extension (same bucketing policy, sharded matmul);
        # otherwise one Extender owns the stripe engine + executables.
        self.sharded = policy.mesh is not None
        self.extender = (
            extend.ShardedExtender(model, block=self.block, policy=policy)
            if self.sharded else
            extend.Extender(model, self.block, policy=policy))
        self._pending: List[np.ndarray] = []
        self.stats: Dict = {}
        self.reset_stats()

    def reset_stats(self, preserve_buckets: bool = False) -> None:
        """Zero the traffic counters.

        preserve_buckets=False also drops bucket_hits — and with it the
        `executables` view that a warm hot-swap (registry.swap) replays
        into the incoming row. Periodic stats sampling (e.g. the drift
        monitor's sample_serving_stats) must pass preserve_buckets=True:
        the hit COUNTS reset to zero but every bucket key survives, so a
        sample between swaps can never cold-start the next swap. Neither
        form touches the jit cache itself."""
        hits = ({b: 0 for b in self.stats.get("bucket_hits", {})}
                if preserve_buckets else {})
        self.stats = {"queries": 0, "padded_queries": 0,
                      "batches": 0, "bucket_hits": hits}

    # -- bucketed one-shot path ------------------------------------------

    def assign_batch(self, Xq: jnp.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Bucketed assignment of Xq (p, b) -> (labels (b,), d2 (b,))."""
        b = Xq.shape[1]
        if b == 0:
            return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))
        labels, d2 = [], []
        for start in range(0, b, self.max_bucket):
            chunk = Xq[:, start:start + self.max_bucket]
            lab, dd = self._assign_bucketed(chunk)
            labels.append(lab)
            d2.append(dd)
        return np.concatenate(labels), np.concatenate(d2)

    def _assign_bucketed(self, chunk: jnp.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        w = chunk.shape[1]
        bsz = bucket_size(w, self.min_bucket, self.max_bucket)
        padded = (chunk if w == bsz
                  else jnp.pad(chunk, ((0, 0), (0, bsz - w))))
        if self.sharded:
            # Sharded path: stripe width is baked into the one compiled
            # sharded executable at ShardedExtender construction.
            lab, d2 = self.extender.assign(padded)
        else:
            # Narrow the gram stripe to the bucket: a bucket-8 request
            # must not pay an n x block (e.g. 512-wide) kernel stripe.
            # bsz is already pow-2-clamped, so stripe widths — and hence
            # compiled executables — stay bounded by the bucket count.
            lab, d2 = self.extender.assign(padded,
                                           block=min(self.block, bsz))
        self.stats["queries"] += w
        self.stats["padded_queries"] += bsz - w
        self.stats["batches"] += 1
        self.stats["bucket_hits"][bsz] = \
            self.stats["bucket_hits"].get(bsz, 0) + 1
        return np.asarray(lab[:w]), np.asarray(d2[:w])

    def warm(self, buckets) -> List[int]:
        """Compile the executables for the given bucket widths now.

        Runs one zero batch per distinct pow-2-clamped bucket through the
        real bucketed path, so the compile cost is paid here — off the
        serving path — and the buckets land in stats["bucket_hits"]
        exactly like traffic would put them there. This is how a warm
        hot-swap (registry.swap) replays the outgoing row's bucket
        history into the incoming row before the flip. Returns the
        bucket sizes warmed, ascending.
        """
        warmed = []
        for b in sorted({bucket_size(int(b), self.min_bucket,
                                     self.max_bucket) for b in buckets}):
            self.assign_batch(np.zeros((self.model.spec.p, b), np.float32))
            warmed.append(b)
        return warmed

    # -- coalescing request queue ----------------------------------------

    def validate_request(self, Xq) -> np.ndarray:
        """Shape-check one request; returns it as float32 numpy.

        Shared with AsyncBatcher (serve/scheduler.py) so both front doors
        reject malformed requests identically, at submit time."""
        Xq = np.asarray(Xq, np.float32)
        if Xq.ndim != 2 or Xq.shape[0] != self.model.spec.p \
                or Xq.shape[1] < 1:
            raise ValueError(f"request must be (p={self.model.spec.p}, "
                             f"b>=1), got {Xq.shape}")
        return Xq

    def submit(self, Xq: jnp.ndarray) -> int:
        """Enqueue one request of queries (p, b_i); returns its ticket."""
        self._pending.append(self.validate_request(Xq))
        return len(self._pending) - 1

    def drain(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Run all pending requests as one coalesced bucketed batch.

        Returns [(labels_i, d2_i)] aligned with submission order.
        """
        if not self._pending:
            return []
        widths = [x.shape[1] for x in self._pending]
        big = jnp.asarray(np.concatenate(self._pending, axis=1))
        self._pending = []
        labels, d2 = self.assign_batch(big)
        out, off = [], 0
        for w in widths:
            out.append((labels[off:off + w], d2[off:off + w]))
            off += w
        return out

    @property
    def executables(self) -> List[int]:
        """Bucket sizes compiled so far (sorted) — the retrace budget."""
        return sorted(self.stats["bucket_hits"])

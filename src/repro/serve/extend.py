"""Out-of-sample extension: embed and assign new points against a fit.

Every approximation backend (repro.api.backends) reduces to the same
extension operator: eigenpairs (U, Sigma) over a set of REFERENCE points
(`model.extension_ref` — the training set for one-pass/exact fits, the m
sampled landmarks for Nystrom fits), and a new point x embeds as

    y(x) = Sigma^{-1/2} U^T kappa(ref, x)              in R^r

For one-pass/exact this reproduces the fitted Y exactly on training
points whenever the kernel matrix is (numerically) rank <= r' — for a
training point x_j, kappa(X_train, x_j) = K e_j = U Sigma U^T e_j and the
formula collapses to Sigma^{1/2} U^T e_j = Y e_j. For Nystrom fits
(U, Sigma) are the landmark-gram eigenpairs and the identity is exact BY
CONSTRUCTION for every kernel (the fitted Y *is* this formula evaluated
on the training columns), and the per-stripe kernel cost drops from
n x block to m x block.

Memory model (`Extender`): the (n_ref, b) kernel block kappa(ref, X_query)
is never materialized beyond n_ref x min(b, block) — query columns stream
in stripes of the SAME `block` the training pass used, so serving never
exceeds the training-time memory budget no matter how many queries arrive
at once. Two stripe engines implement that contract:

  fused (the serving default off-CPU)  one Pallas executable per stripe:
      kernels/extend_embed builds each (row_tile, block) gram tile and
      contracts it against P = Sigma^{-1/2} U^T on-chip, so even the
      n x block stripe only ever exists as one VMEM tile — the (n, block)
      block never round-trips through HBM between gram and projection.
  two-pass (the CPU default)  one jitted gram_stripe executable plus one
      jitted projection executable per stripe, (n, block) materialized
      between them (kernels_fn.stripe_iterator, pad_tail=True).

Both engines run every stripe — ragged tails included — through one
jitted executable per bucket shape (queries are zero-padded to a column
multiple of the stripe width), so steady-state serving never retraces.

Pallas path selection is EXPLICIT: `fused=None` picks the Pallas engine
off-CPU; `fused=True` on CPU runs it in interpret mode (with a warning
unless `interpret=True` was passed, which is how CI forces the Pallas
path on CPU); `fused=True, interpret=False` on CPU and `fused=False,
interpret=<anything>` are conflicting settings and raise. The same rules
govern the Pallas kmeans_assign assignment path (`assign_fused=`).

Mesh-sharded path (`ShardedExtender`): the extension matmul
P kappa(X_train, x) is the serving-time hot loop, and it shards the same
way the training pass does (distributed/cluster.py): X_train and P both
column-sharded over the mesh's data axis, each device computing its
n/shards x block stripe of the kernel against the replicated query block
fused into its (r, block) partial projection, combined by ONE psum of the
tiny (r, block) partials. Per-device kernel memory drops from n x block
to n/shards x block and embedding throughput scales with device count;
see docs/SERVING.md.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.kernels_fn import stripe_iterator
from repro.core.kmeans import _sq_dists
from repro.kernels.extend_embed.ops import extend_embed_pallas
from repro.kernels.kmeans_assign.ops import assign_pallas
from repro.serve.artifact import FittedModel
from repro.serve.policy import (ComputePolicy, merge_legacy_kwargs,
                                resolve_pallas_path)

__all__ = ["Extender", "ShardedExtender", "embed", "assign",
           "embed_sharded", "resolve_pallas_path"]

# Keep in sync with core/nystrom._ABS_EIG_FLOOR: the Nystrom fit floors
# its truncation threshold here so fit and serve agree on which
# directions are rank-deficient.
_EIG_EPS = 1e-7

# kernel_fn() falls back to these when the spec omits a param (see
# kernels_fn registry defaults); the Pallas static args must agree.
_STATIC_DEFAULTS = {"polynomial": {"gamma": 0.0, "degree": 2},
                    "rbf": {"gamma": 1.0}, "linear": {}}


def _kernel_statics(spec) -> Tuple[str, float, int]:
    kp = dict(_STATIC_DEFAULTS.get(spec.kernel, {}))
    kp.update(spec.kernel_params)
    return spec.kernel, float(kp.get("gamma", 0.0)), int(kp.get("degree", 2))


# resolve_pallas_path moved to serve/policy.py (absorbed into
# ComputePolicy); re-exported above so existing imports keep working.


@jax.jit
def _project_stripe(proj: jnp.ndarray, stripe: jnp.ndarray) -> jnp.ndarray:
    """P = Sigma^{-1/2} U^T applied to one (n, block) stripe -> (r, block).

    The second executable of the two-pass engine — the (n, block) stripe
    is an HBM round-trip between gram and this matmul (the fused engine
    exists to delete exactly that traffic).
    """
    return proj @ stripe


@functools.partial(jax.jit, static_argnames=("kind", "gamma", "degree",
                                             "block", "interpret"))
def _fused_stripe(X: jnp.ndarray, proj: jnp.ndarray, Xqp: jnp.ndarray,
                  start: jnp.ndarray, *, kind: str, gamma: float,
                  degree: int, block: int, interpret: bool) -> jnp.ndarray:
    """One fused serving stripe; `start` is traced so all stripes of a
    bucket — ragged tail included — share this single executable."""
    xb = jax.lax.dynamic_slice_in_dim(Xqp, start, block, axis=1)
    return extend_embed_pallas(X, proj, xb, kind=kind, gamma=gamma,
                               degree=degree, interpret=interpret)


def _projection(model: FittedModel) -> jnp.ndarray:
    """P = Sigma^{-1/2} U^T (r, n). Eigenvalues below _EIG_EPS
    (rank-deficient directions) map to 0 rather than exploding; those
    coordinates carry no kernel mass anyway."""
    inv_sqrt = jnp.where(model.eigvals > _EIG_EPS,
                         1.0 / jnp.sqrt(model.eigvals), 0.0)
    return inv_sqrt[:, None] * model.U.T


class Extender:
    """Single-device extension engine: fused Pallas stripe or two-pass.

    Holds the precomputed projection P = Sigma^{-1/2} U^T and the resolved
    path choices, so serving front-ends (MicroBatcher/AsyncBatcher)
    construct one Extender and reuse its executables.

    policy: a ComputePolicy; embed_fused picks the extend_embed stripe
    engine, assign_fused the Pallas kmeans_assign argmin, interpret the
    Pallas interpret-mode override for both (see
    policy.resolve_pallas_path for the conflict rules). The `fused=` /
    `interpret=` / `assign_fused=` kwargs are the deprecated spelling of
    the same three fields.
    """

    def __init__(self, model: FittedModel, block: Optional[int] = None, *,
                 policy: Optional[ComputePolicy] = None,
                 fused: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 assign_fused: Optional[bool] = None):
        policy = merge_legacy_kwargs(
            policy, {"embed_fused": fused, "interpret": interpret,
                     "assign_fused": assign_fused}, "Extender")
        self.model = model
        self.policy = policy
        self.block = block or model.spec.block
        self._interpret_arg = policy.interpret
        self.fused, self._interpret = policy.resolve_embed()
        self.assign_fused, self._assign_interpret = policy.resolve_assign()
        # Backend-agnostic: the reference set the kernel stripes run
        # against (training points, or the Nystrom landmarks).
        self._ref = model.extension_ref
        self._proj = _projection(model)
        self._statics = _kernel_statics(model.spec)

    def embed(self, Xq: jnp.ndarray,
              block: Optional[int] = None) -> jnp.ndarray:
        """Embed query points Xq (p, b) -> Y_q (r, b), streaming over
        columns in stripes of `block` (callers may narrow per bucket)."""
        model = self.model
        if Xq.shape[0] != model.spec.p:
            raise ValueError(f"query dim {Xq.shape[0]} != model dim "
                             f"{model.spec.p}")
        block = block or self.block
        b = Xq.shape[1]
        out = jnp.zeros((model.spec.r, b), jnp.float32)
        if self.fused:
            kind, gamma, degree = self._statics
            b_pad = -(-b // block) * block
            Xqp = (Xq if b_pad == b
                   else jnp.pad(Xq, ((0, 0), (0, b_pad - b))))
            for start in range(0, b, block):
                yb = _fused_stripe(self._ref, self._proj, Xqp,
                                   jnp.asarray(start), kind=kind,
                                   gamma=gamma, degree=degree, block=block,
                                   interpret=self._interpret)
                width = min(block, b - start)
                out = jax.lax.dynamic_update_slice(out, yb[:, :width],
                                                   (0, start))
            return out
        kern = model.kernel_fn()
        for start, stripe in stripe_iterator(kern, Xq, block,
                                             lhs=self._ref,
                                             pad_tail=True):
            yb = _project_stripe(self._proj, stripe)
            width = min(block, b - start)
            out = jax.lax.dynamic_update_slice(out, yb[:, :width],
                                               (0, start))
        return out

    def assign(self, Xq: jnp.ndarray, block: Optional[int] = None,
               fused: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Assign queries to fitted clusters: (labels (b,), sq dist (b,)).

        `fused` overrides the constructor's assignment-path choice for
        this call (re-resolved, so the CPU conflict rules still apply;
        the constructor's interpret arg is only replayed when the Pallas
        path is requested — fused=False per call always means the jnp
        argmin, even on an interpret=True extender).
        """
        if fused is None:
            use_fused, interp = self.assign_fused, self._assign_interpret
        else:
            use_fused, interp = resolve_pallas_path(
                fused, self._interpret_arg if fused else None,
                "Pallas kmeans_assign")
        Yq = self.embed(Xq, block).T                     # (b, r)
        if use_fused:
            return assign_pallas(Yq, self.model.centroids,
                                 interpret=interp)
        return _assign_jnp(Yq, self.model.centroids)


def embed(model: FittedModel, Xq: jnp.ndarray, block: Optional[int] = None,
          fused: Optional[bool] = None,
          interpret: Optional[bool] = None, *,
          policy: Optional[ComputePolicy] = None) -> jnp.ndarray:
    """One-shot embed Xq (p, b) -> (r, b). Serving paths should hold an
    `Extender` and reuse it; this constructs a throwaway one (the jitted
    stripe executables are shared module-level, so only the tiny
    projection precompute is repaid)."""
    policy = merge_legacy_kwargs(
        policy, {"embed_fused": fused, "interpret": interpret}, "embed")
    return Extender(model, block, policy=policy).embed(Xq)


@jax.jit
def _assign_jnp(Yq: jnp.ndarray, C: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d2 = _sq_dists(Yq, C)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def assign(model: FittedModel, Xq: jnp.ndarray,
           block: Optional[int] = None, fused: Optional[bool] = None,
           embed_fused: Optional[bool] = None,
           interpret: Optional[bool] = None, *,
           policy: Optional[ComputePolicy] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign queries to fitted clusters: (labels (b,), sq distance (b,)).

    policy.assign_fused routes the argmin through the Pallas
    kmeans_assign kernel (the serving default off-CPU); embed_fused picks
    the extend_embed stripe engine; interpret applies to both Pallas
    kernels (see policy.resolve_pallas_path for the explicit CPU-override
    contract). The positional fused/embed_fused/interpret kwargs are the
    deprecated spelling.
    """
    policy = merge_legacy_kwargs(
        policy, {"assign_fused": fused, "embed_fused": embed_fused,
                 "interpret": interpret}, "assign")
    return Extender(model, block, policy=policy).assign(Xq)


# ---------------------------------------------------------------------------
# Mesh-sharded extension
# ---------------------------------------------------------------------------

class ShardedExtender:
    """Extension matmul sharded over a mesh axis, one psum per stripe.

    Placement (fixed at construction, so steady-state serving never moves
    training data again):

        X_train (p, n_pad)  columns sharded P(None, axis)
        proj    (r, n_pad)  columns sharded P(None, axis)
        queries (p, block)  replicated per stripe

    n is zero-padded up to a multiple of the shard count; padded proj
    columns are zero (they come from padded U rows), so whatever kernel
    values the padded X_train columns produce are annihilated by the
    projection (exact, not approximate — this is why X_train's
    zero-padding is safe even for kernels with kappa(0, x) != 0, e.g.
    rbf).

    Per stripe each device contracts its (n_pad/shards, block) slab of
    kappa(X_train, x) into an (r, block) partial — through the fused
    extend_embed Pallas kernel when `fused` resolves on (the slab then
    never leaves VMEM either), or a jnp gram+matmul otherwise — and the
    single psum sums the partials. Communication per stripe is r * block
    floats — independent of n.
    """

    def __init__(self, model: FittedModel, mesh=None, axis: str = "data",
                 block: Optional[int] = None,
                 fused: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 assign_fused: Optional[bool] = None, *,
                 policy: Optional[ComputePolicy] = None):
        # mesh/axis may arrive positionally (the class's raison d'etre,
        # not deprecated) or inside the policy; the Pallas knobs follow
        # the standard legacy-kwarg shim.
        policy = merge_legacy_kwargs(
            policy, {"embed_fused": fused, "interpret": interpret,
                     "assign_fused": assign_fused}, "ShardedExtender")
        if mesh is None:
            mesh, axis = policy.mesh, policy.mesh_axis
        if mesh is None:
            raise ValueError("ShardedExtender needs a mesh — pass mesh= "
                             "or a policy with policy.mesh set")
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}; "
                             f"have {mesh.axis_names}")
        self.model = model
        self.mesh = mesh
        self.axis = axis
        self.policy = policy
        self.block = block or model.spec.block
        self.shards = dict(mesh.shape)[axis]
        self._interpret_arg = policy.interpret
        self.fused, self._interpret = policy.resolve_embed(
            "fused extend_embed stripe (sharded)")
        self.assign_fused, self._assign_interpret = policy.resolve_assign()
        # Reference set (training points or Nystrom landmarks), padded to
        # a column multiple of the shard count.
        n = model.n_ref
        n_pad = -(-n // self.shards) * self.shards
        Xt = model.extension_ref
        proj = _projection(model)
        if n_pad != n:
            Xt = jnp.pad(Xt, ((0, 0), (0, n_pad - n)))
            proj = jnp.pad(proj, ((0, 0), (0, n_pad - n)))
        self._Xt = jax.device_put(Xt, NamedSharding(mesh, P(None, axis)))
        self._proj = jax.device_put(proj,
                                    NamedSharding(mesh, P(None, axis)))
        kern = model.kernel_fn()
        kind, gamma, degree = _kernel_statics(model.spec)
        block_w = self.block
        ax = self.axis
        use_fused, interp = self.fused, self._interpret

        @jax.jit
        def stripe_embed(Xt_sh, proj_sh, Xqp, start):
            xb = jax.lax.dynamic_slice_in_dim(Xqp, start, block_w, axis=1)

            def body(xl, prl, xbl):
                if use_fused:
                    part = extend_embed_pallas(
                        xl, prl, xbl, kind=kind, gamma=gamma,
                        degree=degree, interpret=interp)
                else:
                    part = prl @ kern(xl, xbl)           # (r, block)
                return jax.lax.psum(part, ax)[None]      # (1, r, block)

            out = shard_map(body, mesh=mesh,
                            in_specs=(P(None, ax), P(None, ax),
                                      P(None, None)),
                            out_specs=P(ax, None, None),
                            check_rep=False)(Xt_sh, proj_sh, xb)
            return out[0]                                # (r, block)

        self._stripe_embed = stripe_embed

    def embed(self, Xq: jnp.ndarray) -> jnp.ndarray:
        """Embed Xq (p, b) -> (r, b), streaming query columns in stripes.

        Same single-executable streaming discipline as the unsharded
        `Extender.embed`: Xq is zero-padded to a column multiple of
        `block`, every stripe (ragged tail included) runs the one jitted
        sharded executable, and padded columns are sliced off at the end.
        """
        if Xq.shape[0] != self.model.spec.p:
            raise ValueError(f"query dim {Xq.shape[0]} != model dim "
                             f"{self.model.spec.p}")
        b = Xq.shape[1]
        block = self.block
        b_pad = -(-b // block) * block
        Xqp = (Xq if b_pad == b
               else jnp.pad(Xq, ((0, 0), (0, b_pad - b))))
        out = jnp.zeros((self.model.spec.r, b_pad), jnp.float32)
        for start in range(0, b_pad, block):
            yb = self._stripe_embed(self._Xt, self._proj, Xqp,
                                    jnp.asarray(start))
            out = jax.lax.dynamic_update_slice(out, yb, (0, start))
        return out[:, :b]

    def assign(self, Xq: jnp.ndarray, fused: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Sharded-embed then centroid argmin; mirrors `Extender.assign`."""
        if fused is None:
            use_fused, interp = self.assign_fused, self._assign_interpret
        else:
            use_fused, interp = resolve_pallas_path(
                fused, self._interpret_arg if fused else None,
                "Pallas kmeans_assign")
        Yq = self.embed(Xq).T                            # (b, r)
        if use_fused:
            return assign_pallas(Yq, self.model.centroids,
                                 interpret=interp)
        return _assign_jnp(Yq, self.model.centroids)


def embed_sharded(model: FittedModel, Xq: jnp.ndarray, mesh,
                  axis: str = "data",
                  block: Optional[int] = None) -> jnp.ndarray:
    """One-shot sharded embed (constructs a throwaway ShardedExtender;
    serving paths should hold one and reuse its placement/executable)."""
    return ShardedExtender(model, mesh, axis, block).embed(Xq)

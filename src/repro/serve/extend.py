"""Out-of-sample extension: embed and assign new points against a fit.

The fit gives K_hat = U Sigma U^T, so the Nystrom-style extension of a new
point x is

    y(x) = Sigma^{-1/2} U^T kappa(X_train, x)          in R^r

which reproduces the fitted Y exactly on the training points whenever the
kernel matrix is (numerically) rank <= r' — for a training point x_j,
kappa(X_train, x_j) = K e_j = U Sigma U^T e_j and the formula collapses to
Sigma^{1/2} U^T e_j = Y e_j.

Memory model: the (n, b) kernel block kappa(X_train, X_query) is never
materialized beyond n x min(b, block) — query columns stream through
`kernels_fn.stripe_iterator` (lhs=X_train) in stripes of the SAME `block`
the training pass used, so serving never exceeds the training-time memory
budget no matter how many queries arrive at once. Each stripe — ragged
tails included — runs through one jitted gram_stripe executable and one
jitted projection executable (pad_tail=True), so steady-state serving
never retraces.

Assignment offers two paths: a pure-jnp distance argmin, and a fused path
that reuses the Pallas kmeans_assign kernel (distance + argmin in VMEM, the
(b, k) matrix never leaves the chip). On CPU the Pallas kernel runs in
interpret mode, so the jnp path is the default there.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import stripe_iterator
from repro.core.kmeans import _sq_dists
from repro.kernels.kmeans_assign.ops import assign_pallas
from repro.serve.artifact import FittedModel

_EIG_EPS = 1e-7


@jax.jit
def _project_stripe(U: jnp.ndarray, eigvals: jnp.ndarray,
                    stripe: jnp.ndarray) -> jnp.ndarray:
    """Sigma^{-1/2} U^T applied to one (n, block) kernel stripe -> (r, block).

    Eigenvalues below _EIG_EPS (rank-deficient directions) map to 0 rather
    than exploding; those coordinates carry no kernel mass anyway.
    """
    inv_sqrt = jnp.where(eigvals > _EIG_EPS, 1.0 / jnp.sqrt(eigvals), 0.0)
    return (inv_sqrt[:, None] * U.T) @ stripe


def embed(model: FittedModel, Xq: jnp.ndarray,
          block: Optional[int] = None) -> jnp.ndarray:
    """Embed query points Xq (p, b) -> Y_q (r, b), streaming over columns."""
    if Xq.shape[0] != model.spec.p:
        raise ValueError(f"query dim {Xq.shape[0]} != model dim "
                         f"{model.spec.p}")
    block = block or model.spec.block
    kern = model.kernel_fn()
    b = Xq.shape[1]
    out = jnp.zeros((model.spec.r, b), jnp.float32)
    for start, stripe in stripe_iterator(kern, Xq, block, lhs=model.X_train,
                                         pad_tail=True):
        yb = _project_stripe(model.U, model.eigvals, stripe)
        width = min(block, b - start)
        out = jax.lax.dynamic_update_slice(out, yb[:, :width], (0, start))
    return out


@jax.jit
def _assign_jnp(Yq: jnp.ndarray, C: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d2 = _sq_dists(Yq, C)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def assign(model: FittedModel, Xq: jnp.ndarray,
           block: Optional[int] = None, fused: Optional[bool] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign queries to fitted clusters: (labels (b,), sq distance (b,)).

    fused=True routes the argmin through the Pallas kmeans_assign kernel
    (the serving hot path on TPU); default picks it off-CPU.
    """
    if fused is None:
        fused = jax.default_backend() != "cpu"
    Yq = embed(model, Xq, block).T                       # (b, r)
    if fused:
        return assign_pallas(Yq, model.centroids)
    return _assign_jnp(Yq, model.centroids)

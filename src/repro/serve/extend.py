"""Out-of-sample extension: embed and assign new points against a fit.

The fit gives K_hat = U Sigma U^T, so the Nystrom-style extension of a new
point x is

    y(x) = Sigma^{-1/2} U^T kappa(X_train, x)          in R^r

which reproduces the fitted Y exactly on the training points whenever the
kernel matrix is (numerically) rank <= r' — for a training point x_j,
kappa(X_train, x_j) = K e_j = U Sigma U^T e_j and the formula collapses to
Sigma^{1/2} U^T e_j = Y e_j.

Memory model: the (n, b) kernel block kappa(X_train, X_query) is never
materialized beyond n x min(b, block) — query columns stream through
`kernels_fn.stripe_iterator` (lhs=X_train) in stripes of the SAME `block`
the training pass used, so serving never exceeds the training-time memory
budget no matter how many queries arrive at once. Each stripe — ragged
tails included — runs through one jitted gram_stripe executable and one
jitted projection executable (pad_tail=True), so steady-state serving
never retraces.

Assignment offers two paths: a pure-jnp distance argmin, and a fused path
that reuses the Pallas kmeans_assign kernel (distance + argmin in VMEM, the
(b, k) matrix never leaves the chip). On CPU the Pallas kernel runs in
interpret mode, so the jnp path is the default there.

Mesh-sharded path (`ShardedExtender`): the extension matmul
Sigma^{-1/2} U^T kappa(X_train, x) is the serving-time hot loop, and it
shards the same way the training pass does (distributed/cluster.py):
X_train column-sharded and U row-sharded over the mesh's data axis, each
device computing its n/shards x block stripe of the kernel against the
replicated query block plus the matching partial projection, combined by
ONE psum of the tiny (r, block) partials. Per-device kernel memory drops
from n x block to n/shards x block and embedding throughput scales with
device count; see docs/SERVING.md.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.kernels_fn import stripe_iterator
from repro.core.kmeans import _sq_dists
from repro.kernels.kmeans_assign.ops import assign_pallas
from repro.serve.artifact import FittedModel

_EIG_EPS = 1e-7


@jax.jit
def _project_stripe(U: jnp.ndarray, eigvals: jnp.ndarray,
                    stripe: jnp.ndarray) -> jnp.ndarray:
    """Sigma^{-1/2} U^T applied to one (n, block) kernel stripe -> (r, block).

    Eigenvalues below _EIG_EPS (rank-deficient directions) map to 0 rather
    than exploding; those coordinates carry no kernel mass anyway.
    """
    inv_sqrt = jnp.where(eigvals > _EIG_EPS, 1.0 / jnp.sqrt(eigvals), 0.0)
    return (inv_sqrt[:, None] * U.T) @ stripe


def embed(model: FittedModel, Xq: jnp.ndarray,
          block: Optional[int] = None) -> jnp.ndarray:
    """Embed query points Xq (p, b) -> Y_q (r, b), streaming over columns."""
    if Xq.shape[0] != model.spec.p:
        raise ValueError(f"query dim {Xq.shape[0]} != model dim "
                         f"{model.spec.p}")
    block = block or model.spec.block
    kern = model.kernel_fn()
    b = Xq.shape[1]
    out = jnp.zeros((model.spec.r, b), jnp.float32)
    for start, stripe in stripe_iterator(kern, Xq, block, lhs=model.X_train,
                                         pad_tail=True):
        yb = _project_stripe(model.U, model.eigvals, stripe)
        width = min(block, b - start)
        out = jax.lax.dynamic_update_slice(out, yb[:, :width], (0, start))
    return out


@jax.jit
def _assign_jnp(Yq: jnp.ndarray, C: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d2 = _sq_dists(Yq, C)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def assign(model: FittedModel, Xq: jnp.ndarray,
           block: Optional[int] = None, fused: Optional[bool] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign queries to fitted clusters: (labels (b,), sq distance (b,)).

    fused=True routes the argmin through the Pallas kmeans_assign kernel
    (the serving hot path on TPU); default picks it off-CPU.
    """
    if fused is None:
        fused = jax.default_backend() != "cpu"
    Yq = embed(model, Xq, block).T                       # (b, r)
    if fused:
        return assign_pallas(Yq, model.centroids)
    return _assign_jnp(Yq, model.centroids)


# ---------------------------------------------------------------------------
# Mesh-sharded extension
# ---------------------------------------------------------------------------

class ShardedExtender:
    """Extension matmul sharded over a mesh axis, one psum per stripe.

    Placement (fixed at construction, so steady-state serving never moves
    training data again):

        X_train (p, n_pad)  columns sharded P(None, axis)
        U       (n_pad, r)  rows    sharded P(axis, None)
        queries (p, block)  replicated per stripe

    n is zero-padded up to a multiple of the shard count; padded U rows
    are zero, so whatever kernel values the padded X_train columns produce
    are annihilated by the projection (exact, not approximate — this is
    why X_train's zero-padding is safe even for kernels with
    kappa(0, x) != 0, e.g. rbf).

    Per stripe each device materializes only its (n_pad/shards, block)
    slab of kappa(X_train, x) and contracts it immediately into an
    (r, block) partial; the single psum sums the partials. Communication
    per stripe is r * block floats — independent of n.
    """

    def __init__(self, model: FittedModel, mesh, axis: str = "data",
                 block: Optional[int] = None):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}; "
                             f"have {mesh.axis_names}")
        self.model = model
        self.mesh = mesh
        self.axis = axis
        self.block = block or model.spec.block
        self.shards = dict(mesh.shape)[axis]
        n = model.spec.n
        n_pad = -(-n // self.shards) * self.shards
        Xt = model.X_train
        U = model.U
        if n_pad != n:
            Xt = jnp.pad(Xt, ((0, 0), (0, n_pad - n)))
            U = jnp.pad(U, ((0, n_pad - n), (0, 0)))
        self._Xt = jax.device_put(Xt, NamedSharding(mesh, P(None, axis)))
        self._U = jax.device_put(U, NamedSharding(mesh, P(axis, None)))
        self._inv_sqrt = jnp.where(model.eigvals > _EIG_EPS,
                                   1.0 / jnp.sqrt(model.eigvals), 0.0)
        kern = model.kernel_fn()
        block_w = self.block
        ax = self.axis

        @jax.jit
        def stripe_embed(Xt_sh, U_sh, inv_sqrt, Xqp, start):
            xb = jax.lax.dynamic_slice_in_dim(Xqp, start, block_w, axis=1)

            def body(xl, ul, xbl):
                stripe = kern(xl, xbl)                  # (n_local, block)
                part = (inv_sqrt[:, None] * ul.T) @ stripe
                return jax.lax.psum(part, ax)[None]     # (1, r, block)

            out = shard_map(body, mesh=mesh,
                            in_specs=(P(None, ax), P(ax, None),
                                      P(None, None)),
                            out_specs=P(ax, None, None),
                            check_rep=False)(Xt_sh, U_sh, xb)
            return out[0]                               # (r, block)

        self._stripe_embed = stripe_embed

    def embed(self, Xq: jnp.ndarray) -> jnp.ndarray:
        """Embed Xq (p, b) -> (r, b), streaming query columns in stripes.

        Same single-executable streaming discipline as the unsharded
        `embed`: Xq is zero-padded to a column multiple of `block`, every
        stripe (ragged tail included) runs the one jitted sharded
        executable, and padded columns are sliced off at the end.
        """
        if Xq.shape[0] != self.model.spec.p:
            raise ValueError(f"query dim {Xq.shape[0]} != model dim "
                             f"{self.model.spec.p}")
        b = Xq.shape[1]
        block = self.block
        b_pad = -(-b // block) * block
        Xqp = (Xq if b_pad == b
               else jnp.pad(Xq, ((0, 0), (0, b_pad - b))))
        out = jnp.zeros((self.model.spec.r, b_pad), jnp.float32)
        for start in range(0, b_pad, block):
            yb = self._stripe_embed(self._Xt, self._U, self._inv_sqrt,
                                    Xqp, jnp.asarray(start))
            out = jax.lax.dynamic_update_slice(out, yb, (0, start))
        return out[:, :b]

    def assign(self, Xq: jnp.ndarray, fused: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Sharded-embed then centroid argmin; mirrors `assign`."""
        if fused is None:
            fused = jax.default_backend() != "cpu"
        Yq = self.embed(Xq).T                            # (b, r)
        if fused:
            return assign_pallas(Yq, self.model.centroids)
        return _assign_jnp(Yq, self.model.centroids)


def embed_sharded(model: FittedModel, Xq: jnp.ndarray, mesh,
                  axis: str = "data",
                  block: Optional[int] = None) -> jnp.ndarray:
    """One-shot sharded embed (constructs a throwaway ShardedExtender;
    serving paths should hold one and reuse its placement/executable)."""
    return ShardedExtender(model, mesh, axis, block).embed(Xq)

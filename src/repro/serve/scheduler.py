"""Async SLO-aware request scheduling: futures + deadline-driven flush.

This is the ROADMAP item "async request queue + latency SLO accounting in
MicroBatcher". `MicroBatcher.drain()` is synchronous and deterministic by
design — every caller blocks until the whole coalesced batch runs.
`AsyncBatcher` keeps that exact compute path (flushes are literally
`MicroBatcher.submit()* + drain()`, so results are bit-identical by
construction) and puts a latency-aware front door on it:

    submit(Xq) -> Future     returns immediately; the request joins the
                             pending window and its enqueue timestamp is
                             taken
    flush trigger            whichever fires first:
                               - the pending window reaches max_bucket
                                 query columns (a full steady-state batch
                                 is ready -> flushing now costs nothing),
                                 checked at submit time;
                               - the OLDEST pending request has waited
                                 max_wait_ms (the latency deadline),
                                 checked by poll()/the pump thread.
    completion               the flushed batch runs through the bucketed
                             assignment path; each request's Future
                             resolves to its (labels, d2) slice and its
                             enqueue->flush->complete timestamps land in
                             a LatencyStats (serve/latency.py)

Determinism: all scheduling state lives behind one lock and the clock is
injectable, so tests drive deadline semantics with a fake clock and
explicit poll() calls — no sleeps, no flaky timing. A background pump
thread (`start()`/`stop()`, or the context manager) is available for real
deployments where nobody polls.

Batch membership does not affect results: query columns are independent
through the whole extension matmul and the bucketed path pads to the same
pow-2 widths regardless of how requests were grouped (see
serve/batcher.py), so any interleaving of flushes yields the same labels
as one big drain. tests/test_scheduler.py pins this.

Compute-path selection (Pallas kernels, mesh sharding) arrives as a
`policy=ComputePolicy(...)` kwarg forwarded verbatim to the underlying
MicroBatcher — AsyncBatcher adds no knobs of its own (the deprecated
fused=/embed_fused=/mesh= spellings forward the same way).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.serve.artifact import FittedModel
from repro.serve.batcher import MicroBatcher, bucket_size
from repro.serve.latency import LatencyStats


class _Pending(NamedTuple):
    """One queued request: payload + future + its enqueue timestamp."""
    Xq: np.ndarray
    future: Future
    enqueue_ts: float


class AsyncBatcher:
    """Deadline-driven async front door over MicroBatcher's bucketed path.

    max_wait_ms: latency deadline — the longest any request may sit in the
        pending window before a flush is forced. Lower = lower p99, less
        coalescing; higher = bigger batches, better throughput.
    slo_ms: end-to-end latency SLO recorded per request (None disables).
    clock: monotonic-seconds callable; injectable for deterministic tests.
    Remaining kwargs (block, min_bucket, max_bucket, fused, embed_fused,
    interpret, mesh, mesh_axis) go straight to the inner MicroBatcher —
    embed_fused/interpret pick the fused extend_embed Pallas stripe
    engine exactly as in the sync path.
    """

    def __init__(self, model: FittedModel, *, max_wait_ms: float = 5.0,
                 slo_ms: Optional[float] = None,
                 clock=time.monotonic, latency: Optional[LatencyStats] = None,
                 **batcher_kwargs):
        self.batcher = MicroBatcher(model, **batcher_kwargs)
        self.max_wait_ms = float(max_wait_ms)
        self.clock = clock
        self.latency = latency if latency is not None \
            else LatencyStats(slo_ms=slo_ms)
        # lock-order: _flush_lock -> _lock
        # flush() nests the window lock inside the drain lock; nothing
        # may acquire the pair inverted (taking _flush_lock while
        # holding _lock would deadlock against a concurrent flush).
        # repro.analysis reads this contract and the guarded-by
        # annotations below; mutations of annotated fields outside
        # `with self._lock` are build failures (rules L001/L002).
        self._queue: List[_Pending] = []      # guarded-by: _lock
        # Per-bucket deadline overrides (milliseconds), keyed by the pow-2
        # execution bucket the CURRENT pending window would coalesce into.
        # This is the knob the fleet tier's AdaptiveWaitController turns:
        # a bucket whose latency breakdown shows deadline pressure gets a
        # shorter wait (less batching, more headroom); a comfortably-fast
        # bucket earns a longer one. Unset buckets fall back to
        # max_wait_ms. Read by due(); written via set_bucket_wait().
        self._bucket_wait: Dict[int, float] = {}  # guarded-by: _lock
        self._lock = threading.Lock()         # guards the pending window
        self._flush_lock = threading.Lock()   # serializes inner drains
        self._thread: Optional[threading.Thread] = None  # guarded-by: _lock
        self._stop_event = threading.Event()
        self._stopped = False                 # guarded-by: _lock
        # Pump-thread health: a flush that raises has already delivered
        # the exception to that batch's futures; the pump must survive to
        # serve later requests. Counter + last error are the monitoring
        # surface.
        self.pump_errors = 0
        self.last_pump_error: Optional[BaseException] = None

    # -- request side ----------------------------------------------------

    def submit(self, Xq) -> "Future[Tuple[np.ndarray, np.ndarray]]":
        """Enqueue one (p, b) request; resolves to (labels (b,), d2 (b,)).

        Flushes inline when this submit fills the window to max_bucket —
        the full-batch trigger — so a saturating client never waits on the
        deadline.
        """
        Xq = self.batcher.validate_request(Xq)
        fut: Future = Future()
        with self._lock:
            # Checked under the lock so a submit racing stop() either
            # lands in the queue stop() is about to flush, or raises —
            # it can never enqueue into a retired, pump-less batcher
            # where the future would be stranded forever.
            if self._stopped:
                raise RuntimeError(
                    "submit() on a stopped AsyncBatcher: nothing would "
                    "ever flush this request (after a hot-swap, get the "
                    "current scheduler from the registry)")
            self._queue.append(_Pending(Xq, fut, self.clock()))
            full = self._pending_width_locked() >= self.batcher.max_bucket
        if full:
            self.flush()
        return fut

    def _pending_width_locked(self) -> int:
        return sum(p.Xq.shape[1] for p in self._queue)

    @property
    def pending_requests(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def pending_width(self) -> int:
        """Total query columns currently waiting for a flush."""
        with self._lock:
            return self._pending_width_locked()

    # -- flush side ------------------------------------------------------

    def set_bucket_wait(self, bucket: int, max_wait_ms: float) -> None:
        """Override the flush deadline for one pow-2 execution bucket.

        The AdaptiveWaitController's write path: buckets not overridden
        keep the constructor's max_wait_ms."""
        if max_wait_ms <= 0:
            raise ValueError(f"max_wait_ms must be positive, "
                             f"got {max_wait_ms!r}")
        with self._lock:
            self._bucket_wait[int(bucket)] = float(max_wait_ms)

    def bucket_wait(self, bucket: int) -> float:
        """Effective flush deadline (ms) for one pow-2 bucket."""
        with self._lock:
            return self._bucket_wait.get(int(bucket), self.max_wait_ms)

    def due(self, now: Optional[float] = None) -> bool:
        """True when the oldest pending request has hit the deadline.

        The deadline is per execution bucket when overridden
        (set_bucket_wait): the wait that applies is the one for the
        bucket the CURRENT pending window would coalesce into — as the
        window grows into a larger bucket, that bucket's (usually
        longer) wait takes over, which is exactly the batching-vs-
        deadline trade the adaptive controller tunes."""
        now = self.clock() if now is None else now
        with self._lock:
            if not self._queue:
                return False
            if self._bucket_wait:
                b = bucket_size(self._pending_width_locked(),
                                self.batcher.min_bucket,
                                self.batcher.max_bucket)
                wait = self._bucket_wait.get(b, self.max_wait_ms)
            else:
                wait = self.max_wait_ms
            return (now - self._queue[0].enqueue_ts) * 1e3 >= wait

    def poll(self) -> int:
        """Flush if the deadline trigger fires; returns requests completed.

        This is the cooperative scheduling entry point: an event loop (or
        test) calls poll() at whatever cadence it likes; the pump thread
        is just poll() in a loop.
        """
        return self.flush() if self.due() else 0

    def flush(self) -> int:
        """Run all pending requests now; returns requests completed.

        The batch is handed to the inner MicroBatcher exactly as drain()
        would see it, so async results are bit-identical to a synchronous
        drain of the same requests. Futures resolve in submission order;
        on compute failure every future in the batch carries the
        exception instead of the batch dying silently.
        """
        with self._flush_lock:
            with self._lock:
                batch, self._queue = self._queue, []
            if not batch:
                return 0
            flush_ts = self.clock()
            try:
                for p in batch:
                    self.batcher.submit(p.Xq)
                results = self.batcher.drain()
            except Exception as exc:                 # pragma: no cover
                for p in batch:
                    if p.future.set_running_or_notify_cancel():
                        p.future.set_exception(exc)
                raise
            # drain() must return exactly one result per request handed
            # to it; a mismatch means something enqueued on the inner
            # batcher directly and a silent zip would scatter results to
            # the wrong futures.
            if len(results) != len(batch):           # pragma: no cover
                exc = RuntimeError(
                    f"flush expected {len(batch)} results, drained "
                    f"{len(results)}: the inner MicroBatcher had foreign "
                    f"pending requests")
                for p in batch:
                    if p.future.set_running_or_notify_cancel():
                        p.future.set_exception(exc)
                raise exc
            complete_ts = self.clock()
            # The pow-2 execution bucket this flush ran through: the
            # coalesced width, bucketed by the inner batcher's policy
            # (oversized batches chunk into max_bucket pieces, so the
            # clamp is also the dominant executable). Keys the per-bucket
            # latency breakdown.
            width = sum(p.Xq.shape[1] for p in batch)
            bucket = bucket_size(width, self.batcher.min_bucket,
                                 self.batcher.max_bucket)
            # LatencyStats mutation stays inside the flush lock: record()
            # is read-modify-write on histogram counts, and a pump-thread
            # flush can overlap a submit-triggered inline flush.
            for p in batch:
                self.latency.record(p.enqueue_ts, flush_ts, complete_ts,
                                    queries=p.Xq.shape[1], bucket=bucket)
        # A client may have cancel()ed its future while the request sat in
        # the pending window; set_result on a cancelled future raises
        # InvalidStateError and would strand every LATER future in the
        # batch unresolved. set_running_or_notify_cancel() claims the
        # future atomically (False = it was cancelled -> drop the result).
        for p, res in zip(batch, results):
            if p.future.set_running_or_notify_cancel():
                p.future.set_result(res)
        return len(batch)

    # -- background pump -------------------------------------------------

    def _pump_period(self) -> float:
        """Pump poll period: a quarter of the SHORTEST active deadline."""
        with self._lock:
            waits = list(self._bucket_wait.values())
        return max(min(waits + [self.max_wait_ms]) / 4e3, 1e-4)

    @property
    def running(self) -> bool:
        """True while the background pump thread is alive."""
        return self._thread is not None

    @property
    def stopped(self) -> bool:
        """True once stop() retired this batcher (submits now raise)."""
        return self._stopped

    def start(self) -> "AsyncBatcher":
        """Spawn the daemon pump thread (poll() every max_wait_ms / 4).

        The check-and-spawn is one critical section: two concurrent
        start() calls must not both see `_thread is None` and leak a
        second pump.
        """

        def pump():
            # Re-read the period every cycle: the adaptive controller may
            # shorten a bucket's wait below the constructor deadline, and
            # a pump polling at the stale (longer) quarter-period would
            # miss the new deadline by up to the difference.
            while not self._stop_event.wait(self._pump_period()):
                try:
                    self.poll()
                except Exception as exc:   # batch futures carry the error
                    self.pump_errors += 1
                    self.last_pump_error = exc

        with self._lock:
            if self._stopped:
                raise RuntimeError("cannot start a stopped AsyncBatcher")
            if self._thread is not None:
                raise RuntimeError("pump thread already running")
            self._stop_event.clear()
            thread = threading.Thread(target=pump, daemon=True,
                                      name="AsyncBatcher-pump")
            self._thread = thread
        thread.start()
        return self

    def stop(self) -> int:
        """Retire this batcher: stop the pump, flush pending, reject
        all later submits. Idempotent — a second stop() is a no-op that
        flushes an empty queue. Returns the requests flushed by THIS
        call (what a hot-swap drained into the outgoing model).

        The thread handle is claimed under _lock (two concurrent
        stop() calls must not both join-and-clear it), but join()
        happens OUTSIDE: the pump's poll()->flush() takes _lock, so
        joining while holding it would deadlock.
        """
        with self._lock:
            self._stopped = True
            thread, self._thread = self._thread, None
        if thread is not None:
            self._stop_event.set()
            thread.join()
        return self.flush()

    def __enter__(self) -> "AsyncBatcher":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

"""Streaming latency accounting for the async serving path (ROADMAP:
"async request queue + latency SLO accounting in MicroBatcher").

A serving process answers millions of requests; keeping every latency
sample to compute percentiles is out of the question. `LatencyStats` keeps
a *streaming histogram* instead: fixed log-spaced bucket edges spanning
1 microsecond .. ~100 s, O(1) per sample, O(buckets) memory, and
percentiles recovered by walking the cumulative counts with geometric
interpolation inside the winning bucket (error bounded by the bucket
ratio, ~9% with 16 buckets/decade — far below the run-to-run noise of any
real latency distribution).

Three timestamps bound every request's life (recorded by
`serve.scheduler.AsyncBatcher`):

    enqueue   submit() accepted the request
    flush     the deadline/full-bucket trigger moved it into a batch
    complete  results were scattered back and its future resolved

from which two spans are tracked per request: queue wait
(enqueue->flush) and total latency (enqueue->complete). An optional SLO
threshold (`slo_ms`) turns the total-latency stream into a violation
counter — the number every later PR (hot-swap, quantized artifacts)
reports against.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

# Bucket edges: 16 buckets per decade from 1e-3 ms (1 us) to 1e5 ms (100 s),
# i.e. ratio 10^(1/16) ~ 1.15 between edges. Samples outside the range clamp
# to the first/last bucket.
_LO_MS = 1e-3
_HI_MS = 1e5
_PER_DECADE = 16
# round(), not int(): the decade count is an exact integer mathematically
# (the range is a power-of-10 ratio), but float log10 may land at
# 7.999999... on some libms and int() would silently drop a whole decade
# of buckets.
_N_BUCKETS = round(math.log10(_HI_MS / _LO_MS)) * _PER_DECADE


def _bucket_index(ms: float) -> int:
    if ms <= _LO_MS:
        return 0
    # int() truncation mis-buckets samples sitting exactly on a bucket
    # edge (log10 of an edge value can land just below the integer).
    # round() is within one bucket of the true floor; the compare against
    # the recomputed edges — the same float expressions that define the
    # buckets — settles it exactly, edges included.
    idx = int(round(math.log10(ms / _LO_MS) * _PER_DECADE))
    idx = min(max(idx, 0), _N_BUCKETS - 1)
    lo, hi = _bucket_edges(idx)
    if ms < lo:
        idx -= 1
    elif ms >= hi:
        idx += 1
    return min(max(idx, 0), _N_BUCKETS - 1)


def _bucket_edges(idx: int) -> tuple:
    lo = _LO_MS * 10.0 ** (idx / _PER_DECADE)
    hi = _LO_MS * 10.0 ** ((idx + 1) / _PER_DECADE)
    return lo, hi


class Histogram:
    """Fixed-edge log-spaced streaming histogram over milliseconds."""

    def __init__(self):
        self.counts: List[int] = [0] * _N_BUCKETS
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0

    def record(self, ms: float) -> None:
        ms = max(float(ms), 0.0)
        self.counts[_bucket_index(ms)] += 1
        self.n += 1
        self.total += ms
        self.min = min(self.min, ms)
        self.max = max(self.max, ms)

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold `other`'s samples into this histogram, in place.

        Exact, not approximate: both histograms share the same fixed
        bucket edges, so summing counts yields bit-for-bit the histogram
        a single stream of the union of samples would have built — the
        property the fleet tier's per-worker -> tier-level aggregation
        relies on (tests/test_fleet.py pins it)."""
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def percentile(self, q: float) -> float:
        """q in [0, 100]. Geometric interpolation inside the bucket; the
        observed min/max clamp the first/last occupied bucket so tiny
        sample counts do not report a bucket edge nobody hit."""
        if self.n == 0:
            return 0.0
        rank = q / 100.0 * self.n
        seen = 0
        for idx, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo, hi = _bucket_edges(idx)
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo * (hi / lo) ** frac
            seen += c
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


class LatencyStats:
    """Per-request latency accounting: queue-wait + total histograms, an
    SLO-violation counter, and a per-bucket total-latency breakdown.

    slo_ms=None disables SLO accounting (violations stay 0).

    The per-bucket breakdown keys a separate total-latency Histogram by
    the pow-2 bucket the request's flush batch ran through
    (serve/batcher.py bucketing policy) — the knob-tuning read-out the
    aggregate percentiles hide: a fat p99 can be one under-coalesced
    bucket, not the whole pipeline. Callers that do not batch (or do not
    know the bucket) simply omit `bucket` and only the aggregate
    histograms move."""

    def __init__(self, slo_ms: Optional[float] = None):
        self.slo_ms = slo_ms
        self.queue_wait = Histogram()
        self.total = Histogram()
        self.by_bucket: Dict[int, Histogram] = {}
        self.requests = 0
        self.queries = 0
        self.slo_violations = 0

    def record(self, enqueue_ts: float, flush_ts: float, complete_ts: float,
               queries: int = 1, bucket: Optional[int] = None) -> None:
        """Record one request's life from its three timestamps (seconds).

        `bucket` (optional) is the pow-2 execution bucket of the flush
        that completed the request; it lands the total latency in the
        per-bucket breakdown."""
        wait_ms = (flush_ts - enqueue_ts) * 1e3
        total_ms = (complete_ts - enqueue_ts) * 1e3
        self.queue_wait.record(wait_ms)
        self.total.record(total_ms)
        if bucket is not None:
            self.by_bucket.setdefault(int(bucket), Histogram()) \
                .record(total_ms)
        self.requests += 1
        self.queries += int(queries)
        if self.slo_ms is not None and total_ms > self.slo_ms:
            self.slo_violations += 1

    def merge(self, other: "LatencyStats") -> "LatencyStats":
        """Fold another LatencyStats into this one, in place.

        The fleet aggregation path: each worker keeps its own per-process
        LatencyStats; the tier-level p50/p95/p99 summary is the merge of
        all of them. Because every histogram shares the same fixed bucket
        edges, merging is exact — the merged summary equals the summary a
        single stream observing all samples (in any interleaving) would
        report. Both sides must account the same SLO (otherwise the
        summed violation counters would silently mix thresholds); merging
        into a stats whose slo_ms is None adopts the other's threshold
        only when no samples were recorded against None yet."""
        if other.slo_ms != self.slo_ms:
            if self.slo_ms is None and self.requests == 0:
                self.slo_ms = other.slo_ms
            else:
                raise ValueError(
                    f"cannot merge LatencyStats with different SLOs "
                    f"({self.slo_ms!r} vs {other.slo_ms!r}): the summed "
                    f"violation counters would mix thresholds")
        self.queue_wait.merge(other.queue_wait)
        self.total.merge(other.total)
        for b, h in other.by_bucket.items():
            self.by_bucket.setdefault(int(b), Histogram()).merge(h)
        self.requests += other.requests
        self.queries += other.queries
        self.slo_violations += other.slo_violations
        return self

    @classmethod
    def merged(cls, stats: "List[LatencyStats]",
               slo_ms: Optional[float] = None) -> "LatencyStats":
        """Fresh tier-level aggregate of per-worker stats (non-mutating)."""
        out = cls(slo_ms=slo_ms if slo_ms is not None
                  else (stats[0].slo_ms if stats else None))
        for s in stats:
            out.merge(s)
        return out

    @property
    def slo_violation_rate(self) -> float:
        return self.slo_violations / self.requests if self.requests else 0.0

    def summary(self) -> Dict:
        """JSON-ready summary — the schema BENCH_serve.json's async mode
        embeds (see docs/SERVING.md, "SLO metrics glossary")."""
        t, w = self.total, self.queue_wait
        return {
            "requests": self.requests,
            "queries": self.queries,
            "latency_ms": {
                "p50": t.percentile(50.0),
                "p95": t.percentile(95.0),
                "p99": t.percentile(99.0),
                "mean": t.mean,
                "max": t.max if t.n else 0.0,
            },
            "queue_wait_ms": {
                "p50": w.percentile(50.0),
                "p95": w.percentile(95.0),
                "p99": w.percentile(99.0),
            },
            # Per-execution-bucket total latency (string keys: this dict
            # is JSON-serialized verbatim into BENCH_serve.json).
            "per_bucket": {
                str(b): {
                    "requests": h.n,
                    "p50": h.percentile(50.0),
                    "p95": h.percentile(95.0),
                    "p99": h.percentile(99.0),
                    "mean": h.mean,
                }
                for b, h in sorted(self.by_bucket.items())
            },
            "slo_ms": self.slo_ms,
            "slo_violations": self.slo_violations,
            "slo_violation_rate": self.slo_violation_rate,
        }

    def format_table(self) -> str:
        """Human-readable latency table (printed by serve_cluster --bench
        and examples/serve_async.py)."""
        s = self.summary()
        lines = [
            f"{'requests':>14s}: {s['requests']}",
            f"{'queries':>14s}: {s['queries']}",
            f"{'p50':>14s}: {s['latency_ms']['p50']:10.3f} ms",
            f"{'p95':>14s}: {s['latency_ms']['p95']:10.3f} ms",
            f"{'p99':>14s}: {s['latency_ms']['p99']:10.3f} ms",
            f"{'mean':>14s}: {s['latency_ms']['mean']:10.3f} ms",
            f"{'max':>14s}: {s['latency_ms']['max']:10.3f} ms",
            f"{'queue-wait p95':>14s}: {s['queue_wait_ms']['p95']:10.3f} ms",
        ]
        if self.slo_ms is not None:
            lines.append(f"{'SLO':>14s}: {self.slo_ms:g} ms, "
                         f"{self.slo_violations} violations "
                         f"({100.0 * self.slo_violation_rate:.2f}%)")
        for b, h in sorted(self.by_bucket.items()):
            lines.append(f"{f'bucket {b}':>14s}: "
                         f"p50 {h.percentile(50.0):8.3f} ms  "
                         f"p95 {h.percentile(95.0):8.3f} ms  "
                         f"({h.n} requests)")
        return "\n".join(lines)

"""Sketched gradient all-reduce with error feedback (beyond-paper).

Reuses the paper's SRHT primitive Omega^T = R^T H D as a gradient
compressor for data-parallel training: instead of all-reducing the full
n-dim gradient, each worker all-reduces the r'-dim sketch s = Omega^T g
(n/r' x less cross-pod traffic) and applies the projection
ĝ = Omega s = Omega Omega^T g. Because Omega has exactly orthonormal
columns ((R^T H D)(D H R) = I), ĝ is an orthogonal projection of g onto a
random r'-dim subspace; the residual e = g - ĝ is carried by error
feedback (EF-SGD, Stich et al.) so the update is unbiased over time.

The same `signs/rows` must be used by all workers in a round (seeded from
the step counter) and SHOULD be rotated every step so the projection
subspace varies — both handled by `sketch_round_keys`.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sketch import fwht, next_pow2


def _flatten(tree) -> Tuple[jnp.ndarray, Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    vec = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32)
                           for leaf in leaves])
    return vec, treedef, [(leaf.shape, leaf.dtype) for leaf in leaves]


def _unflatten(vec, treedef, metas):
    out = []
    off = 0
    for shape, dtype in metas:
        size = 1
        for s in shape:
            size *= s
        out.append(vec[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def sketch_params(key: jax.Array, n: int, r_prime: int):
    """(signs, rows) of the round's Omega = D H R; n padded internally."""
    n_pad = next_pow2(n)
    k1, k2 = jax.random.split(key)
    signs = jax.random.rademacher(k1, (n_pad,), dtype=jnp.float32)
    rows = jax.random.choice(k2, n_pad, (r_prime,), replace=False)
    return signs, rows


def compress(vec: jnp.ndarray, signs: jnp.ndarray,
             rows: jnp.ndarray) -> jnp.ndarray:
    """s = Omega^T g = R^T H (D g).  vec: (n,) -> (r',)."""
    n_pad = signs.shape[0]
    g = jnp.pad(vec, (0, n_pad - vec.shape[0])) * signs
    return fwht(g[:, None])[:, 0][rows]


def decompress(s: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray,
               n: int) -> jnp.ndarray:
    """ĝ = Omega s = D H R s -> (n,)."""
    n_pad = signs.shape[0]
    scat = jnp.zeros((n_pad,), s.dtype).at[rows].set(s)
    return (fwht(scat[:, None])[:, 0] * signs)[:n]


def make_sketched_grad_transform(params_like, r_prime: int,
                                 axis: Optional[str] = None):
    """Returns (transform, init_ef_state).

    transform(grads, ef_state, step_key) -> (grads_hat, new_ef_state):
      1. v = flatten(grads) + ef
      2. s = compress(v)  (all-reduced over `axis` when inside shard_map /
         pmapped data-parallel training; with jit+GSPMD the mean is already
         global, so axis=None just applies the projection)
      3. ĝ = decompress(s); ef' = v - ĝ
    """
    vec0, treedef, metas = _flatten(jax.tree.map(jnp.zeros_like, params_like))
    n = vec0.shape[0]

    def init_ef():
        return jnp.zeros((n,), jnp.float32)

    def transform(grads, ef, key):
        vec, _, _ = _flatten(grads)
        v = vec + ef
        signs, rows = sketch_params(key, n, r_prime)
        s = compress(v, signs, rows)
        if axis is not None:
            s = jax.lax.pmean(s, axis)
        g_hat = decompress(s, signs, rows, n)
        new_ef = v - g_hat
        return _unflatten(g_hat, treedef, metas), new_ef

    return transform, init_ef


def compression_ratio(params_like, r_prime: int) -> float:
    n = sum(leaf.size for leaf in jax.tree.leaves(params_like))
    return n / r_prime


# ---------------------------------------------------------------------------
# Quantized artifact codec (ROADMAP "quantized (bf16/int8) artifacts")
# ---------------------------------------------------------------------------
# bf16 is stored as its uint16 bit pattern: numpy's .npy format round-trips
# ml_dtypes.bfloat16 as an opaque void dtype (np.load gives |V2), so the
# artifact layer (serve/artifact.py save_model(dtype="bf16")) persists
# uint16 and records which leaves are encoded; decode restores float32.

_QUANTIZED_DTYPES = ("bf16", "int8")


def bf16_encode(x: jnp.ndarray) -> jnp.ndarray:
    """float array -> (same-shape) uint16 bfloat16 bit pattern."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.bfloat16),
                                        jnp.uint16)


def bf16_decode(u: jnp.ndarray) -> jnp.ndarray:
    """uint16 bfloat16 bit pattern -> float32 (exact for any bf16 value)."""
    b = jax.lax.bitcast_convert_type(jnp.asarray(u, jnp.uint16),
                                     jnp.bfloat16)
    return b.astype(jnp.float32)


def int8_encode(x: jnp.ndarray) -> Tuple[jnp.ndarray, float]:
    """float array -> (int8 array, per-leaf scale), symmetric absmax.

    scale = max|x| / 127, so decode is q * scale — one float of metadata
    per leaf, carried in the artifact's quantized map (JSON), not as a
    side array. A quarter of the f32 bytes; ~2 decimal digits, enough
    for the retrain loop's frequently-republished serving artifacts."""
    x = jnp.asarray(x, jnp.float32)
    amax = float(jnp.max(jnp.abs(x))) if x.size else 0.0
    scale = amax / 127.0 if amax > 0.0 else 1.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def int8_decode(q: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Invert int8_encode -> float32."""
    return jnp.asarray(q, jnp.float32) * jnp.float32(scale)


def quantize_state(state: dict, dtype: str = "bf16"
                   ) -> Tuple[dict, dict]:
    """Encode every floating leaf of a flat array dict for storage.

    Returns (encoded_state, quantized) where `quantized` records, per
    encoded leaf name, the codec — the bare string "bf16", or
    {"codec": "int8", "scale": s} for the scaled int8 codec — in a
    JSON-ready shape (serve/artifact.py persists it verbatim in
    leaves.json). Integer leaves (sketch row indices, landmark indices,
    stream counts) pass through untouched and do not appear in the map.
    `dequantize_state` inverts it.
    """
    if dtype not in _QUANTIZED_DTYPES:
        raise ValueError(f"unknown quantized dtype {dtype!r}; "
                         f"have {list(_QUANTIZED_DTYPES)}")
    out, quantized = {}, {}
    for name, arr in state.items():
        if jnp.issubdtype(jnp.asarray(arr).dtype, jnp.floating):
            if dtype == "bf16":
                out[name] = bf16_encode(arr)
                quantized[name] = dtype
            else:
                q, scale = int8_encode(arr)
                out[name] = q
                quantized[name] = {"codec": "int8", "scale": scale}
        else:
            out[name] = arr
    return out, quantized


def dequantize_state(state: dict, quantized: dict) -> dict:
    """Invert `quantize_state`: decode the recorded leaves to float32.

    Accepts both quantized-map shapes: the legacy bare codec string
    ("bf16") and the per-leaf dict ({"codec": "int8", "scale": s})."""
    out = dict(state)
    for name, meta in quantized.items():
        codec = meta if isinstance(meta, str) else meta.get("codec")
        if codec not in _QUANTIZED_DTYPES:
            raise ValueError(f"leaf {name!r} encoded with unknown dtype "
                             f"{codec!r}; have {list(_QUANTIZED_DTYPES)}")
        if name not in out:
            continue
        if codec == "bf16":
            out[name] = bf16_decode(out[name])
        else:
            out[name] = int8_decode(out[name], float(meta["scale"]))
    return out

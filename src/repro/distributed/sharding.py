"""Sharding rules: 2D (FSDP x TP) weight sharding + batch/cache specs.

PartitionSpec policy for the LM-workload side of the repo (models/,
train/, launch/dryrun); the clustering pipeline's sharding lives in
distributed/cluster.py (training) and serve/extend.py::ShardedExtender
(the mesh-sharded extension matmul, ROADMAP "Serve subsystem").

Scheme:
- every 2D projection W (d_in, d_out): P(fsdp, tp) — input dim sharded over
  the data(+pod) axes ZeRO-3 style, output dim tensor-parallel over 'model';
  "reduction" projections that map back to the residual stream (wo, w2, cv,
  w_out, wb) use P(tp, fsdp) so the contraction dim is the TP-sharded one.
- embeddings: vocab over 'model' (padded to /128/tp), d_model over fsdp.
- MoE expert weights (E, d, f): experts replicated, d over fsdp, f over tp
  (divisibility-safe for E=8/16 vs the 16-way model axis).
- KV caches: batch over dp when divisible, sequence dim over 'model'
  (flash-decode style distributed softmax is then GSPMD-derived).
- every rule is guarded by divisibility; a non-divisible dim stays
  replicated rather than failing to lower.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Names whose 2D matrices contract their TP-sharded input back to the
# residual stream: shard as P(tp, fsdp) instead of P(fsdp, tp).
_REDUCE_BACK = {"wo", "w2", "cv", "w_out", "wb"}
# Stacked containers: arrays carry a leading layer/superblock dim.
_STACKED = {"layers", "supers", "enc_layers", "dec_layers"}


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


class _ShardCtx(threading.local):
    def __init__(self):
        self.dp: Tuple[str, ...] = ()
        self.active = False
        self.seq_axis: Optional[str] = None    # sequence parallelism
        self.seq_div: int = 1                  # size of seq_axis
        self.tp: Optional[str] = None          # model axis name


_CTX = _ShardCtx()


@contextlib.contextmanager
def activation_sharding(dp: Tuple[str, ...], seq_axis: Optional[str] = None,
                        seq_div: int = 1, tp: Optional[str] = "model"):
    """Enable with_sharding_constraint on activations inside model code.

    seq_axis: also shard the sequence dim over this axis at layer
    boundaries (sequence parallelism — the TP all-reduce of layer outputs
    becomes reduce-scatter + all-gather, halving collective bytes)."""
    prev = (_CTX.dp, _CTX.active, _CTX.seq_axis, _CTX.seq_div, _CTX.tp)
    _CTX.dp, _CTX.active = tuple(dp), True
    _CTX.seq_axis, _CTX.seq_div = seq_axis, seq_div
    _CTX.tp = tp
    try:
        yield
    finally:
        (_CTX.dp, _CTX.active, _CTX.seq_axis, _CTX.seq_div,
         _CTX.tp) = prev


def maybe_shard(x: jnp.ndarray, kind: str = "btd") -> jnp.ndarray:
    """Constrain activation sharding if a context is active (no-op in tests).

    kind: 'btd' (B,S,d) batch-sharded; 'bd' (B,d).
    """
    if not _CTX.active:
        return x
    if kind == "btd":
        seq = (_CTX.seq_axis if _CTX.seq_axis and
               x.shape[1] % max(_CTX.seq_div, 1) == 0 else None)
        spec = P(_CTX.dp, seq, None)
    elif kind == "bd":
        spec = P(_CTX.dp, None)
    # MoE expert-pipeline pins (apply_moe): groups over dp, expert-ffn dim
    # over tp, everything else replicated — keeps routing gathers local and
    # forbids XLA from replicating the group dim (which otherwise shows up
    # as activation-sized data-axis all-reduces in the backward).
    elif kind == "moe_gtd":      # (G, Tg, d)
        spec = P(_CTX.dp if x.shape[0] % max(_dp_size(), 1) == 0 else None,
                 None, None)
    elif kind == "moe_gecd":     # (G, E, C, d)
        spec = P(_CTX.dp if x.shape[0] % max(_dp_size(), 1) == 0 else None,
                 None, None, None)
    elif kind == "moe_gecf":     # (G, E, C, f)
        spec = P(_CTX.dp if x.shape[0] % max(_dp_size(), 1) == 0 else None,
                 None, None, _CTX.tp)
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, spec)


def _dp_size() -> int:
    try:
        from jax.sharding import get_abstract_mesh
        m = get_abstract_mesh()
        if m is not None and m.axis_names:
            sizes = dict(m.shape)
            return _prod(sizes.get(a, 1) for a in _CTX.dp) or 1
    except Exception:
        pass
    return 1


def _axes_if_div(dim: int, axes, sizes: Dict[str, int]):
    """Return `axes` (str or tuple) if dim divides by their product."""
    if axes is None:
        return None
    tup = (axes,) if isinstance(axes, str) else tuple(axes)
    if not tup:
        return None
    if dim % _prod(sizes[a] for a in tup) == 0:
        return axes if isinstance(axes, str) else tup
    return None


def _param_rule(name: str, shape: Tuple[int, ...], stacked: bool,
                fsdp, tp, sizes: Dict[str, int]) -> P:
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    nd = len(core)
    if nd <= 1:
        return P(*lead, *(None,) * nd)
    if name == "embed":                     # (V, d)
        return P(*lead, _axes_if_div(core[0], tp, sizes),
                 _axes_if_div(core[1], fsdp, sizes))
    if name == "unembed":                   # (d, V)
        return P(*lead, _axes_if_div(core[0], fsdp, sizes),
                 _axes_if_div(core[1], tp, sizes))
    if name == "router":                    # (d, E)
        return P(*lead, _axes_if_div(core[0], fsdp, sizes), None)
    if nd == 3:                             # MoE expert weights (E, x, y)
        if name in _REDUCE_BACK:            # (E, f, d)
            return P(*lead, None, _axes_if_div(core[1], tp, sizes),
                     _axes_if_div(core[2], fsdp, sizes))
        return P(*lead, None, _axes_if_div(core[1], fsdp, sizes),
                 _axes_if_div(core[2], tp, sizes))
    if nd == 2:
        if name == "conv_w":                # (4, dr)
            return P(*lead, None, _axes_if_div(core[1], tp, sizes))
        if name in _REDUCE_BACK:
            return P(*lead, _axes_if_div(core[0], tp, sizes),
                     _axes_if_div(core[1], fsdp, sizes))
        return P(*lead, _axes_if_div(core[0], fsdp, sizes),
                 _axes_if_div(core[1], tp, sizes))
    return P(*lead, *(None,) * nd)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for part in path:
        if hasattr(part, "key"):
            names.append(str(part.key))
        elif hasattr(part, "idx"):
            names.append(str(part.idx))
        elif hasattr(part, "name"):
            names.append(str(part.name))
    return tuple(names)


def param_pspecs(params_shape, mesh, use_fsdp: bool = True) -> Any:
    """PartitionSpec pytree for a model param tree (works on eval_shape
    output — ShapeDtypeStructs — or concrete arrays).

    use_fsdp=False drops the data-axis factor (TP-only): used as the
    pre-gather target spec when cfg.pregather is on."""
    from repro.launch.mesh import dp_axes, tp_axis, mesh_axis_sizes
    fsdp = dp_axes(mesh) if use_fsdp else ()
    tp = tp_axis(mesh)
    sizes = mesh_axis_sizes(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        stacked = any(n in _STACKED for n in names[:-1])
        return _param_rule(name, leaf.shape, stacked and len(leaf.shape) > 1,
                           fsdp, tp, sizes)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def state_pspecs(state_shape, mesh, zero1: bool = False) -> Any:
    """TrainState(params, opt{m,v,step}).

    Default (ZeRO-3-flavoured): params AND moments 2D-sharded (fsdp x tp).
    zero1=True: params/grads TP-only — every contraction is device-local
    (no data-axis partial-sum all-reduces of activation-sized tensors) —
    while the f32 moments stay fully 2D-sharded; the optimizer update
    reduce-scatters grads and all-gathers fresh params ONCE per step.
    """
    from repro.train.steps import TrainState
    params_spec = param_pspecs(state_shape.params, mesh,
                               use_fsdp=not zero1)
    return TrainState(
        params=params_spec,
        opt={"m": param_pspecs(state_shape.opt["m"], mesh),
             "v": param_pspecs(state_shape.opt["v"], mesh),
             "step": P()})


def batch_pspecs(batch_shape, mesh) -> Any:
    from repro.launch.mesh import dp_axes, mesh_axis_sizes
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)

    def rule(path, leaf):
        b = _axes_if_div(leaf.shape[0], dp, sizes)
        return P(b, *(None,) * (len(leaf.shape) - 1))

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_pspecs(cache_shape, mesh) -> Any:
    """KV caches: (L, B, T, H, hd) -> P(None, dp, tp-on-T, None, None);
    recurrent states: batch over dp, width over tp."""
    from repro.launch.mesh import dp_axes, tp_axis, mesh_axis_sizes
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    sizes = mesh_axis_sizes(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        s = leaf.shape
        if name == "pos":
            return P()
        if name in ("k", "v", "xk", "xv"):       # (L,B,T,H,hd)
            return P(None, _axes_if_div(s[1], dp, sizes),
                     _axes_if_div(s[2], tp, sizes), None, None)
        if name == "s":                          # (L,B,H,dk,dv)
            return P(None, _axes_if_div(s[1], dp, sizes),
                     _axes_if_div(s[2], tp, sizes), None, None)
        if name in ("tm", "cm", "h"):            # (L,B,d)
            return P(None, _axes_if_div(s[1], dp, sizes),
                     _axes_if_div(s[2], tp, sizes))
        if name == "conv":                       # (L,B,3,d)
            return P(None, _axes_if_div(s[1], dp, sizes), None,
                     _axes_if_div(s[3], tp, sizes))
        return P(*(None,) * len(s))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)

"""Distributed FWHT: mesh-collective butterfly (DESIGN.md §3).

The paper parallelizes H with pthreads (11x on 16 threads); at cluster
scale the transform rows are sharded over the mesh, so we use the Kronecker
factorization H_n = H_dev (x) H_local:

  1. local FWHT on each shard's rows (Pallas kernel on TPU),
  2. log2(ndev) butterfly stages across devices via `jax.lax.ppermute`
     (each stage: exchange the full local block with the XOR-partner and
     combine +/-).

Stage k moves n/ndev * c elements per device — total collective traffic
log2(ndev) * n * c / ndev per device, the classic hypercube FWHT schedule.
This is exactly what the one-pass sketch needs to precondition a
row-sharded kernel stripe without gathering it.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.sketch import fwht as _fwht_ref


def butterfly_stages(xl: jnp.ndarray, axis: str, ndev: int) -> jnp.ndarray:
    """H_dev butterfly across devices, inside a shard_map body.

    xl is one device's (n/ndev, ...) row slab after its LOCAL
    (unnormalized) FWHT; log2(ndev) ppermute stages exchange the full
    slab with the XOR-partner and combine +/-. Shared by
    `distributed_fwht` and the sharded fit engine (distributed/fit.py),
    which inlines the transform into its per-block update body.
    """
    idx = jax.lax.axis_index(axis)
    h = 1
    while h < ndev:
        perm = [(i, i ^ h) for i in range(ndev)]
        other = jax.lax.ppermute(xl, axis, perm=perm)
        low = (idx & h) == 0
        xl = jnp.where(low, xl + other, other - xl)
        h *= 2
    return xl


def distributed_fwht(x: jnp.ndarray, mesh, axis: str = "data",
                     normalize: bool = True,
                     local_fwht: Optional[Callable] = None) -> jnp.ndarray:
    """FWHT along axis 0 of (n, c), rows sharded P(axis, None) on `mesh`.

    n and the axis size must be powers of two. `local_fwht` defaults to the
    pure-jnp FWHT; pass repro.kernels.fwht_pallas on TPU.
    """
    n = x.shape[0]
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if n & (n - 1) or ndev & (ndev - 1):
        raise ValueError(f"n={n} and axis size={ndev} must be powers of two")
    lf = local_fwht or (lambda v: _fwht_ref(v, normalize=False))

    def body(xl):
        # xl: (n/ndev, c) local block. Step 1: H_local.
        xl = lf(xl)
        # Step 2: H_dev butterfly across devices.
        xl = butterfly_stages(xl, axis, ndev)
        if normalize:
            xl = xl / jnp.sqrt(jnp.asarray(n, xl.dtype))
        return xl

    spec = P(axis, *(None,) * (x.ndim - 1))
    # Every mesh axis other than `axis` sees replicated data.
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)(x)

from repro.distributed.sharding import (param_pspecs, batch_pspecs,
                                        cache_pspecs, state_pspecs,
                                        maybe_shard, activation_sharding)
__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "state_pspecs",
           "maybe_shard", "activation_sharding"]

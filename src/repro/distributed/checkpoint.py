"""Sharded checkpointing: save/restore with resharding, async writes,
atomic commits, retention. The restart path of the fault-tolerance story
(distributed/fault.py) builds on restore-with-resharding: a checkpoint
written on one mesh restores onto any other mesh (elastic re-mesh).

Layout:
  <dir>/step_<N>.tmp/      while writing
  <dir>/step_<N>/          after atomic rename (os.replace)
      manifest.json        treedef, shapes, dtypes, step, wall time
      leaf_<i>.npy         one file per pytree leaf (device_get'ed)
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> List[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    blocking: bool = True) -> str:
    """Write `state` (any pytree of arrays) atomically. Returns final path.

    blocking=False snapshots to host memory synchronously (cheap) and
    writes files on a daemon thread (compute continues) — the standard
    async-checkpoint pattern.
    """
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step}.tmp"
    final = base / f"step_{step}"
    leaves, treedef = jax.tree.flatten(state)
    host_leaves = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    manifest = {
        "step": step,
        "time": time.time(),
        "paths": _tree_paths(state),
        "shapes": [list(leaf.shape) for leaf in host_leaves],
        "dtypes": [str(leaf.dtype) for leaf in host_leaves],
        "treedef": str(treedef),
    }

    def write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, leaf in enumerate(host_leaves):
            np.save(tmp / f"leaf_{i}.npy", leaf)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _ASYNC_THREADS.append(t)
    return str(final)


_ASYNC_THREADS: List[threading.Thread] = []


def wait_for_async_saves():
    for t in _ASYNC_THREADS:
        t.join()
    _ASYNC_THREADS.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for p in base.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                not p.name.endswith(".tmp") and \
                (p / "manifest.json").exists():
            steps.append(int(p.name[5:]))
    return max(steps) if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    """Manifest (paths/shapes/dtypes) of a checkpoint without loading leaves.

    Lets callers that only persisted a flat dict of arrays (e.g. the
    repro.serve FittedModel artifact) rebuild a `state_like` skeleton for
    restore_checkpoint from the checkpoint itself.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = pathlib.Path(ckpt_dir) / f"step_{step}"
    return json.loads((path / "manifest.json").read_text())


def restore_checkpoint(ckpt_dir: str, state_like: Any,
                       step: Optional[int] = None, mesh=None,
                       pspecs: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of `state_like`.

    With (mesh, pspecs) the leaves are device_put with NamedShardings —
    this is how a checkpoint written on a 512-chip mesh restores onto a
    shrunken mesh after failures (elastic re-mesh).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves_like, treedef = jax.tree.flatten(state_like)
    n = len(manifest["shapes"])
    if n != len(leaves_like):
        raise ValueError(f"checkpoint has {n} leaves, expected "
                         f"{len(leaves_like)}")
    out = []
    spec_leaves = (jax.tree.flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))[0]
        if pspecs is not None else [None] * n)
    for i, (like, spec) in enumerate(zip(leaves_like, spec_leaves)):
        arr = np.load(path / f"leaf_{i}.npy")
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != "
                             f"{like.shape}")
        a = jnp.asarray(arr, dtype=like.dtype)
        if mesh is not None and spec is not None:
            a = jax.device_put(a, NamedSharding(mesh, spec))
        out.append(a)
    return jax.tree.unflatten(treedef, out), step


class CheckpointManager:
    """Interval + retention policy around save/restore."""

    def __init__(self, ckpt_dir: str, save_every: int = 100,
                 keep: int = 3, async_saves: bool = True):
        self.dir = ckpt_dir
        self.save_every = save_every
        self.keep = keep
        self.async_saves = async_saves

    def maybe_save(self, step: int, state: Any) -> Optional[str]:
        if step % self.save_every:
            return None
        path = save_checkpoint(self.dir, step, state,
                               blocking=not self.async_saves)
        self._gc()
        return path

    def _gc(self):
        base = pathlib.Path(self.dir)
        steps = sorted(int(p.name[5:]) for p in base.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(base / f"step_{s}", ignore_errors=True)

    def restore_latest(self, state_like, mesh=None, pspecs=None):
        return restore_checkpoint(self.dir, state_like, mesh=mesh,
                                  pspecs=pspecs)

"""Communication-avoiding mesh-sharded one-pass fit (training-side mesh).

The serving stack sharded its hot loop long ago (extend.ShardedExtender);
this module does the same for TRAINING: each device owns an n/d row-slab
of the padded sample space, and every block update of the streaming
sketch accumulator (stream/accumulate.py) runs as one jitted shard_map in
which a device only ever touches its own slab:

    Kc_local = kappa(X_slab, C)                 (L, b)  local gram stripe
    new rows = Omega^T pad(Kc): local (masked, sign-scaled) FWHT +
               butterfly_stages (distributed/dfwht.py) + one psum of the
               gathered (r', b) sampled rows — the ONLY sketch collective
    cross    = Kc_local @ Omega[q:q+b]          (L, r') purely local
    norms    = one psum of the (b,) masked column sums

Communication per block is r'*b + b floats — independent of n, the
paper's point restated for the fit path. The per-stripe psum and the
cross-term matmul are independent ops inside one jitted body, so XLA
overlaps the collective with the next contraction's compute.

Bit-identity contract: the DEFAULT path reproduces the single-host
update value-for-value (tests/test_sharded_fit.py pins 1-device
bit-identity; multi-device parity is fp-tolerance, tests/fit_dist_checks)
because every step is either the same arithmetic in the same order
(mask-then-sign matches the canonical zero-pad-then-sign, the local
FWHT + butterfly is the canonical normalized FWHT's Kronecker
factorization, zero-appended reductions are bit-neutral) or exact data
movement (gathers, masked scatters, psum over the slab partition). The
FUSED path (policy.fit_fused -> kernels/fit_sketch) instead materializes
the Omega row slab and contracts on the MXU — fp-tolerance parity, same
trade the fused serving stripe makes.

Eigendecomposition stays single-host: `eig()` gathers the tiny (cap, r')
sketch — the whole point of sketching is that this is the only thing
worth gathering — and runs the canonical Alg. 1 core, bit-identical by
construction.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.kernels_fn import KernelFn
from repro.core.sketch import SRHT, fwht
from repro.distributed.dfwht import butterfly_stages


def srht_rows_dynamic(sketch: SRHT, start, b: int) -> jnp.ndarray:
    """Rows [start, start+b) of the implicit Omega with a TRACED start.

    Same Sylvester entry formula as core.sketch.srht_rows (popcount is
    exact integer arithmetic, so the values are identical); the static
    variant can't be used inside the one-executable-per-block-width fit
    path, where the block offset q is a traced scalar.
    """
    start = jnp.asarray(start, jnp.int32)
    idx = start + jnp.arange(b, dtype=jnp.int32)
    bits = jnp.bitwise_and(idx[:, None], sketch.rows.astype(jnp.int32)[None, :])
    parity = jax.lax.population_count(bits) & 1
    scale = 1.0 / jnp.sqrt(jnp.asarray(sketch.n_pad, jnp.float32))
    vals = jnp.where(parity == 1, -scale, scale)
    signs = jax.lax.dynamic_slice(sketch.signs, (start,), (b,))
    return signs[:, None] * vals


def _omega_rows_local(gids: jnp.ndarray, rows: jnp.ndarray, n_pad: int,
                      signs_l: jnp.ndarray) -> jnp.ndarray:
    """Materialize a device's own (L, r') slab of the implicit Omega —
    the fused path's replacement for the distributed FWHT."""
    bits = jnp.bitwise_and(gids[:, None], rows[None, :])
    parity = jax.lax.population_count(bits) & 1
    scale = 1.0 / jnp.sqrt(jnp.asarray(n_pad, jnp.float32))
    vals = jnp.where(parity == 1, -scale, scale)
    return signs_l[:, None] * vals


class ShardedFitEngine:
    """Mesh-sharded executor for SketchAccumulator block updates.

    Owns the device placement: a persistent (p, N) column-sharded data
    buffer (N = the padded row space: SRHT's n_pad, or capacity rounded
    up to a shard multiple for Gaussian), the sharded sketch constants
    (signs slab / Omega slab), and one jitted shard_map executable per
    block width b — the block offset q is traced, so chunked ingest with
    ragged tails compiles a bounded handful of executables.

    The accumulator keeps its logical (cap, r') view of W/row_norms2;
    `pad_rows`/`pad_vec` place them row-sharded once and `gather` pulls
    the [:cap] slice back to host only at eig/persist boundaries.
    """

    def __init__(self, mesh, axis: str, sketch, kernel: KernelFn, p: int,
                 *, fit_fused: bool = False, interpret: bool = False,
                 kernel_statics: Optional[Tuple[str, float, int]] = None,
                 local_fwht: Optional[Callable] = None):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}; "
                             f"have {mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self.shards = d = dict(mesh.shape)[axis]
        self.sketch = sketch
        self.kernel = kernel
        self.p = int(p)
        self.fit_fused = bool(fit_fused)
        self.interpret = bool(interpret)
        self.kernel_statics = kernel_statics
        if fit_fused and kernel_statics is None:
            raise ValueError(
                "fit_fused needs the kernel statics (kind, gamma, degree) "
                "for the Pallas fit_sketch kernel — fit through "
                "KernelKMeans (which passes them from the spec) or give "
                "SketchAccumulator kernel_statics=")
        self._is_srht = isinstance(sketch, SRHT)
        if self._is_srht:
            self.capacity = int(sketch.n)
            N = int(sketch.n_pad)
            if d & (d - 1):
                raise ValueError(f"sharded SRHT fit needs a power-of-two "
                                 f"device count, got {d}")
            if d > N:
                raise ValueError(f"{d} devices cannot shard the "
                                 f"{N}-row padded sample space")
        else:
            self.capacity = int(sketch.omega.shape[0])
            N = -(-self.capacity // d) * d
        self.N = N
        self.L = N // d
        self._local_fwht = local_fwht or (
            lambda v: fwht(v, normalize=False))
        self._row_sh = NamedSharding(mesh, P(axis))
        self._mat_sh = NamedSharding(mesh, P(axis, None))
        self._col_sh = NamedSharding(mesh, P(None, axis))
        if self._is_srht:
            self._aux = jax.device_put(sketch.signs, self._row_sh)
        else:
            omega_pad = jnp.zeros((N, sketch.omega.shape[1]),
                                  jnp.float32).at[:self.capacity].set(
                                      sketch.omega)
            self._aux = jax.device_put(omega_pad, self._mat_sh)
        self._Xbuf = jax.device_put(jnp.zeros((self.p, N), jnp.float32),
                                    self._col_sh)
        self._n_cols = 0
        self._set_cache: Dict[int, Callable] = {}
        self._apply_cache: Dict[int, Callable] = {}
        # Stand-alone executables for the norm-ledger update (see
        # _build_apply for why they cannot live inside the body).
        self._square_fn = jax.jit(lambda K: K * K)
        self._rowsum_fns: Dict[int, Callable] = {}
        self._colsum_fns: Dict[int, Callable] = {}
        self._merge_fns: Dict[int, Callable] = {}

    # -- data placement ---------------------------------------------------

    def ingest(self, cols: jnp.ndarray) -> None:
        """Append columns to the sharded data buffer (one executable per
        distinct chunk width; the start offset is traced)."""
        cols = jnp.asarray(cols, jnp.float32)
        w = int(cols.shape[1])
        if self._n_cols + w > self.capacity:
            raise ValueError(f"sharded buffer capacity {self.capacity} "
                             f"exceeded at {self._n_cols} + {w} columns")
        fn = self._set_cache.get(w)
        if fn is None:
            fn = jax.jit(
                lambda X, c, s: jax.lax.dynamic_update_slice(X, c, (0, s)),
                out_shardings=self._col_sh)
            self._set_cache[w] = fn
        self._Xbuf = fn(self._Xbuf, cols, jnp.asarray(self._n_cols,
                                                      jnp.int32))
        self._n_cols += w

    def pad_rows(self, W: jnp.ndarray) -> jnp.ndarray:
        """(cap, r') -> row-sharded (N, r')."""
        Wp = jnp.zeros((self.N, W.shape[1]), jnp.float32)
        Wp = Wp.at[:W.shape[0]].set(jnp.asarray(W, jnp.float32))
        return jax.device_put(Wp, self._mat_sh)

    def pad_vec(self, v: jnp.ndarray) -> jnp.ndarray:
        """(cap,) -> row-sharded (N,)."""
        vp = jnp.zeros((self.N,), jnp.float32)
        vp = vp.at[:v.shape[0]].set(jnp.asarray(v, jnp.float32))
        return jax.device_put(vp, self._row_sh)

    def gather(self, arr: jnp.ndarray) -> jnp.ndarray:
        """Pull the logical [:cap] rows back to a replicated host array —
        the eig/persist boundary, the only time sketch state moves."""
        return jnp.asarray(np.asarray(arr)[:self.capacity])

    # -- the sharded block update -----------------------------------------

    def apply(self, W_pad: jnp.ndarray, rn_pad: jnp.ndarray, q: int,
              b: int):
        """Fold columns [q, q+b) into the padded sharded (W, row_norms2);
        pure in its array arguments, like SketchAccumulator._apply."""
        fn = self._apply_cache.get(b)
        if fn is None:
            fn = self._build_apply(int(b))
            self._apply_cache[b] = fn
        return fn(self._Xbuf, W_pad, rn_pad, self._aux,
                  jnp.asarray(q, jnp.int32))

    def _build_apply(self, b: int) -> Callable:
        mesh, ax, d = self.mesh, self.axis, self.shards
        L, N = self.L, self.N
        kern = self.kernel
        srht = self._is_srht
        sketch = self.sketch
        fused, interp = self.fit_fused, self.interpret
        statics = self.kernel_statics
        local_fwht = self._local_fwht
        if srht:
            rows_const = jnp.asarray(sketch.rows, jnp.int32)

        def body(xl, wl, rnl, aux_l, c, q, cross):
            # xl (p, L) data slab, wl (L, r'), rnl (L,), aux_l the signs
            # slab (L,) [srht] or Omega slab (L, r') [gaussian],
            # c (p, b) and cross (b, r') replicated, q traced scalar.
            dev = jax.lax.axis_index(ax)
            gids = dev * L + jax.lax.iota(jnp.int32, L)
            valid = gids < q + b               # border rows [0, q+b)
            applied = gids < q                 # already-folded rows
            isnew = valid & jnp.logical_not(applied)
            if fused:
                kind, gamma, degree = statics
                from repro.kernels.fit_sketch.ops import fit_sketch_pallas
                if srht:
                    O_l = _omega_rows_local(gids, rows_const, N, aux_l)
                else:
                    O_l = aux_l
                O_l = jnp.where(valid[:, None], O_l, 0.0)
                V = jnp.zeros((8, L), jnp.float32).at[0].set(
                    valid.astype(jnp.float32))
                accp, delta, rn_rows, rn_cols = fit_sketch_pallas(
                    xl, O_l, c, cross, V, kind=kind, gamma=gamma,
                    degree=degree, interpret=interp)
                new_rows = jax.lax.psum(accp, ax)          # (b, r')
                colsum = jax.lax.psum(rn_cols, ax)         # (b,)
            else:
                # optimization_barrier: materialize the gram stripe once.
                # Without it XLA clones the cheap producer chain into
                # each consumer fusion, and the clone feeding the norm
                # reduction picks up FMAs the eager canonical path (one
                # executable per op) never emits — a 1-ulp break in the
                # bit-identity contract.
                Kl = jax.lax.optimization_barrier(kern(xl, c))  # (L, b)
                Kv = jnp.where(valid[:, None], Kl, 0.0)
                if srht:
                    # Canonical order: zero-pad (the mask), THEN signs —
                    # matches srht_apply_t on the zero-padded border.
                    Ml = Kv * aux_l[:, None]
                    Fl = local_fwht(Ml)
                    Fl = butterfly_stages(Fl, ax, d)
                    Fl = Fl / jnp.sqrt(jnp.asarray(N, Fl.dtype))
                    base = dev * L
                    inloc = (rows_const >= base) & (rows_const < base + L)
                    loc = jnp.clip(rows_const - base, 0, L - 1)
                    sel = jnp.where(inloc[:, None], Fl[loc], 0.0)
                    wt = jax.lax.psum(sel, ax)             # (r', b)
                    new_rows = wt.T
                else:
                    part = Kv.T @ aux_l                    # (b, r')
                    new_rows = jax.lax.psum(part, ax)
                # The cross-term matmul is independent of the psum above:
                # XLA overlaps the collective with this compute.
                delta = Kl @ cross                         # (L, r')
                colsum = rn_rows = None
            nidx = jnp.clip(gids - q, 0, b - 1)
            wl = jnp.where(applied[:, None], wl + delta, wl)
            wl = jnp.where(isnew[:, None], new_rows[nidx], wl)
            if fused:
                rnl = jnp.where(applied, rnl + rn_rows, rnl)
                rnl = jnp.where(isnew, colsum[nidx], rnl)
                return wl, rnl
            # Default path: the norm ledger is NOT updated here. The CPU
            # fusion emitter folds the square into the in-body
            # reductions as FMAs (optimization_barrier does not stop
            # it), and the column reduction's tree shape depends on its
            # length — both break bit-identity with the canonical eager
            # square-then-reduce executables. So the masked stripe is
            # returned (sharded) and the ledger update runs in the same
            # stand-alone square / reduce / merge executables the
            # canonical path dispatches.
            return wl, Kv

        @jax.jit
        def apply_fn(Xbuf, W, rn, aux, q):
            c = jax.lax.dynamic_slice_in_dim(Xbuf, q, b, axis=1)
            if srht:
                cross = srht_rows_dynamic(sketch, q, b)
            else:
                cross = jax.lax.dynamic_slice_in_dim(sketch.omega, q, b,
                                                     axis=0)
            aux_spec = P(ax) if srht else P(ax, None)
            out2 = P(ax) if fused else P(ax, None)
            return shard_map(
                body, mesh=mesh,
                in_specs=(P(None, ax), P(ax, None), P(ax), aux_spec,
                          P(None, None), P(), P(None, None)),
                out_specs=(P(ax, None), out2),
                check_rep=False)(Xbuf, W, rn, aux, c, q, cross)

        if fused:
            return apply_fn

        square = self._square_fn
        rowsum = self._rowsum_fns.setdefault(
            b, jax.jit(lambda A: jnp.sum(A, axis=1)))
        colsum_fn = self._colsum_fns.setdefault(
            b, jax.jit(lambda A: jnp.sum(A, axis=0)))
        merge = self._merge_fns.setdefault(b, self._build_merge(b))

        def apply_default(Xbuf, W, rn, aux, q):
            wl, Kv = apply_fn(Xbuf, W, rn, aux, q)
            # Norm-ledger update as stand-alone executables (square,
            # minor-axis reduce for applied rows, shape-stable column
            # reduce for new rows, masked merge) — the same
            # materialize-then-reduce sequence the canonical eager path
            # runs, hence the same bits on one device. On a multi-device
            # mesh the column reduce becomes partial-sums + all-reduce
            # under GSPMD (fp-tolerance parity there).
            K2 = square(Kv)
            return wl, merge(rn, rowsum(K2), colsum_fn(K2),
                             jnp.asarray(q, jnp.int32))

        return apply_default

    def _build_merge(self, b: int) -> Callable:
        gids = jnp.arange(self.N, dtype=jnp.int32)

        def merge(rn, inc, colsum, q):
            applied = gids < q
            isnew = (gids >= q) & (gids < q + b)
            nidx = jnp.clip(gids - q, 0, b - 1)
            rn = jnp.where(applied, rn + inc, rn)
            return jnp.where(isnew, colsum[nidx], rn)

        return jax.jit(merge)

"""Distributed one-pass kernel K-means: the paper's Alg. 1 at cluster scale.

Data X (p, n) is column-sharded over the mesh's data axis; the kernel
matrix K never exists, not even a full column stripe on one device:

  sketch     stripe rows are sharded; D is applied locally, H via the
             ppermute-butterfly distributed FWHT, R^T via a masked
             scatter + psum (r' rows are tiny);
  basis      Q from W (n x r', row-sharded) by Cholesky-QR:
             G = W^T W (psum, r' x r'), Q = W G^{-1/2} — no gather of W;
  core       B (Q^T Omega) = Q^T W solved on r' x r' replicated matrices;
  embed      Y = Sigma^{1/2} V^T Q^T stays column-sharded (r x n_local);
  cluster    distributed Lloyd: local assignment (the Pallas fused
             assign kernel on TPU), centroids via psum of (sums, counts).

Communication per stripe: log2(dp) * n/dp * b (butterfly) + r' * b (psum)
— versus gathering the stripe (n * b) for a centralized sketch. The
whole pipeline is the launch target of launch/cluster.py and the
"paper-representative" roofline cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.sketch import next_pow2
from repro.distributed.dfwht import distributed_fwht


class DistClusterResult(NamedTuple):
    labels: jnp.ndarray      # (n,) column-sharded like X
    Y: jnp.ndarray           # (r, n) column-sharded
    centroids: jnp.ndarray   # (k, r) replicated
    eigvals: jnp.ndarray     # (r,)


def _dp_size(mesh, axis):
    return dict(mesh.shape)[axis]


def distributed_sketch(kernel, X, mesh, signs, rows, axis="data",
                       block: int = 1024):
    """W = K Omega with K row/column-sharded stripes. X: (p, n) sharded
    P(None, axis). signs: (n_pad,), rows: (r',). Returns W (n, r') sharded
    P(axis, None)."""
    p, n = X.shape
    dp = _dp_size(mesh, axis)
    n_pad = signs.shape[0]
    r_prime = rows.shape[0]
    n_local = n // dp
    assert n % dp == 0 and n_pad % dp == 0

    # The distributed path requires pre-padded n == n_pad (pow2): callers
    # pad X with zero columns up front (zero columns of K are harmless —
    # D/R act trivially on them and K-means ignores them downstream).
    assert n == n_pad, "distributed path expects pre-padded n (pow2)"

    W = jnp.zeros((n, r_prime), jnp.float32)
    W = jax.device_put(W, NamedSharding(mesh, P(axis, None)))
    signs_sh = jax.device_put(signs, NamedSharding(mesh, P(axis)))

    scale = 1.0 / jnp.sqrt(jnp.asarray(n_pad, jnp.float32))

    def rt_gather(stripe_f):
        """R^T: pick global rows `rows` from a row-sharded (n, b) array."""
        def inner(sl):
            idx = jax.lax.axis_index(axis)
            base = idx * n_local
            # local contribution: rows in [base, base + n_local)
            rel = rows - base
            inb = (rel >= 0) & (rel < n_local)
            rel_safe = jnp.clip(rel, 0, n_local - 1)
            contrib = jnp.where(inb[:, None], sl[rel_safe], 0.0)
            return jax.lax.psum(contrib, axis)[None]   # (1, r', b)
        out = shard_map(inner, mesh=mesh, in_specs=P(axis, None),
                        out_specs=P(axis, None, None),
                        check_rep=False)(stripe_f)
        return out[0]                                    # (r', b)

    for start in range(0, n, block):
        b = min(block, n - start)
        xb = jax.lax.dynamic_slice_in_dim(X, start, b, axis=1)
        # Replicate the small (p, b) stripe seed.
        xb = jax.device_put(xb, NamedSharding(mesh, P(None, None)))

        # Stripe rows sharded: each shard holds kernel(X_local_cols, xb).
        def mk_stripe(xl, xbl):
            return kernel(xl, xbl)

        stripe = shard_map(mk_stripe, mesh=mesh,
                           in_specs=(P(None, axis), P(None, None)),
                           out_specs=P(axis, None),
                           check_rep=False)(X, xb)       # (n, b) row-shard
        stripe = stripe * signs_sh[:, None]
        stripe = distributed_fwht(stripe, mesh, axis, normalize=False)
        wt_block = rt_gather(stripe) * scale             # (r', b)
        W = jax.lax.dynamic_update_slice(W, wt_block.T, (start, 0))
    return W


def cholesky_qr(W, mesh, axis="data", eps: float = 1e-7):
    """Q with orthonormal columns spanning range(W), W (n, r') row-sharded.

    Cholesky-QR via the psum'd Gram matrix: G = W^T W (r' x r', tiny),
    Q_i = W v_i / sqrt(lambda_i). Rank-deficient W (e.g. an exactly
    low-rank kernel) keeps only the positive-eigenvalue columns — the
    truncation is decided eagerly (this is orchestration code, not a jit
    body), so Q has static shape (n, rank) per pipeline run.
    """
    import numpy as np

    def gram(wl):
        return jax.lax.psum(wl.T @ wl, axis)[None]

    G = shard_map(gram, mesh=mesh, in_specs=P(axis, None),
                  out_specs=P(axis, None, None), check_rep=False)(W)[0]
    evals, V = jnp.linalg.eigh(0.5 * (G + G.T))
    ev = np.asarray(evals)
    keep = ev > eps * max(float(ev.max()), 1e-30)
    idx = np.nonzero(keep)[0][::-1].copy()        # descending eigenvalues
    cols = (V[:, idx] / jnp.sqrt(evals[idx])[None, :])
    return W @ cols                               # (n, rank) row-sharded


def distributed_omega_t(M, mesh, signs, rows, axis="data"):
    """Omega^T M for row-sharded M (n, c): D, distributed H, R^T."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(signs.shape[0], jnp.float32))
    signs_sh = jax.device_put(signs, NamedSharding(mesh, P(axis)))
    Mh = distributed_fwht(M * signs_sh[:, None], mesh, axis,
                          normalize=False)
    n_local = M.shape[0] // _dp_size(mesh, axis)

    def inner(sl):
        idx = jax.lax.axis_index(axis)
        base = idx * n_local
        rel = rows - base
        inb = (rel >= 0) & (rel < n_local)
        contrib = jnp.where(inb[:, None], sl[jnp.clip(rel, 0, n_local - 1)],
                            0.0)
        return jax.lax.psum(contrib, axis)[None]

    out = shard_map(inner, mesh=mesh, in_specs=P(axis, None),
                    out_specs=P(axis, None, None), check_rep=False)(Mh)
    return out[0] * scale                  # (r', c)


def distributed_kmeans(Y, k, key, mesh, axis="data", n_iter: int = 20,
                       n_restarts: int = 10):
    """Lloyd on column-sharded Y (r, n): local assign, psum centroid update.

    Init: k random data columns per restart (gathering k columns is O(kr)
    — tiny); best-objective restart wins, mirroring the single-device
    implementation's semantics (full k-means++ D^2 sampling would need a
    distributed weighted draw per centroid; random-column restarts are the
    standard large-scale substitute).
    """
    r, n = Y.shape

    def step(C, yl):
        d2 = (jnp.sum(yl * yl, axis=0)[None, :]
              + jnp.sum(C * C, axis=1)[:, None] - 2.0 * (C @ yl))  # (k, nl)
        labels = jnp.argmin(d2, axis=0)
        onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)      # (nl, k)
        sums = jax.lax.psum(yl @ onehot, axis)                     # (r, k)
        counts = jax.lax.psum(jnp.sum(onehot, axis=0), axis)       # (k,)
        newC = jnp.where(counts[:, None] > 0,
                         sums.T / jnp.maximum(counts[:, None], 1.0), C)
        obj = jax.lax.psum(jnp.sum(jnp.min(d2, axis=0)), axis)
        return newC, labels, obj

    def run_one(C0):
        def body(yl, C0l):
            C = C0l

            def it(C, _):
                C, _, _ = step(C, yl)
                return C, None

            C, _ = jax.lax.scan(it, C, None, length=n_iter)
            C, labels, obj = step(C, yl)
            return (labels.astype(jnp.int32), C[None],
                    jnp.reshape(obj, (1,)))

        return shard_map(
            body, mesh=mesh, in_specs=(P(None, axis), P(None, None)),
            out_specs=(P(axis), P(axis, None, None), P(axis)),
            check_rep=False)(Y, C0)

    best = None
    for s in range(n_restarts):
        idx = jax.random.choice(jax.random.fold_in(key, s), n, (k,),
                                replace=False)
        C0 = jax.device_put(Y[:, idx].T,
                            NamedSharding(mesh, P(None, None)))
        labels, C, obj = run_one(C0)
        score = float(obj[0])
        if best is None or score < best[0]:
            best = (score, labels, C[0])
    return best[1], best[2], best[0]


def distributed_one_pass_kernel_kmeans(
        key, kernel, X, k: int, r: int, mesh, oversampling: int = 10,
        axis: str = "data", block: int = 1024,
        n_iter: int = 20) -> DistClusterResult:
    """Alg. 1 end-to-end on a mesh. X: (p, n) sharded P(None, axis);
    n must be a power of two (pad with zero columns upstream)."""
    p, n = X.shape
    r_prime = r + oversampling
    k1, k2 = jax.random.split(key)
    signs = jax.random.rademacher(k1, (next_pow2(n),), dtype=jnp.float32)
    rows = jax.random.choice(k2, next_pow2(n), (r_prime,), replace=False)

    W = distributed_sketch(kernel, X, mesh, signs, rows, axis, block)
    Q = cholesky_qr(W, mesh, axis)                       # (n, r') row-shard
    QtO = distributed_omega_t(Q, mesh, signs, rows, axis).T   # (r', r')
    # Q^T W: r' x r' via psum.
    def qtw(ql, wl):
        return jax.lax.psum(ql.T @ wl, axis)[None]
    QtW = shard_map(qtw, mesh=mesh, in_specs=(P(axis, None), P(axis, None)),
                    out_specs=P(axis, None, None), check_rep=False)(Q, W)[0]
    Bt, *_ = jnp.linalg.lstsq(QtO.T, QtW.T)
    B = 0.5 * (Bt + Bt.T)
    evals, V = jnp.linalg.eigh(B)
    evals = jnp.maximum(evals[::-1], 0.0)
    V = V[:, ::-1]
    # Y = Sigma^{1/2} V^T Q^T, column-sharded like X.
    proj = (jnp.sqrt(evals[:r])[:, None] * V[:, :r].T)   # (r, r')

    def embed(ql):
        return proj @ ql.T                               # (r, n_local)

    Y = shard_map(embed, mesh=mesh, in_specs=P(axis, None),
                  out_specs=P(None, axis), check_rep=False)(Q)
    labels, C, obj = distributed_kmeans(Y, k, key, mesh, axis, n_iter)
    return DistClusterResult(labels=labels, Y=Y, centroids=C,
                             eigvals=evals[:r])

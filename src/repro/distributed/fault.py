"""Fault tolerance: heartbeats, straggler detection, elastic re-mesh,
checkpoint/restart supervision.

This container has one CPU process, so host failure/preemption is
SIMULATED at the process level (injected exceptions, mock clocks); the
control-flow — detect -> shrink mesh -> restore resharded checkpoint ->
continue — is the same code a multi-host launcher drives, and is what the
tests exercise.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Tuple

import numpy as np


class HeartbeatMonitor:
    """Tracks last-seen times per host; hosts silent > timeout are dead."""

    def __init__(self, hosts: List[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last: Dict[str, float] = {h: clock() for h in hosts}

    def beat(self, host: str):
        self.last[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]

    def healthy_hosts(self) -> List[str]:
        dead = set(self.dead_hosts())
        return [h for h in self.last if h not in dead]


class StragglerTracker:
    """Flags hosts whose step times exceed `factor` x the fleet median.

    Mitigation hooks: (a) report for re-scheduling, (b) with microbatch
    gradient accumulation the supervisor can drop the slowest host's last
    microbatch (bounded staleness) — policy returned as an action string.
    """

    def __init__(self, factor: float = 2.0, window: int = 20):
        self.factor = factor
        self.window = window
        self.times: Dict[str, List[float]] = {}

    def record(self, host: str, step_time: float):
        self.times.setdefault(host, []).append(step_time)
        self.times[host] = self.times[host][-self.window:]

    def stragglers(self) -> List[str]:
        if not self.times:
            return []
        meds = {h: float(np.median(t)) for h, t in self.times.items()}
        fleet = float(np.median(list(meds.values())))
        return [h for h, m in meds.items() if m > self.factor * fleet]

    def action(self, host: str) -> str:
        return ("skip-last-microbatch" if host in self.stragglers()
                else "none")


def elastic_mesh(n_hosts_healthy: int, chips_per_host: int = 8,
                 model_parallel: int = 16):
    """Largest (data, model) mesh from surviving chips.

    Keeps the model axis fixed (weights must still fit) and shrinks the
    data axis to the largest power of two that the healthy chips support.
    Returns (shape, axis_names) — callers build it with jax.make_mesh once
    the runtime has been restarted on the surviving hosts.
    """
    chips = n_hosts_healthy * chips_per_host
    data = chips // model_parallel
    if data < 1:
        raise RuntimeError(f"not enough chips ({chips}) for model_parallel="
                           f"{model_parallel}")
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel), ("data", "model")


@dataclasses.dataclass
class RestartReport:
    restarts: int
    completed_steps: int
    remesh_events: List[Tuple[int, Tuple[int, ...]]]


class TrainSupervisor:
    """Run a step loop with checkpoint/restart and (simulated) elastic
    re-mesh. `step_fn(state, step) -> state` may raise HostFailure."""

    def __init__(self, ckpt_manager, state_like_fn: Callable[[], Any],
                 max_restarts: int = 10):
        self.ckpt = ckpt_manager
        self.state_like_fn = state_like_fn
        self.max_restarts = max_restarts

    def run(self, state, step_fn, n_steps: int, start_step: int = 0,
            mesh=None, pspecs=None) -> Tuple[Any, RestartReport]:
        restarts = 0
        remesh_events: List[Tuple[int, Tuple[int, ...]]] = []
        step = start_step
        while step < n_steps:
            try:
                state = step_fn(state, step)
                step += 1
                self.ckpt.maybe_save(step, state)
            except HostFailure as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                # Recover: rebuild mesh from survivors, restore latest.
                shape, axes = elastic_mesh(e.healthy_hosts,
                                           e.chips_per_host,
                                           e.model_parallel)
                remesh_events.append((step, shape))
                state, step = self.ckpt.restore_latest(
                    self.state_like_fn(), mesh=mesh, pspecs=pspecs)
        return state, RestartReport(restarts, step, remesh_events)


class HostFailure(RuntimeError):
    def __init__(self, msg: str, healthy_hosts: int = 31,
                 chips_per_host: int = 8, model_parallel: int = 16):
        super().__init__(msg)
        self.healthy_hosts = healthy_hosts
        self.chips_per_host = chips_per_host
        self.model_parallel = model_parallel

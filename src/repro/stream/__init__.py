"""repro.stream: fit as a living service.

Four layers over the one-pass sketch (see ROADMAP / ISSUE 6):

    accumulate  SketchAccumulator — exact incremental W = K Omega
                accumulation per data chunk; the engine under both
                one-shot `fit` and `KernelKMeans.partial_fit`
    minibatch   Sculley-style minibatch K-means in the rank-r embedding
                space, for re-eigs at huge n
    drift       DriftMonitor — streaming kernel-approximation-error and
                assignment-shift estimators over sampled live traffic
    retrain     RetrainWorker — drift trigger -> refit from accumulated
                state -> VersionStore.publish -> ModelRegistry.swap
"""
from repro.stream.accumulate import SketchAccumulator
from repro.stream.minibatch import MiniBatchResult, minibatch_kmeans
from repro.stream.drift import DriftMonitor, DriftReport
from repro.stream.retrain import RetrainReport, RetrainWorker

__all__ = [
    "DriftMonitor",
    "DriftReport",
    "MiniBatchResult",
    "RetrainReport",
    "RetrainWorker",
    "SketchAccumulator",
    "minibatch_kmeans",
]

"""Incremental one-pass sketch accumulation: fit as a stream of chunks.

The paper's sketch W = K Omega is a sum over entries of K, so it admits
exact incremental accumulation: when a new block of data points C arrives
after q applied points, the only kernel values that exist beyond the
already-applied principal block are the symmetric border

    Kc = kappa([X_applied | C], C)          (q + b, b)

and the sketch update splits along it:

    W[q:q+b]  = (Omega^T pad(Kc)).T         new rows, one FWHT over the
                                            zero-padded border columns
    W[:q]    += Kc[:q] @ Omega[q:q+b]       symmetric cross-term into the
                                            old rows, via the materialized
                                            Omega row slice (srht_rows)

Row norms of K accumulate the same way, giving a streaming estimate of
||K||_F^2 (and hence of the approximation error) for free.

Chunk-size invariance — the contract `KernelKMeans.partial_fit` builds
on — comes from BLOCK-GRANULAR STAGING: `add()` buffers incoming columns
and applies updates only in exact `block`-wide slices; the ragged tail is
applied on a COPY at `eig()` time, so the canonical update sequence never
depends on how callers chunked their data. One-shot `fit` routes through
this same accumulator (repro.api.backends), so a chunked partial_fit
over a full pass is bit-identical to fit at the re-eig boundary.

The sketch is built at a fixed `capacity` (SRHT pads to the next power of
two of the capacity, not of the data seen so far), so the test matrix —
and therefore the fit — is a pure function of (key, capacity) no matter
when data arrives.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.kernels_fn import KernelFn
from repro.core.sketch import (GaussianSketch, LowRankEig, SRHT,
                               make_gaussian, make_srht, one_pass_core,
                               srht_apply_t, srht_rows)

Sketch = Union[SRHT, GaussianSketch]


class SketchAccumulator:
    """Streaming accumulation of the one-pass sketch state.

    key:         PRNGKey the test matrix is drawn from (same key +
                 capacity => same sketch, whatever the chunking)
    kernel:      KernelFn kappa(X, Z)
    capacity:    maximum total columns this accumulator will ever hold;
                 the SRHT/Gaussian test matrix is sized to it up front
    r:           target rank of `eig()`
    oversampling/block/sketch_type/fwht_fn/truncate_basis: exactly the
                 one-pass backend knobs (repro.api.backends)
    policy:      optional serve.ComputePolicy. policy.mesh routes every
                 block update through the mesh-sharded fit engine
                 (distributed/fit.py, bit-identical on one device);
                 policy.fit_fused routes it through the fused
                 fit_sketch Pallas kernel (fp-tolerance parity).
    kernel_statics: (kind, gamma, degree) for the fused kernel; required
                 whenever fit_fused resolves on.

    add(X_chunk) stages columns and applies full-block updates;
    eig() applies the staged tail on a copy and runs Alg. 1 lines 3-6
    on the effective sketch; state_arrays() exports the persistable
    state (FittedModel stream_* leaves) and from_model() resumes from it.
    """

    def __init__(self, key: jax.Array, kernel: KernelFn, capacity: int,
                 r: int, *, oversampling: int = 10, block: int = 512,
                 sketch_type: str = "srht",
                 fwht_fn: Optional[Callable] = None,
                 truncate_basis: bool = False,
                 policy=None, kernel_statics=None):
        capacity = int(capacity)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        r_prime = int(r) + int(oversampling)
        if sketch_type == "srht":
            sketch: Sketch = make_srht(key, capacity, r_prime)
        elif sketch_type == "gaussian":
            sketch = make_gaussian(key, capacity, r_prime)
        else:
            raise ValueError(f"unknown sketch_type {sketch_type!r}")
        self._bind(kernel, int(r), sketch,
                   jnp.zeros((capacity, r_prime), jnp.float32),
                   jnp.zeros((capacity,), jnp.float32), 0, None,
                   block=block, truncate_basis=truncate_basis,
                   fwht_fn=fwht_fn, policy=policy,
                   kernel_statics=kernel_statics)

    def _bind(self, kernel, r, sketch, W, row_norms2, n_applied, X, *,
              block, truncate_basis, fwht_fn, policy=None,
              kernel_statics=None) -> None:
        self.kernel = kernel
        self.r = int(r)
        self.sketch = sketch
        self.W = W
        self.row_norms2 = row_norms2
        self.n_applied = int(n_applied)
        self._X = X
        self.block = int(block)
        self.truncate_basis = bool(truncate_basis)
        self.fwht_fn = fwht_fn
        self.reeigs = 0
        self.last_fro2 = 0.0
        self.last_approx_err = 0.0
        self.policy = policy
        self.kernel_statics = kernel_statics
        self._engine = None
        if policy is not None:
            self._fit_fused, self._fit_interpret = policy.resolve_fit()
        else:
            self._fit_fused, self._fit_interpret = False, False
        if self._fit_fused and kernel_statics is None:
            raise ValueError(
                "fit_fused needs the kernel statics (kind, gamma, degree) "
                "for the Pallas fit_sketch kernel — fit through "
                "KernelKMeans (which passes them from the spec) or give "
                "SketchAccumulator kernel_statics=")
        if X is not None:
            self._ensure_engine(int(X.shape[0]))

    def _ensure_engine(self, p: int) -> None:
        """Build the mesh-sharded fit engine on first sight of data (the
        row count p is not known before then): pads the current sketch
        state row-sharded and loads any existing columns into the
        sharded data buffer. From then on self.W / self.row_norms2 hold
        the PADDED sharded (N, r') / (N,) arrays; eig() and
        state_arrays() gather the logical [:capacity] rows back."""
        if (self._engine is not None or self.policy is None
                or self.policy.mesh is None):
            return
        from repro.distributed.fit import ShardedFitEngine

        self._engine = ShardedFitEngine(
            self.policy.mesh, self.policy.mesh_axis, self.sketch,
            self.kernel, p, fit_fused=self._fit_fused,
            interpret=self._fit_interpret,
            kernel_statics=self.kernel_statics)
        self.W = self._engine.pad_rows(self.W)
        self.row_norms2 = self._engine.pad_vec(self.row_norms2)
        if self._X is not None:
            self._engine.ingest(self._X)

    # -- resume ----------------------------------------------------------

    @classmethod
    def from_arrays(cls, kernel: KernelFn, r: int, sketch: Sketch,
                    W: jnp.ndarray, row_norms2: jnp.ndarray,
                    n_applied: int, X: Optional[jnp.ndarray], *,
                    block: int = 512, truncate_basis: bool = False,
                    fwht_fn: Optional[Callable] = None,
                    policy=None, kernel_statics=None
                    ) -> "SketchAccumulator":
        """Rebuild an accumulator around existing state (see from_model)."""
        acc = cls.__new__(cls)
        acc._bind(kernel, r, sketch, jnp.asarray(W, jnp.float32),
                  jnp.asarray(row_norms2, jnp.float32), n_applied,
                  None if X is None else jnp.asarray(X, jnp.float32),
                  block=block, truncate_basis=truncate_basis,
                  fwht_fn=fwht_fn, policy=policy,
                  kernel_statics=kernel_statics)
        if acc.n_added < acc.n_applied or acc.n_added > acc.capacity:
            raise ValueError(
                f"inconsistent stream state: {acc.n_added} columns of data "
                f"for n_applied={acc.n_applied}, capacity={acc.capacity}")
        return acc

    @classmethod
    def from_model(cls, model, *, fwht_fn: Optional[Callable] = None,
                   policy=None, kernel_statics=None
                   ) -> "SketchAccumulator":
        """Resume accumulation from a (possibly published) FittedModel.

        The artifact's stream_* leaves carry the applied sketch state;
        columns of X_train past stream_counts[0] are the staged tail and
        re-enter the pending buffer, so resume-then-eig reproduces the
        pre-publish eig exactly.
        """
        spec = model.spec
        if getattr(model, "stream_counts", None) is None:
            raise ValueError(
                "model carries no streaming state (stream_counts is "
                "missing): only one-pass fits made through "
                "SketchAccumulator can resume partial_fit")
        sketch_type = spec.sketch_type
        if sketch_type == "srht":
            sketch: Sketch = SRHT(signs=model.sketch_signs,
                                  rows=model.sketch_rows,
                                  n=int(model.stream_counts[1]),
                                  n_pad=int(model.sketch_signs.shape[0]))
        elif sketch_type == "gaussian":
            sketch = GaussianSketch(omega=model.sketch_omega)
        else:
            raise ValueError(
                f"backend {spec.backend!r} has no streaming sketch state")
        return cls.from_arrays(
            model.kernel_fn(), spec.r, sketch, model.stream_w,
            model.stream_row_norms2, int(model.stream_counts[0]),
            model.X_train, block=spec.block,
            truncate_basis=bool(
                spec.backend_params.get("truncate_basis", False)),
            fwht_fn=fwht_fn, policy=policy, kernel_statics=kernel_statics)

    # -- views -----------------------------------------------------------

    @property
    def capacity(self) -> int:
        return (self.sketch.n if isinstance(self.sketch, SRHT)
                else int(self.sketch.omega.shape[0]))

    @property
    def r_prime(self) -> int:
        return int(self.W.shape[1])

    @property
    def n_added(self) -> int:
        """Total columns added (applied + staged)."""
        return 0 if self._X is None else int(self._X.shape[1])

    @property
    def n_pending(self) -> int:
        """Staged columns not yet folded into the canonical W."""
        return self.n_added - self.n_applied

    @property
    def X_all(self) -> jnp.ndarray:
        """All columns added so far, (p, n_added) — the model's X_train."""
        if self._X is None:
            raise RuntimeError("no data accumulated; call add() first")
        return self._X

    # -- accumulation ----------------------------------------------------

    def add(self, X_chunk: jnp.ndarray) -> "SketchAccumulator":
        """Fold one data chunk (p, b) in; applies any full blocks now."""
        X_chunk = jnp.asarray(X_chunk, jnp.float32)
        if X_chunk.ndim != 2 or X_chunk.shape[1] < 1:
            raise ValueError(f"chunk must be (p, b>=1), got "
                             f"{getattr(X_chunk, 'shape', None)}")
        if self._X is not None and X_chunk.shape[0] != self._X.shape[0]:
            raise ValueError(f"chunk has p={X_chunk.shape[0]}, accumulator "
                             f"holds p={self._X.shape[0]}")
        if self.n_added + int(X_chunk.shape[1]) > self.capacity:
            raise ValueError(
                f"capacity {self.capacity} exceeded: have {self.n_added} "
                f"columns, chunk adds {int(X_chunk.shape[1])}")
        # Build the engine BEFORE concatenating — _ensure_engine loads
        # the pre-existing columns into the sharded buffer, then the new
        # chunk goes in once below.
        self._ensure_engine(int(X_chunk.shape[0]))
        self._X = (X_chunk if self._X is None
                   else jnp.concatenate([self._X, X_chunk], axis=1))
        if self._engine is not None:
            self._engine.ingest(X_chunk)
        while self.n_added - self.n_applied >= self.block:
            self.W, self.row_norms2 = self._apply(
                self.W, self.row_norms2, self.n_applied, self.block)
            self.n_applied += self.block
        return self

    def _apply(self, W, row_norms2, q, b):
        """One block update: fold columns [q, q+b) of the data into
        (W, row_norms2); pure — returns the updated pair.

        Dispatch: mesh policy -> the sharded engine (bit-identical to
        the canonical path on one device); fit_fused policy -> the
        single-host Pallas fit_sketch path (fp-tolerance parity, like
        fused serving); otherwise the canonical eager update below."""
        if self._engine is not None:
            return self._engine.apply(W, row_norms2, q, b)
        if self._fit_fused:
            return self._apply_fused(W, row_norms2, q, b)
        C = self._X[:, q:q + b]
        Kc = self.kernel(self._X[:, :q + b], C)            # (q+b, b)
        if isinstance(self.sketch, SRHT):
            Kp = jnp.zeros((self.capacity, b),
                           jnp.float32).at[:q + b].set(Kc)
            new_rows = srht_apply_t(self.sketch, Kp, self.fwht_fn).T
            cross = srht_rows(self.sketch, q, q + b)
        else:
            new_rows = Kc.T @ self.sketch.omega[:q + b]
            cross = self.sketch.omega[q:q + b]
        W = W.at[q:q + b].set(new_rows)
        # Column norms over a statically zero-padded stripe: the
        # reduction length is shape-stable (n_pad / capacity) rather
        # than q+b, so the mesh-sharded fit engine (distributed/fit.py)
        # — which can only ever reduce over its fixed padded row space —
        # reproduces these bits exactly on one device. The trailing
        # zero rows are value-neutral.
        n_red = (self.sketch.n_pad if isinstance(self.sketch, SRHT)
                 else self.capacity)
        Kf = jnp.zeros((n_red, b), jnp.float32).at[:q + b].set(Kc)
        K2f = Kf * Kf
        row_norms2 = row_norms2.at[q:q + b].set(jnp.sum(K2f, axis=0))
        if q:
            W = W.at[:q].add(Kc[:q] @ cross)
            row_norms2 = row_norms2.at[:q].add(
                jnp.sum(Kc[:q] * Kc[:q], axis=1))
        return W, row_norms2

    def _apply_fused(self, W, row_norms2, q, b):
        """Single-host block update through the fused fit_sketch Pallas
        kernel: gram-stripe -> sketch-accumulate in one pass with the
        accumulator VMEM-resident. Materializes the Omega row prefix
        (the price of trading the FWHT for an MXU contraction; the
        distributed engine shards that slab instead)."""
        from repro.kernels.fit_sketch.ops import fit_sketch_pallas

        kind, gamma, degree = self.kernel_statics
        Xpre = self._X[:, :q + b]
        C = self._X[:, q:q + b]
        if isinstance(self.sketch, SRHT):
            Omega = srht_rows(self.sketch, 0, q + b)
            cross = srht_rows(self.sketch, q, q + b)
        else:
            Omega = self.sketch.omega[:q + b]
            cross = self.sketch.omega[q:q + b]
        new_rows, delta, rn_rows, rn_cols = fit_sketch_pallas(
            Xpre, Omega, C, cross, kind=kind, gamma=float(gamma),
            degree=int(degree), interpret=self._fit_interpret)
        W = W.at[q:q + b].set(new_rows)
        row_norms2 = row_norms2.at[q:q + b].set(rn_cols)
        if q:
            W = W.at[:q].add(delta[:q])
            row_norms2 = row_norms2.at[:q].add(rn_rows[:q])
        return W, row_norms2

    def _effective_state(self):
        """(W, row_norms2, n_eff) with the staged tail applied on a COPY
        — the canonical block alignment is never disturbed, so later
        adds keep the chunk-invariant update sequence. In sharded mode
        the result is gathered back to the logical (capacity, .) host
        view: eig() always runs the canonical single-host core on it,
        which is what makes sharded eig bit-identical by construction
        (the sketch is the ONLY thing small enough to be worth
        gathering — the paper's point)."""
        tail = self.n_added - self.n_applied
        if tail == 0:
            W, rn, n_eff = self.W, self.row_norms2, self.n_applied
        else:
            W, rn = self._apply(self.W, self.row_norms2, self.n_applied,
                                tail)
            n_eff = self.n_added
        if self._engine is not None:
            W, rn = self._engine.gather(W), self._engine.gather(rn)
        return W, rn, n_eff

    # -- eigendecomposition ----------------------------------------------

    def eig(self, r: Optional[int] = None) -> LowRankEig:
        """Alg. 1 lines 3-6 on the effective sketch (tail included).

        Also refreshes `last_fro2` (exact streaming ||K||_F^2) and
        `last_approx_err` (sqrt(1 - sum(eigvals^2) / ||K||_F^2), the
        free residual estimate the drift monitor thresholds on).
        """
        r = self.r if r is None else int(r)
        W, rn, n_eff = self._effective_state()
        if n_eff < 1:
            raise RuntimeError("no data accumulated; call add() first")
        Wn = W[:n_eff]
        if self.truncate_basis:
            U, S, Vt = jnp.linalg.svd(Wn, full_matrices=False)
            Wn = (U[:, :r] * S[None, :r]) @ Vt[:r]
        if isinstance(self.sketch, SRHT):
            if n_eff == self.capacity:
                def omega_t_q(Q):
                    return srht_apply_t(self.sketch, Q, self.fwht_fn)
            else:
                def omega_t_q(Q):
                    Qp = jnp.zeros((self.capacity, Q.shape[1]),
                                   Q.dtype).at[:n_eff].set(Q)
                    return srht_apply_t(self.sketch, Qp, self.fwht_fn)
        else:
            def omega_t_q(Q):
                return self.sketch.omega[:n_eff].T @ Q
        out = one_pass_core(Wn, omega_t_q, r)
        fro2 = float(jnp.sum(rn))
        tail2 = max(fro2 - float(jnp.sum(out.eigvals ** 2)), 0.0)
        self.last_fro2 = fro2
        self.last_approx_err = (tail2 / fro2) ** 0.5 if fro2 > 0 else 0.0
        self.reeigs += 1
        return out

    # -- persistence -----------------------------------------------------

    def state_arrays(self) -> Dict[str, jnp.ndarray]:
        """The persistable stream state, keyed as FittedModel leaves.

        Staged (pending) columns are NOT separate state: they are the
        trailing columns of the model's X_train, recovered by
        from_model() via stream_counts[0].
        """
        if isinstance(self.sketch, SRHT):
            st = {"sketch_signs": self.sketch.signs,
                  "sketch_rows": self.sketch.rows}
        else:
            st = {"sketch_omega": self.sketch.omega}
        if self._engine is not None:
            st["stream_w"] = self._engine.gather(self.W)
            st["stream_row_norms2"] = self._engine.gather(self.row_norms2)
        else:
            st["stream_w"] = self.W
            st["stream_row_norms2"] = self.row_norms2
        st["stream_counts"] = jnp.array([self.n_applied, self.capacity],
                                        jnp.int32)
        return st

    def __repr__(self) -> str:
        kind = ("srht" if isinstance(self.sketch, SRHT) else "gaussian")
        return (f"SketchAccumulator({kind}, r={self.r}, "
                f"r'={self.r_prime}, {self.n_added}/{self.capacity} cols, "
                f"{self.n_pending} pending)")

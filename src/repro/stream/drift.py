"""Drift detection over live serving traffic.

Two streaming estimators, both O(1) memory in the query count, decide
when the fitted model has gone stale:

  approximation error   per sampled query x, the relative residual of
                        the kernel column outside the fitted eigenbasis,
                        ||(I - U U^T) kappa(ref, x)|| / ||kappa(ref, x)||
                        — the serving-time analogue of the paper's
                        ||K - K_hat||_F / ||K||_F, accumulated in the
                        same log-spaced streaming histogram the latency
                        layer uses (serve/latency.py), so p50/p95 drift
                        read-outs cost O(buckets), not O(queries).
  assignment shift      live cluster-population fractions vs. the fitted
                        reference, scored by the chi-square statistic
                        n * sum((p_live - p_ref)^2 / p_ref) and the max
                        absolute fraction delta.

`DriftMonitor.observe()` is called from the serving loop with each
(sampled) batch and the labels it was served; `report()` folds both
estimators against their thresholds into a `DriftReport`, which
`stream/retrain.py` turns into refit -> publish -> swap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serve import extend
from repro.serve.artifact import FittedModel
from repro.serve.latency import Histogram


@dataclasses.dataclass
class DriftReport:
    """One monitoring read-out; `fired` is the retrain trigger."""
    queries: int                 # labeled queries in the window
    samples: int                 # queries the approx-err estimator saw
    approx_err_p50: float
    approx_err_p95: float
    approx_err_mean: float
    chi2: float
    max_frac_delta: float
    live_fracs: List[float]
    ref_fracs: List[float]
    approx_fired: bool
    assign_fired: bool
    fired: bool
    reason: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class DriftMonitor:
    """Streaming drift estimators bound to one fitted model.

    ref_labels: training labels fixing the reference assignment
        distribution; None derives them by assigning X_train through the
        model (exact for the one-pass backends, where Y spans X_train).
    approx_err_threshold: fire when the sampled p95 relative kernel
        residual exceeds this (None disables the approx-error trigger —
        e.g. for kernels whose fitted rank is exact, where residuals stay
        ~0 under any shift and only assignment drift is informative).
    chi2_threshold / frac_delta_threshold: assignment-shift triggers;
        chi-square grows linearly in the window size under a real shift,
        so any O(1) threshold separates shift from sampling noise once
        min_queries is met.
    min_queries: assignment trigger stays quiet below this window size.
    sample_every: the approx-error estimator (one kernel-column
        evaluation per query batch) runs on every sample_every-th
        observe() call; assignment counting is always on.
    """

    def __init__(self, model: FittedModel, *,
                 ref_labels: Optional[np.ndarray] = None,
                 approx_err_threshold: Optional[float] = None,
                 chi2_threshold: float = 30.0,
                 frac_delta_threshold: float = 0.25,
                 min_queries: int = 64, sample_every: int = 1):
        self.approx_err_threshold = approx_err_threshold
        self.chi2_threshold = float(chi2_threshold)
        self.frac_delta_threshold = float(frac_delta_threshold)
        self.min_queries = int(min_queries)
        self.sample_every = max(int(sample_every), 1)
        self.rebind(model, ref_labels=ref_labels)

    # -- lifecycle -------------------------------------------------------

    def rebind(self, model: FittedModel,
               ref_labels: Optional[np.ndarray] = None) -> None:
        """Point the monitor at a (new) model and reset the window —
        called by the retrain worker after every swap."""
        self.model = model
        self.k = int(model.spec.k)
        self._extender = extend.Extender(model)
        if ref_labels is None:
            ref_labels, _ = self._extender.assign(
                jnp.asarray(model.X_train, jnp.float32))
        ref_labels = np.asarray(ref_labels)
        counts = np.bincount(ref_labels, minlength=self.k).astype(np.float64)
        if counts.sum() <= 0:
            raise ValueError("reference labels are empty")
        self.ref_fracs = counts / counts.sum()
        self.reset_window()

    def reset_window(self) -> None:
        """Clear the live window (reference distribution is kept)."""
        self._counts = np.zeros(self.k, np.float64)
        self._hist = Histogram()
        self._calls = 0
        self.queries = 0
        self.samples = 0

    # -- streaming updates -----------------------------------------------

    def observe(self, Xq, labels=None) -> None:
        """Fold one served batch into the window.

        Xq: (p, b) queries; labels: the (b,) labels they were served
        (None recomputes them through the bound model). The approx-error
        estimator runs on every `sample_every`-th call.

        Xq only goes to the device on the paths that compute with it
        (label recompute, sampled error estimate). The common serving
        call — labels provided, not a sampled call — must not pay a
        host->device copy of the whole query block per observe(): this
        runs once per served batch.
        """
        if not hasattr(Xq, "shape"):        # host-side normalization only
            Xq = np.asarray(Xq, np.float32)
        if labels is None:
            labels, _ = self._extender.assign(jnp.asarray(Xq, jnp.float32))
        labels = np.asarray(labels)
        self._counts += np.bincount(labels, minlength=self.k
                                    )[:self.k].astype(np.float64)
        self.queries += int(labels.shape[0])
        sampled = self._calls % self.sample_every == 0
        self._calls += 1
        if sampled:
            errs = self._approx_errors(jnp.asarray(Xq, jnp.float32))
            for err in np.asarray(errs):
                self._hist.record(float(err))
            self.samples += int(Xq.shape[1])

    def _approx_errors(self, Xq: jnp.ndarray) -> jnp.ndarray:
        """Relative kernel-column residual outside the fitted basis,
        per query column: ||(I - U U^T) z|| / ||z||, z = kappa(ref, x)."""
        model = self.model
        z = model.kernel_fn()(model.extension_ref, Xq)     # (n_ref, b)
        resid = z - model.U @ (model.U.T @ z)
        num = jnp.linalg.norm(resid, axis=0)
        den = jnp.maximum(jnp.linalg.norm(z, axis=0), 1e-12)
        return num / den

    def sample_serving_stats(self, batcher) -> Dict:
        """Snapshot + reset a MicroBatcher's traffic counters without
        touching bucket_hits (preserve_buckets=True), so a periodic
        stats sample can never cold-start the next warm hot-swap."""
        snap = {k: (dict(v) if isinstance(v, dict) else v)
                for k, v in batcher.stats.items()}
        batcher.reset_stats(preserve_buckets=True)
        return snap

    # -- read-out --------------------------------------------------------

    def report(self) -> DriftReport:
        total = self._counts.sum()
        live = (self._counts / total if total > 0
                else np.zeros_like(self._counts))
        chi2 = float(total * np.sum(
            (live - self.ref_fracs) ** 2 / np.maximum(self.ref_fracs, 1e-9)))
        max_delta = float(np.max(np.abs(live - self.ref_fracs))
                          if total > 0 else 0.0)
        p50 = self._hist.percentile(50.0)
        p95 = self._hist.percentile(95.0)
        approx_fired = (self.approx_err_threshold is not None
                        and self._hist.n > 0
                        and p95 > self.approx_err_threshold)
        assign_fired = (total >= self.min_queries
                        and (chi2 > self.chi2_threshold
                             or max_delta > self.frac_delta_threshold))
        reasons = []
        if approx_fired:
            reasons.append(f"approx-err p95 {p95:.3g} > "
                           f"{self.approx_err_threshold:.3g}")
        if assign_fired:
            reasons.append(f"assignment shift chi2 {chi2:.3g} / "
                           f"max-delta {max_delta:.3g}")
        return DriftReport(
            queries=self.queries, samples=self.samples,
            approx_err_p50=p50, approx_err_p95=p95,
            approx_err_mean=self._hist.mean,
            chi2=chi2, max_frac_delta=max_delta,
            live_fracs=[float(v) for v in live],
            ref_fracs=[float(v) for v in self.ref_fracs],
            approx_fired=approx_fired, assign_fired=assign_fired,
            fired=approx_fired or assign_fired,
            reason="; ".join(reasons) if reasons else "no drift")

"""The closed loop: drift trigger -> refit -> publish -> warm hot-swap.

`RetrainWorker` watches a `DriftMonitor` and, when a window fires,
drives the whole rollout against the existing serve stack:

    1. refit      `refit_fn(report)` produces the replacement
                  FittedModel — typically `KernelKMeans.partial_fit`
                  over the accumulated window, or a spec-driven refit
                  (`spec_to_estimator(old.spec).fit(X_accum, key)`)
    2. publish    `VersionStore.publish()` commits it as the next
                  immutable version (atomic, GC'ed per the store policy)
    3. swap       `ModelRegistry.swap()` warms the new row off the
                  serving path and flips atomically; the outgoing
                  AsyncBatcher drains into the OLD model, so no future
                  is ever stranded (SwapReport.drained_requests counts
                  the tail)
    4. rebind     the monitor re-references the new model and opens a
                  fresh window

Like the async scheduler, the worker is deterministic-first: `step()` is
the cooperative entry point (tests and single-threaded loops call it
directly); `start()/stop()` wrap it in a daemon poll thread for real
deployments. Every completed rollout is a `RetrainReport`, whose
detect_to_swap_s is the headline number the "stream" bench section
tracks.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.serve.artifact import FittedModel
from repro.serve.registry import ModelRegistry, SwapReport
from repro.serve.versions import VersionStore
from repro.stream.drift import DriftMonitor, DriftReport


@dataclasses.dataclass
class RetrainReport:
    """One drift-triggered rollout, fully measured."""
    name: str
    version: int                 # published version of the new model
    drift: DriftReport           # the window that fired
    swap: SwapReport
    refit_s: float
    publish_s: float
    swap_s: float
    detect_to_swap_s: float      # trigger read -> flip committed

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["drift"] = self.drift.to_dict()
        d["swap"] = self.swap.to_dict()
        return d


class RetrainWorker:
    """Background (or cooperative) drift-to-swap loop for one model row.

    name/registry: the serving row to roll over.
    store: the VersionStore every refit publishes into.
    monitor: the DriftMonitor whose report() is the trigger.
    refit_fn: DriftReport -> FittedModel; owns how to refit (from the
        estimator's accumulated partial_fit state, a spec-driven refit
        on fresh data, ...).
    cooldown_s: minimum spacing between rollouts — a still-drifting
        window right after a swap must not re-fire before the new model
        has seen traffic.
    """

    def __init__(self, name: str, registry: ModelRegistry,
                 store: VersionStore, monitor: DriftMonitor,
                 refit_fn: Callable[[DriftReport], FittedModel], *,
                 cooldown_s: float = 0.0, clock=time.monotonic):
        self.name = name
        self.registry = registry
        self.store = store
        self.monitor = monitor
        self.refit_fn = refit_fn
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.reports: List[RetrainReport] = []
        self.checks = 0
        self._last_rollout: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_event = threading.Event()
        # A refit that raises must not kill the poll loop silently.
        self.errors = 0
        self.last_error: Optional[BaseException] = None

    # -- cooperative entry point -----------------------------------------

    def step(self) -> Optional[RetrainReport]:
        """Check the monitor once; run the full rollout if it fired.

        Returns the RetrainReport of a completed rollout, else None
        (no drift, or still inside the cooldown window)."""
        self.checks += 1
        now = self.clock()
        if (self._last_rollout is not None
                and now - self._last_rollout < self.cooldown_s):
            return None
        report = self.monitor.report()
        if not report.fired:
            return None
        t0 = self.clock()
        model = self.refit_fn(report)
        t1 = self.clock()
        version = self.store.publish(model)
        t2 = self.clock()
        swap = self.registry.swap(self.name, model, version=version)
        t3 = self.clock()
        self.monitor.rebind(model)
        out = RetrainReport(
            name=self.name, version=version, drift=report, swap=swap,
            refit_s=t1 - t0, publish_s=t2 - t1, swap_s=t3 - t2,
            detect_to_swap_s=t3 - t0)
        self.reports.append(out)
        self._last_rollout = self.clock()
        return out

    @property
    def retrains(self) -> int:
        return len(self.reports)

    # -- background poll loop --------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None

    def start(self, poll_s: float = 0.1) -> "RetrainWorker":
        """Spawn the daemon poll thread (step() every poll_s)."""
        if self._thread is not None:
            raise RuntimeError("retrain worker already running")
        self._stop_event.clear()

        def loop():
            while not self._stop_event.wait(poll_s):
                try:
                    self.step()
                except Exception as exc:
                    self.errors += 1
                    self.last_error = exc

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="RetrainWorker")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._stop_event.set()
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "RetrainWorker":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

"""Minibatch K-means (Sculley 2010) in the rank-r embedding space.

Chitta et al. ("Scalable Kernel Clustering", PAPERS.md) motivate
approximating kernel K-means with cheap per-batch updates; here the
kernel is already linearized (Y = Sigma^{1/2} U^T from the one-pass
sketch), so the minibatch variant is plain Sculley minibatch K-means on
the columns of Y: per step, sample a batch, assign to the nearest
centroid, and move each centroid toward its batch mean with a
per-centroid count-based learning rate cnt / (counts + cnt).

This is the `kmeans_mode="minibatch"` path of
`KernelKMeans.partial_fit` — an O(steps * batch * k * r) re-eig follow-up
instead of full Lloyd's O(restarts * iters * n * k * r). Each re-eig
re-seeds with k-means++ on the fresh embedding: the r-space basis
rotates between re-eigs (Q is recomputed), so carrying centroids across
bases would chase a moving frame.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import _sq_dists, kmeans_plus_plus


class MiniBatchResult(NamedTuple):
    labels: jnp.ndarray      # (n,) int32 — final full-data assignment
    centroids: jnp.ndarray   # (K, r)
    objective: jnp.ndarray   # () float32 — full-data sum of squared dists
    n_steps: jnp.ndarray     # () int32


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def minibatch_kmeans(key: jax.Array, Y: jnp.ndarray, k: int,
                     batch_size: int = 256,
                     n_steps: int = 50) -> MiniBatchResult:
    """Sculley minibatch K-means. Y: (n, r) rows = samples (matching
    core.kmeans.kmeans); sampling is uniform with replacement."""
    n = Y.shape[0]
    k_init, k_loop = jax.random.split(key)
    C0 = kmeans_plus_plus(k_init, Y, k)

    def body(_, carry):
        C, counts, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch_size,), 0, n)
        B = Y[idx]
        labels = jnp.argmin(_sq_dists(B, C), axis=1)
        onehot = jax.nn.one_hot(labels, k, dtype=Y.dtype)    # (b, K)
        cnt = jnp.sum(onehot, axis=0)                        # (K,)
        mean = (onehot.T @ B) / jnp.maximum(cnt, 1.0)[:, None]
        new_counts = counts + cnt
        lr = (cnt / jnp.maximum(new_counts, 1.0))[:, None]
        C = jnp.where(cnt[:, None] > 0, C + lr * (mean - C), C)
        return C, new_counts, key

    init = (C0, jnp.zeros((k,), Y.dtype), k_loop)
    C, _, _ = jax.lax.fori_loop(0, n_steps, body, init)
    d2 = _sq_dists(Y, C)
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    objective = jnp.sum(jnp.min(d2, axis=1))
    return MiniBatchResult(labels=labels, centroids=C, objective=objective,
                           n_steps=jnp.int32(n_steps))

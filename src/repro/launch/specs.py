"""Input ShapeDtypeStruct builders for every (arch x shape) cell.

The assigned shape grid (all 10 LM-family archs):
    train_4k     seq=4096   global_batch=256   -> train_step
    prefill_32k  seq=32768  global_batch=32    -> prefill_step
    decode_32k   seq=32768  global_batch=128   -> decode_step (KV cache 32k)
    long_500k    seq=524288 global_batch=1     -> decode_step, sub-quadratic
                                                  archs only (DESIGN.md §4)

`concrete=False` returns ShapeDtypeStructs (dry-run: no allocation);
`concrete=True` returns real arrays (smoke tests / examples) — only valid
for reduced configs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# Archs for which long_500k decode is runnable (bounded state/window);
# everything else is a documented skip (DESIGN.md §4).
LONG_OK = {"recurrentgemma-2b", "rwkv6-1.6b", "mixtral-8x7b"}


def cell_supported(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and cfg.name not in LONG_OK:
        return False, ("pure full-attention arch: 500k-token decode is "
                       "quadratic/HBM-infeasible; skipped per assignment")
    return True, ""


def _mk(shape, dtype, concrete, key=None, maxval=None):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if dtype in (jnp.int32, "int32"):
        return jax.random.randint(key, shape, 0, maxval or 2, jnp.int32)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def train_inputs(cfg: ArchConfig, seq: int, batch: int,
                 concrete: bool = False, key=None) -> Dict[str, Any]:
    """Batch dict for train_step. Token budget == seq per sample; modality
    prefixes (whisper frames / pixtral patches) occupy their slice of it."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    act_dtype = jnp.dtype(cfg.dtype)
    V = cfg.vocab_size
    if cfg.family == "encdec":
        return {
            "frames": _mk((batch, cfg.n_audio_frames, cfg.d_model),
                          act_dtype, concrete, ks[0]),
            "tokens": _mk((batch, seq), jnp.int32, concrete, ks[1], V),
            "labels": _mk((batch, seq), jnp.int32, concrete, ks[2], V),
        }
    if cfg.family == "vlm":
        n_patch = min(cfg.n_patch_tokens, seq // 2)
        return {
            "patches": _mk((batch, n_patch, cfg.d_model), act_dtype,
                           concrete, ks[0]),
            "tokens": _mk((batch, seq - n_patch), jnp.int32, concrete,
                          ks[1], V),
            # labels cover patch prefix (masked -1) + text.
            "labels": (_mk((batch, seq), jnp.int32, concrete, ks[2], V)
                       if not concrete else
                       jnp.concatenate([
                           jnp.full((batch, n_patch), -1, jnp.int32),
                           jax.random.randint(ks[2], (batch, seq - n_patch),
                                              0, V, jnp.int32)], axis=1)),
        }
    return {
        "tokens": _mk((batch, seq), jnp.int32, concrete, ks[1], V),
        "labels": _mk((batch, seq), jnp.int32, concrete, ks[2], V),
    }


def prefill_inputs(cfg: ArchConfig, seq: int, batch: int,
                   concrete: bool = False, key=None) -> Dict[str, Any]:
    b = train_inputs(cfg, seq, batch, concrete, key)
    b.pop("labels", None)
    return b


def decode_tokens(cfg: ArchConfig, batch: int, concrete: bool = False,
                  key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return _mk((batch,), jnp.int32, concrete, key, cfg.vocab_size)


def cache_specs(cfg: ArchConfig, api, batch: int, max_seq: int,
                concrete: bool = False, dtype=jnp.bfloat16):
    """Cache as ShapeDtypeStructs (dry-run) or zeros (smoke)."""
    if concrete:
        return api.init_cache(cfg, batch, max_seq, dtype)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, batch, max_seq,
                                                  dtype))
    return cache

"""Production training launcher: mesh + sharded state + checkpoint/restart.

On real TPU pods this is the per-host entrypoint (jax.distributed.initialize
is called when JAX_COORDINATOR is set); on CPU it runs reduced configs for
end-to-end validation. The fault-tolerance supervisor wraps the step loop:
on HostFailure it restores the latest checkpoint (resharded if the mesh
shrank) and continues.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1, help="data-axis size")
    ap.add_argument("--model", type=int, default=1, help="model-axis size")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--sketch-grads", type=int, default=0,
                    help="r' for SRHT gradient compression (0 = off)")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    if "JAX_COORDINATOR" in os.environ:      # multi-host entry
        jax.distributed.initialize()

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.train import steps as tsteps
    from repro.train.optimizer import AdamWConfig
    from repro.distributed import sharding as shd
    from repro.distributed.checkpoint import CheckpointManager
    from repro.launch import specs
    from repro.launch.mesh import make_debug_mesh, dp_axes

    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_api(cfg)
    mesh = make_debug_mesh(args.data, args.model)
    tp = args.model
    key = jax.random.PRNGKey(0)
    state = tsteps.init_train_state(key, cfg, api, tp=tp)
    state_spec = shd.state_pspecs(jax.eval_shape(
        lambda: tsteps.init_train_state(key, cfg, api, tp=tp)), mesh)
    def ns(spec):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                            is_leaf=lambda q: isinstance(q, P))
    state = jax.device_put(state, ns(state_spec))

    grad_transform = None
    ef_holder = {}
    if args.sketch_grads:
        from repro.distributed.compression import make_sketched_grad_transform
        transform, init_ef = make_sketched_grad_transform(
            state.params, r_prime=args.sketch_grads)
        ef_holder["ef"] = init_ef()
        ef_holder["t"] = 0

        def grad_transform(grads):
            g, ef_holder["ef"] = transform(
                grads, ef_holder["ef"],
                jax.random.PRNGKey(ef_holder["t"]))
            ef_holder["t"] += 1
            return g

    opt_cfg = AdamWConfig(lr=args.lr, moment_dtype=cfg.optimizer_dtype)
    # A fixed synthetic corpus: the model must drive loss down on it.
    batch = specs.train_inputs(cfg, args.seq, args.batch, concrete=True,
                               key=jax.random.PRNGKey(7))
    batch_spec = shd.batch_pspecs(jax.eval_shape(lambda: batch), mesh)
    batch = jax.device_put(batch, ns(batch_spec))

    mgr = (CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every)
           if args.ckpt_dir else None)
    start = 0
    if mgr is not None:
        try:
            state, start = mgr.restore_latest(jax.eval_shape(lambda: state))
            print(f"restored checkpoint at step {start}")
        except FileNotFoundError:
            pass

    with mesh:
        with shd.activation_sharding(dp_axes(mesh)):
            step_jit = jax.jit(
                tsteps.make_train_step(cfg, api, groups=args.data,
                                       grad_transform=None,
                                       opt_cfg=opt_cfg),
                in_shardings=(ns(state_spec), ns(batch_spec)),
                out_shardings=(ns(state_spec), None),
                donate_argnums=(0,))
            losses = []
            t0 = time.time()
            for step in range(start, args.steps):
                if grad_transform is not None:
                    # Eager path when compressing (EF state lives outside
                    # jit; production uses the shard_map variant).
                    sfn = tsteps.make_train_step(
                        cfg, api, groups=args.data,
                        grad_transform=grad_transform, opt_cfg=opt_cfg)
                    state, metrics = sfn(state, batch)
                else:
                    state, metrics = step_jit(state, batch)
                losses.append(float(metrics["loss"]))
                if mgr is not None:
                    mgr.maybe_save(step + 1, state)
                if step % 10 == 0 or step == args.steps - 1:
                    print(f"step {step:5d} loss {losses[-1]:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"({(time.time()-t0):.1f}s)", flush=True)
            print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
            assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()

"""Trip-count-aware HLO cost analysis.

XLA's built-in cost_analysis counts `while` bodies ONCE, so scanned-layer /
microbatched programs under-report FLOPs, bytes and collective traffic by
the loop trip counts. This module parses the post-SPMD HLO text, builds the
computation call graph (while body/condition edges carry the loop trip
count, fusion/call edges carry 1) and accumulates:

  - dot FLOPs            2 * prod(batch+free dims) * prod(contracting dims)
  - HBM traffic          operand + output bytes of top-level fusions/dots/
                         copies/dynamic-slices (post-fusion HLO: each
                         top-level op is roughly one HBM round trip)
  - collective bytes     per collective kind, shape bytes * trip weight

Trip counts come from the `constant(N)` in the while condition computation
(jax scans lower to 0..N LT-loops). Conservative fallbacks: unknown trip
count -> 1 (matches XLA's own behaviour, and is logged). `lax.cond`
branch_computations execute at top level (they are NOT fusion-internal),
so their ops keep HBM traffic; each branch is weighted by the parent's
trip weight — an upper bound, since only one branch runs per visit.

This is an approximation (elementwise FLOPs ignored; fusion traffic assumes
one read per operand) — but it is *structurally* exact for loops, which is
the term that matters at 96 layers x 16 microbatches. Validated against
hand-computable programs in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_elems_bytes(s: str) -> Tuple[int, int]:
    m = _SHAPE_RE.match(s)
    if not m:
        return 0, 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 4)


def _all_shapes_bytes(text: str) -> int:
    return sum(_shape_elems_bytes(m.group(0))[1]
               for m in _SHAPE_RE.finditer(text))


@dataclasses.dataclass
class Op:
    name: str
    shape: str            # result shape text (may be tuple "(a, b)")
    kind: str             # opcode
    rest: str             # full remainder of the line


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        # Computation header: `%name (args) -> type {` or `ENTRY %name ...`
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                     line)
        if m and not line.startswith(" "):
            cur = Computation(m.group(1), [])
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        ls = line.strip()
        om = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+"
                      r"([\w\-]+)\((.*)$", ls)
        if om:
            name, shape, kind, rest = om.groups()
            cur.ops.append(Op(name, shape, kind, rest))
    return comps


def _callees(op: Op) -> List[Tuple[str, str]]:
    """(role, computation) edges out of an op."""
    out = []
    for role in ("body", "condition", "calls", "to_apply",
                 "branch_computations", "true_computation",
                 "false_computation"):
        m = re.search(role + r"=\{([^}]*)\}", op.rest)
        if m:
            for c in m.group(1).split(","):
                name = c.strip().lstrip("%")
                if name:
                    out.append((role, name))
            continue
        m = re.search(role + r"=%([\w.\-]+)", op.rest)
        if m:
            out.append((role, m.group(1)))
    return out


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the condition computation (jax scan:
    `i < N`). Fallback 1."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\-?\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
        # constants may hide inside wrapped_compare fusions' operands —
        # also scan the raw rest text.
        for m in re.finditer(r"constant\((\d+)\)", op.rest):
            best = max(best, int(m.group(1)))
    return best


def computation_weights(comps: Dict[str, Computation]
                        ) -> Tuple[Dict[str, float], set]:
    """weight(C) = sum over call sites of weight(parent) * trip_count.

    Also returns the set of 'fused' computations (reached via calls= /
    to_apply= rather than while body/condition): ops inside those live in
    registers/VMEM, so they carry FLOPs but NOT HBM traffic.
    """
    called = set()
    fused = set()
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for cname, comp in comps.items():
        for op in comp.ops:
            if op.kind == "while":
                body = cond = None
                for role, callee in _callees(op):
                    if role == "body":
                        body = callee
                    elif role == "condition":
                        cond = callee
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body in edges:
                    edges[body].append((cname, float(max(trips, 1))))
                if cond in edges:
                    edges[cond].append((cname, float(max(trips, 1) + 1)))
                called.update(x for x in (body, cond) if x)
            else:
                for role, callee in _callees(op):
                    if callee in edges:
                        edges[callee].append((cname, 1.0))
                        called.add(callee)
                        # Only computations inlined into a fusion (or used as
                        # a reducer/comparator via to_apply on a real op) live
                        # in registers/VMEM. A plain `call` op (e.g. the CPU
                        # backend's parallel-task wrapper inside while bodies)
                        # executes its body at top level, so its ops DO touch
                        # HBM and must keep their trip-count weight. The same
                        # holds for `conditional` branch_computations
                        # (lax.cond bodies): exactly one branch runs per
                        # visit, but it runs at top level — treating it as
                        # fusion-internal under-counted its HBM traffic
                        # entirely (ROADMAP "HLO analyzer" item).
                        if op.kind not in ("call", "conditional"):
                            fused.add(callee)
    # Fusion-reachability is transitive.
    changed = True
    while changed:
        changed = False
        for cname, comp in comps.items():
            if cname not in fused:
                continue
            for op in comp.ops:
                for _, callee in _callees(op):
                    if callee in comps and callee not in fused:
                        fused.add(callee)
                        changed = True
    roots = [c for c in comps if c not in called]
    weights: Dict[str, float] = {}

    def weight(c: str, stack=()) -> float:
        if c in weights:
            return weights[c]
        if c in stack:          # recursion guard
            return 1.0
        if c in roots or not edges[c]:
            weights[c] = 1.0
            return 1.0
        w = sum(weight(p, stack + (c,)) * t for p, t in edges[c])
        weights[c] = w
        return w

    for c in comps:
        weight(c)
    return weights, fused


def _operands(op: Op) -> List[str]:
    """Operand names: %refs inside the call parens (before attributes)."""
    depth = 1
    end = len(op.rest)
    for i, ch in enumerate(op.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", op.rest[:end])


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """FLOPs of a dot: 2 * output elems * contraction size."""
    out_elems, _ = _shape_elems_bytes(op.shape.strip("("))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not m:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    ops_ = _operands(op)
    lhs_shape = shapes.get(ops_[0], "") if ops_ else ""
    dm = _SHAPE_RE.match(lhs_shape.strip("("))
    if not dm:
        return 2.0 * out_elems
    dims = [int(x) for x in dm.group(2).split(",") if x]
    csize = 1
    for cd in cdims:
        if cd < len(dims):
            csize *= dims[cd]
    return 2.0 * out_elems * csize


_TRAFFIC_KINDS = {"fusion", "dot", "convolution", "copy", "dynamic-slice",
                  "dynamic-update-slice", "gather", "scatter", "transpose",
                  "reshape", "broadcast", "reduce", "concatenate", "slice",
                  "sort", "iota", "select-and-scatter", "pad"}


def analyze(text: str) -> Dict:
    comps = parse_hlo(text)
    weights, fused = computation_weights(comps)
    flops = 0.0
    traffic = 0.0
    coll_bytes = {c: 0.0 for c in _COLLECTIVES}
    coll_counts = {c: 0.0 for c in _COLLECTIVES}
    for cname, comp in comps.items():
        w = weights.get(cname, 1.0)
        shapes = {op.name: op.shape for op in comp.ops}
        in_fusion = cname in fused
        for op in comp.ops:
            if op.kind == "dot":
                flops += w * _dot_flops(op, shapes)
            if op.kind in _COLLECTIVES:
                nbytes = _all_shapes_bytes(op.shape)
                coll_bytes[op.kind] += w * nbytes
                coll_counts[op.kind] += w
            # HBM traffic: only top-level (non-fusion-internal) ops touch
            # HBM; fusion internals live in registers/VMEM.
            if not in_fusion and op.kind in _TRAFFIC_KINDS:
                out_b = _all_shapes_bytes(op.shape)
                in_b = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                           for o in _operands(op))
                traffic += w * (out_b + in_b)
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total": sum(coll_bytes.values()),
        "n_computations": len(comps),
    }

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the right step function (train_4k -> train_step,
prefill_32k -> prefill_step, decode_32k / long_500k -> decode_step) with
full production shardings against ShapeDtypeStruct inputs (no allocation),
compiles it, and records:
  - memory_analysis (bytes per device: argument/output/temp/peak),
  - cost_analysis (per-device HLO flops / bytes accessed),
  - the collective-bytes breakdown parsed from the post-SPMD HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), which §Roofline consumes.

One cell per process invocation (device count is locked at first jax init);
benchmarks/dryrun_all.py fans these out.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multipod] [--out artifacts/dryrun]
"""
import argparse
import json
import pathlib
import time


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             overrides: dict = None) -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.launch import specs
    from repro.launch.mesh import (make_production_mesh, dp_axes,
                                   mesh_axis_sizes)
    from repro.distributed import sharding as shd
    from repro.train import steps as tsteps

    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    ok, why = specs.cell_supported(cfg, shape)
    res = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        res.update(status="skipped", reason=why)
        out_path = pathlib.Path(out_dir)
        out_path.mkdir(parents=True, exist_ok=True)
        fname = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}.json"
        (out_path / fname).write_text(json.dumps(res, indent=1))
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = mesh_axis_sizes(mesh)
    tp = sizes.get("model", 1)
    dp_total = 1
    for a in dp_axes(mesh):
        dp_total *= sizes[a]
    sh = specs.SHAPES[shape]
    B, S = sh["batch"], sh["seq"]
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    def ns(spec):
        return jax.tree.map(lambda p: NamedSharding(mesh, p), spec,
                            is_leaf=lambda x: isinstance(x, P))
    groups = dp_total if (B % dp_total == 0 and B * min(S, 1) >= 0) else 1
    if B % dp_total != 0:
        groups = 1

    t0 = time.time()
    seq_axis = "model" if cfg.seq_shard_acts else None
    with mesh:
        with shd.activation_sharding(dp_axes(mesh), seq_axis=seq_axis,
                                     seq_div=tp):
            if sh["kind"] == "train":
                state_shape = jax.eval_shape(
                    lambda: tsteps.init_train_state(key, cfg, api, tp))
                state_spec = shd.state_pspecs(state_shape, mesh,
                                              zero1=cfg.zero1)
                batch_shape = specs.train_inputs(cfg, S, B)
                batch_spec = shd.batch_pspecs(batch_shape, mesh)
                # Microbatch count must keep per-microbatch batch divisible
                # by dp (DESIGN.md §5).
                micro = min(cfg.microbatches, max(1, B // dp_total))
                while (B // micro) % dp_total and micro > 1:
                    micro -= 1
                import dataclasses
                cfg_run = dataclasses.replace(cfg, microbatches=micro)
                pregather_spec = (shd.param_pspecs(state_shape.params, mesh,
                                                   use_fsdp=False)
                                  if cfg.pregather else None)
                grad_spec = shd.param_pspecs(state_shape.params, mesh,
                                             use_fsdp=True)
                step = tsteps.make_train_step(cfg_run, api, groups=groups,
                                              pregather_spec=pregather_spec,
                                              grad_spec=grad_spec)
                lowered = jax.jit(
                    step,
                    in_shardings=(ns(state_spec), ns(batch_spec)),
                    out_shardings=(ns(state_spec), None),
                    donate_argnums=(0,),   # state double-buffer elided
                ).lower(state_shape, batch_shape)
            elif sh["kind"] == "prefill":
                params_shape = jax.eval_shape(
                    lambda: api.init(key, cfg, tp))
                params_spec = shd.param_pspecs(params_shape, mesh)
                batch_shape = specs.prefill_inputs(cfg, S, B)
                batch_spec = shd.batch_pspecs(batch_shape, mesh)
                cache_shape = specs.cache_specs(cfg, api, B, S)
                cache_spec = shd.cache_pspecs(cache_shape, mesh)
                step = tsteps.make_prefill_step(cfg, api, groups=groups)
                lowered = jax.jit(
                    step,
                    in_shardings=(ns(params_spec), ns(batch_spec),
                                  ns(cache_spec)),
                    out_shardings=(None, ns(cache_spec)),
                    donate_argnums=(2,),   # cache updated in place
                ).lower(params_shape, batch_shape, cache_shape)
            else:  # decode
                params_shape = jax.eval_shape(
                    lambda: api.init(key, cfg, tp))
                params_spec = shd.param_pspecs(params_shape, mesh)
                cache_shape = specs.cache_specs(cfg, api, B, S)
                cache_spec = shd.cache_pspecs(cache_shape, mesh)
                tokens_shape = specs.decode_tokens(cfg, B)
                tok_spec = shd.batch_pspecs({"t": tokens_shape}, mesh)["t"]
                step = tsteps.make_decode_step(cfg, api, groups=groups)
                lowered = jax.jit(
                    step,
                    in_shardings=(ns(params_spec), ns(tok_spec),
                                  ns(cache_spec)),
                    out_shardings=(ns(tok_spec), None, ns(cache_spec)),
                    donate_argnums=(2,),   # cache updated in place
                ).lower(params_shape, tokens_shape, cache_shape)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.launch.hlo_analysis import analyze

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # Trip-count-aware analysis (XLA's cost_analysis counts while bodies
    # once; ours multiplies by scan/microbatch trip counts).
    hres = analyze(hlo)
    coll = {"bytes": hres["collective_bytes"],
            "counts": hres["collective_counts"],
            "total_bytes": hres["collective_total"]}
    res.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        n_devices=int(mesh.devices.size),
        memory=dict(
            argument_mb=round(getattr(mem, "argument_size_in_bytes", 0) / 2**20, 1),
            output_mb=round(getattr(mem, "output_size_in_bytes", 0) / 2**20, 1),
            temp_mb=round(getattr(mem, "temp_size_in_bytes", 0) / 2**20, 1),
            peak_mb=round((getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)) / 2**20, 1),
        ),
        cost=dict(flops=float(cost.get("flops", 0.0)),
                  bytes_accessed=float(cost.get("bytes accessed", 0.0))),
        hlo_flops=hres["flops"],
        hlo_traffic_bytes=hres["traffic_bytes"],
        collectives=coll,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        microbatches=locals().get("micro", 1),
        groups=groups,
    )
    out_path = pathlib.Path(out_dir)
    out_path.mkdir(parents=True, exist_ok=True)
    fname = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}.json"
    (out_path / fname).write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=["train_4k",
                    "prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--overrides", default="",
                    help="JSON dict of ArchConfig overrides (perf iters)")
    args = ap.parse_args()
    overrides = json.loads(args.overrides) if args.overrides else None
    res = run_cell(args.arch, args.shape, args.multipod, args.out, overrides)
    print(json.dumps(res, indent=1))
    if res["status"] == "ok":
        print(f"\nOK {args.arch} x {args.shape} "
              f"[{res['mesh']}] peak={res['memory']['peak_mb']} MiB/dev "
              f"flops={res['hlo_flops']:.3e} "
              f"coll={res['collectives']['total_bytes']:.3e}B")


if __name__ == "__main__":
    main()

"""Batched serving launcher: prefill + decode loop over a request queue.

CPU-runnable with reduced configs; the same step functions lower for the
production mesh in dryrun.py (prefill_32k / decode_32k cells).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.registry import get_api
    from repro.train import steps as tsteps
    from repro.launch import specs

    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, tp=1)
    prefill = jax.jit(tsteps.make_prefill_step(cfg, api, groups=1))
    decode = jax.jit(tsteps.make_decode_step(cfg, api, groups=1))

    # Synthetic request batch.
    pb = specs.prefill_inputs(cfg, args.prompt_len, args.batch,
                              concrete=True, key=jax.random.PRNGKey(1))
    if cfg.family == "vlm":
        pb = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)}
    cache = api.init_cache(cfg, args.batch, args.max_seq, jnp.float32)

    t0 = time.time()
    logits, cache = prefill(params, pb, cache)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [np.asarray(tokens)]
    t0 = time.time()
    for _ in range(args.gen):
        tokens, logits, cache = decode(params, tokens, cache)
        generated.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill*1e3:.1f} ms; "
          f"{args.gen} decode steps in {t_decode*1e3:.1f} ms "
          f"({t_decode/args.gen*1e3:.2f} ms/step incl. dispatch)")
    print("generated token ids (first request):", gen[0].tolist())
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()

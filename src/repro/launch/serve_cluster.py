"""Serving launcher: fit -> persist artifact -> load -> drive query load.

End-to-end demo/check of repro.serve on synthetic data:

  1. fit a one-pass kernel clustering (Alg. 1) on blob+ring data,
  2. save the FittedModel artifact and load it back through the registry,
  3. verify the artifact serves correctly:
       - out-of-sample embeddings of the TRAINING points reproduce the
         fitted Y (the extension identity; rel err <= 1e-4),
       - bucketed/batched assignment == unbatched assignment exactly,
  4. drive synthetic query load and write BENCH_serve.json: synchronous
     assignments/sec per batch size (--bench sync), async latency
     percentiles p50/p95/p99 + SLO accounting through AsyncBatcher
     (--bench async), or both (--bench all, the default),
  5. verify the async path resolves futures bit-identically to a
     synchronous drain of the same requests,
  6. with --swap, exercise the model lifecycle: publish versions to a
     VersionStore (retention via --gc-keep), then warm hot-swap the live
     registry row to a pinned version while async requests are pending —
     every future resolves, post-swap labels come from the new version,
     and the SwapReport's measured flip/warm numbers are printed,
  7. with --sharded, run the extension matmul mesh-sharded over all local
     devices (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to
     fake a CPU mesh) and verify it matches the single-device path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_cluster --smoke --swap
  PYTHONPATH=src python -m repro.launch.serve_cluster --n 8000 --r 2 \
      --batch-sizes 64,512,4096 --queries 8192 --bench all --slo-ms 250
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + full round-trip verification")
    ap.add_argument("--n", type=int, default=4000, help="training points")
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--l", type=int, default=10, help="oversampling")
    ap.add_argument("--kernel", default="polynomial")
    ap.add_argument("--degree", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=None,
                    help="kernel gamma; defaults to 0.0 for polynomial, "
                         "1.0 for rbf")
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--sketch", default="srht",
                    choices=["srht", "gaussian"])
    ap.add_argument("--artifact-dir", default="serve_artifacts/demo")
    ap.add_argument("--batch-sizes", default="64,512")
    ap.add_argument("--queries", type=int, default=2048,
                    help="synthetic queries for the equality check")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--bench", default="all",
                    choices=["sync", "async", "fused", "swap", "all"],
                    help="which benchmark modes land in BENCH_serve.json")
    ap.add_argument("--swap", action="store_true",
                    help="exercise the model lifecycle: publish versions, "
                         "warm hot-swap under pending async traffic, GC")
    ap.add_argument("--gc-keep", type=int, default=None,
                    help="VersionStore retention for --swap: keep the "
                         "last K published versions")
    ap.add_argument("--fused-embed", default="auto",
                    choices=["auto", "on", "off"],
                    help="extension stripe engine for the benches: fused "
                         "Pallas (on), two-pass (off), backend default "
                         "(auto)")
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (forces "
                         "the Pallas path on CPU — the CI hook)")
    ap.add_argument("--async-requests", type=int, default=256,
                    help="request count for the async latency bench")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="AsyncBatcher flush deadline")
    ap.add_argument("--slo-ms", type=float, default=250.0,
                    help="latency SLO for violation accounting")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the extension matmul over all local "
                         "devices (needs >= 2)")
    ap.add_argument("--bench-passes", type=int, default=1,
                    help="bench repetitions; BENCH_serve.json gets the "
                         "per-metric median (smoke forces >= 3 so the CI "
                         "regression gate diffs stable numbers)")
    ap.add_argument("--bench-out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.n = min(args.n, 2000)
        args.queries = min(args.queries, 1024)
        args.bench_passes = max(args.bench_passes, 3)

    from repro.data import blob_ring
    from repro.serve import (DEFAULT_REGISTRY, ShardedExtender, assign,
                             embed, fit_model, save_model, write_bench)
    from repro.serve.bench import format_bench, run_benches

    key = jax.random.PRNGKey(args.seed)
    k_fit, k_query = jax.random.split(key)
    X, _ = blob_ring(key, n=args.n)
    # gamma=0.0 is the right homogeneous-polynomial default but makes rbf a
    # degenerate constant kernel — pick the per-kernel default when unset.
    gamma = args.gamma if args.gamma is not None else \
        (0.0 if args.kernel == "polynomial" else 1.0)
    params = ({"gamma": gamma, "degree": args.degree}
              if args.kernel == "polynomial" else
              {"gamma": gamma} if args.kernel == "rbf" else {})

    t0 = time.time()
    model = fit_model(k_fit, X, k=args.k, r=args.r, kernel=args.kernel,
                      kernel_params=params, oversampling=args.l,
                      block=args.block, sketch_type=args.sketch)
    t_fit = time.time() - t0
    print(f"fit: n={args.n} r={args.r} l={args.l} kernel={args.kernel} "
          f"sketch={args.sketch} in {t_fit:.2f} s")

    path = save_model(model, args.artifact_dir)
    served = DEFAULT_REGISTRY.load("demo", path)
    print(f"artifact saved + loaded: {path}")

    # Check 1: the extension reproduces the fitted Y on training points.
    # The identity y(x_j) = Y e_j is exact only when the kernel matrix is
    # numerically rank <= r' (polynomial/linear); a full-rank kernel (rbf)
    # keeps the irreducible rank-r truncation residual, so there the number
    # is reported but not gated.
    Y_ext = embed(served, served.X_train)
    rel = (float(jnp.linalg.norm(Y_ext - served.Y)) /
           float(jnp.linalg.norm(served.Y)))
    print(f"train-point round-trip rel err: {rel:.2e}")
    if args.kernel in ("polynomial", "linear"):
        assert rel <= 1e-4, f"extension inconsistent with fit: {rel:.2e}"
    else:
        print("  (full-rank kernel: residual is the rank-r truncation "
              "error, not gated)")

    # Check 2: bucketed/batched == unbatched, bit-identical labels.
    Xq = jax.random.normal(k_query, (X.shape[0], args.queries), jnp.float32)
    labels_direct, _ = assign(served, Xq)
    batcher = DEFAULT_REGISTRY.batcher("demo")
    labels_bucketed, _ = batcher.assign_batch(Xq)
    # Also through the coalescing queue, as ragged concurrent requests.
    rng = np.random.RandomState(args.seed)
    splits = np.sort(rng.choice(np.arange(1, args.queries),
                                size=min(7, args.queries - 1),
                                replace=False))
    tickets = [batcher.submit(part)
               for part in np.split(np.asarray(Xq), splits, axis=1)]
    drained = batcher.drain()
    labels_queued = np.concatenate([drained[t][0] for t in tickets])
    assert np.array_equal(np.asarray(labels_direct), labels_bucketed), \
        "bucketed assignment != unbatched assignment"
    assert np.array_equal(labels_bucketed, labels_queued), \
        "queued micro-batching changed assignments"
    print(f"bucketed == unbatched == queued on {args.queries} queries "
          f"(buckets compiled: {batcher.executables})")

    # Check 3: async futures resolve bit-identically to a sync drain.
    sched = DEFAULT_REGISTRY.scheduler("demo", max_wait_ms=args.max_wait_ms,
                                       slo_ms=args.slo_ms)
    futs = [sched.submit(part)
            for part in np.split(np.asarray(Xq), splits, axis=1)]
    sched.flush()
    labels_async = np.concatenate([f.result()[0] for f in futs])
    assert np.array_equal(labels_bucketed, labels_async), \
        "async scheduling changed assignments"
    print(f"async == sync on {args.queries} queries "
          f"({sched.latency.requests} requests recorded)")

    # Check 4 (--swap): model lifecycle — publish versions, GC, warm
    # hot-swap the live row while async requests are pending.
    if args.swap:
        from repro.serve import VersionStore
        if args.gc_keep is not None and args.gc_keep < 1:
            ap.error("--gc-keep must be >= 1")
        store = VersionStore(args.artifact_dir + "_versions",
                             keep=args.gc_keep)
        v1 = store.publish(model)
        v2 = store.publish(model)
        # A distinguishable refresh, published LAST so it survives any
        # --gc-keep >= 1: flipping the centroid rows permutes the labels,
        # so post-swap labels prove which version served.
        model_b = model._replace(centroids=model.centroids[::-1])
        v3 = store.publish(model_b)
        print(f"published v{v1}, v{v2}, v{v3} -> {store.versions()}"
              + (f" (keep={args.gc_keep})" if args.gc_keep else ""))
        if args.gc_keep:
            assert len(store.versions()) <= args.gc_keep, \
                f"GC kept {store.versions()}, wanted <= {args.gc_keep}"
        served_b = store.load(v3)                 # pinned-version read
        w = min(args.queries, 64)
        swap_splits = [w // 3, 2 * w // 3] if w >= 3 else []
        parts = np.split(np.asarray(Xq[:, :w]), swap_splits, axis=1)
        pending = [sched.submit(part) for part in parts]
        report = DEFAULT_REGISTRY.swap("demo", served_b, version=v3)
        assert all(f.done() for f in pending), \
            "swap stranded pending futures"
        old_labels = np.concatenate([f.result()[0] for f in pending])
        assert np.array_equal(old_labels,
                              np.asarray(labels_bucketed[:w])), \
            "pre-swap requests must resolve against the old version"
        sched2 = DEFAULT_REGISTRY.scheduler("demo")
        futs = [sched2.submit(part) for part in parts]
        sched2.flush()
        new_labels = np.concatenate([f.result()[0] for f in futs])
        want_new, _ = assign(served_b, Xq[:, :w])
        assert np.array_equal(new_labels, np.asarray(want_new)), \
            "post-swap requests must resolve against the new version"
        print(f"warm swap v{report.old_version} -> v{report.new_version}: "
              f"flip {report.flip_ms:.3f} ms, warm {report.warm_s:.3f} s "
              f"(buckets {report.buckets_warmed}), drained "
              f"{report.drained_requests} pending requests into the old "
              f"model; p95 before {report.p95_before_ms:.2f} ms")

    # Optional: the mesh-sharded extension path against the local mesh.
    mesh = None
    if args.sharded:
        n_dev = len(jax.devices())
        if n_dev < 2:
            ap.error(f"--sharded needs >= 2 devices, have {n_dev} (set "
                     "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        mesh = jax.make_mesh((n_dev,), ("data",))
        ext = ShardedExtender(served, mesh)
        Y_sh = ext.embed(Xq[:, :256])
        Y_1d = embed(served, Xq[:, :256])
        rel_sh = (float(jnp.linalg.norm(Y_sh - Y_1d)) /
                  max(float(jnp.linalg.norm(Y_1d)), 1e-30))
        assert rel_sh <= 1e-5, f"sharded embed != single-device: {rel_sh:.2e}"
        print(f"sharded extension matches single-device over {n_dev} "
              f"devices (rel err {rel_sh:.2e})")

    # Benchmarks -> BENCH_serve.json (only the modes asked for run).
    batch_sizes = [int(b) for b in args.batch_sizes.split(",") if b.strip()]
    if not batch_sizes:
        ap.error(f"--batch-sizes {args.batch_sizes!r} parses to nothing")
    modes = (("sync", "async", "fused", "swap") if args.bench == "all"
             else (args.bench,))
    embed_fused = {"auto": None, "on": True, "off": False}[args.fused_embed]
    from repro.serve import median_benches
    bench = median_benches([
        run_benches(served, modes=modes, batch_sizes=batch_sizes,
                    repeats=args.repeats, key=k_query, mesh=mesh,
                    embed_fused=embed_fused,
                    interpret=True if args.interpret else None,
                    n_requests=args.async_requests,
                    max_wait_ms=args.max_wait_ms, slo_ms=args.slo_ms)
        for _ in range(max(args.bench_passes, 1))])
    write_bench(args.bench_out, bench)
    print(format_bench(bench))
    print(f"wrote {args.bench_out}")

    # Smoke also forces both Pallas serving paths (interpret mode on CPU)
    # for agreement with the jnp / two-pass paths: the fused kmeans_assign
    # argmin and the fused gram->projection extend_embed stripe.
    if args.smoke:
        small = Xq[:, :256]
        lab_jnp, _ = assign(served, small, fused=False)
        lab_pallas, _ = assign(served, small, fused=True, interpret=True)
        assert np.array_equal(np.asarray(lab_jnp), np.asarray(lab_pallas)), \
            "fused Pallas assignment disagrees with jnp path"
        print("fused Pallas assignment path agrees (256 queries)")
        Y_two = embed(served, small, fused=False)
        Y_fused = embed(served, small, fused=True, interpret=True)
        rel_f = (float(jnp.linalg.norm(Y_fused - Y_two)) /
                 max(float(jnp.linalg.norm(Y_two)), 1e-30))
        assert rel_f <= 1e-5, \
            f"fused extend_embed stripe != two-pass: {rel_f:.2e}"
        print(f"fused extend_embed stripe agrees (rel err {rel_f:.2e})")
    print("serve_cluster: OK")


if __name__ == "__main__":
    main()

"""Serving launcher: fit -> persist artifact -> load -> drive query load.

End-to-end demo/check of the estimator API + repro.serve on synthetic
data, for ANY approximation backend (--backend onepass-srht |
onepass-gaussian | nystrom | exact):

  1. fit a kernel clustering through `repro.api.KernelKMeans` on
     blob+ring data,
  2. save the FittedModel artifact and load it back through the registry,
  3. verify the artifact serves correctly:
       - out-of-sample embeddings of the TRAINING points reproduce the
         fitted linearization Y (the extension identity; rel err <= 1e-4
         — gated for low-rank kernels on the training-set backends and
         for the Nystrom backend on EVERY kernel, where the identity
         holds by construction),
       - bucketed/batched assignment == unbatched assignment exactly,
  4. drive synthetic query load and write BENCH_serve.json: synchronous
     assignments/sec per batch size (--bench sync), async latency
     percentiles p50/p95/p99 + SLO accounting through AsyncBatcher
     (--bench async), the per-backend accuracy/memory/throughput sweep
     (--bench backends), or everything (--bench all, the default),
  5. verify the async path resolves futures bit-identically to a
     synchronous drain of the same requests,
  6. with --swap, exercise the model lifecycle: publish versions to a
     VersionStore (retention via --gc-keep), then warm hot-swap the live
     registry row to a pinned version while async requests are pending —
     every future resolves, post-swap labels come from the new version,
     and the SwapReport's measured flip/warm numbers are printed,
  7. with --sharded, run the extension matmul mesh-sharded over all local
     devices (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to
     fake a CPU mesh) and verify it matches the single-device path,
  8. with --stream, run the streaming drift loop (repro.stream):
     partial_fit on an initial distribution, drifted synthetic traffic
     through AsyncBatcher trips the DriftMonitor (--drift-* thresholds),
     RetrainWorker refits from the accumulated sketch, publishes and
     warm-swaps — asserted: exactly one rollout, zero stranded futures,
     post-swap accuracy on the drifted distribution beats the stale
     model. `--bench stream` (in `all`) adds the partial_fit/re-eig/
     detection-to-swap numbers to BENCH_serve.json,
  9. with --fleet, run the multi-worker tier (repro.fleet):
     --fleet-workers replicas over one shared VersionStore behind the
     routed/admission-controlled front door — asserted: fleet-routed
     labels match direct assignment bit-identically, GC cannot delete a
     version the workers pin, a canary-then-promote rollout lands every
     worker on the new version with zero stranded futures, a rollout
     whose canary probe breaches the budget rolls back to the prior
     version, and a flood past a tiny admission cap sheds (typed
     ShedError) with shed_rate > 0. `--bench fleet` (in `all`) adds the
     q/s-vs-worker-count/overload/rollout soak numbers.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_cluster --smoke --swap
  PYTHONPATH=src python -m repro.launch.serve_cluster --smoke --stream
  PYTHONPATH=src python -m repro.launch.serve_cluster --smoke --fleet \
      --fleet-workers 2 --bench fleet
  PYTHONPATH=src python -m repro.launch.serve_cluster --smoke \
      --backend nystrom            # full stack on a Nystrom fit
  PYTHONPATH=src python -m repro.launch.serve_cluster --n 8000 --r 2 \
      --batch-sizes 64,512,4096 --queries 8192 --bench all --slo-ms 250
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + full round-trip verification")
    ap.add_argument("--n", type=int, default=4000, help="training points")
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--l", type=int, default=10, help="oversampling")
    ap.add_argument("--kernel", default="polynomial")
    ap.add_argument("--degree", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=None,
                    help="kernel gamma; defaults to 0.0 for polynomial, "
                         "1.0 for rbf")
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--backend", default=None,
                    choices=["onepass-srht", "onepass-gaussian", "nystrom",
                             "exact"],
                    help="approximation backend (default: onepass-<sketch>)")
    ap.add_argument("--nystrom-m", type=int, default=None,
                    help="landmark count for --backend nystrom "
                         "(default: repro.api default, 16r floored at 64)")
    ap.add_argument("--sketch", default="srht",
                    choices=["srht", "gaussian"],
                    help="one-pass sketch type (legacy spelling of "
                         "--backend onepass-<sketch>)")
    ap.add_argument("--artifact-dir", default="serve_artifacts/demo")
    ap.add_argument("--batch-sizes", default="64,512")
    ap.add_argument("--queries", type=int, default=2048,
                    help="synthetic queries for the equality check")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--bench", default="all",
                    choices=["sync", "async", "fused", "swap", "backends",
                             "stream", "fit_scaling", "fleet", "all"],
                    help="which benchmark modes land in BENCH_serve.json")
    ap.add_argument("--swap", action="store_true",
                    help="exercise the model lifecycle: publish versions, "
                         "warm hot-swap under pending async traffic, GC")
    ap.add_argument("--fleet", action="store_true",
                    help="run the multi-worker fleet tier checks: routing "
                         "parity, gc-under-pin, canary-then-promote "
                         "rollout + probe-breached rollback, overload "
                         "shedding (all asserted)")
    ap.add_argument("--fleet-workers", type=int, default=2,
                    help="replica count for --fleet")
    ap.add_argument("--stream", action="store_true",
                    help="run the streaming drift loop demo: partial_fit "
                         "on an initial distribution, drifted async "
                         "traffic trips the DriftMonitor, RetrainWorker "
                         "refits from accumulated state, publishes and "
                         "warm-swaps — exactly one rollout, zero "
                         "stranded futures (asserted)")
    ap.add_argument("--drift-chi2", type=float, default=30.0,
                    help="assignment-shift chi-square trigger threshold")
    ap.add_argument("--drift-frac-delta", type=float, default=0.25,
                    help="max cluster-population fraction delta trigger")
    ap.add_argument("--drift-min-queries", type=int, default=64,
                    help="assignment trigger stays quiet below this "
                         "window size")
    ap.add_argument("--drift-approx-threshold", type=float, default=None,
                    help="p95 kernel-approximation-error trigger "
                         "(default: disabled — exact-rank kernels keep "
                         "residuals ~0 under any shift)")
    ap.add_argument("--gc-keep", type=int, default=None,
                    help="VersionStore retention for --swap: keep the "
                         "last K published versions")
    ap.add_argument("--fused-embed", default="auto",
                    choices=["auto", "on", "off"],
                    help="extension stripe engine for the benches: fused "
                         "Pallas (on), two-pass (off), backend default "
                         "(auto)")
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (forces "
                         "the Pallas path on CPU — the CI hook)")
    ap.add_argument("--async-requests", type=int, default=256,
                    help="request count for the async latency bench")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="AsyncBatcher flush deadline")
    ap.add_argument("--slo-ms", type=float, default=250.0,
                    help="latency SLO for violation accounting")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the extension matmul over all local "
                         "devices (needs >= 2)")
    ap.add_argument("--bench-passes", type=int, default=None,
                    help="bench repetitions; BENCH_serve.json gets the "
                         "per-metric median. Default: 1, or 3 under "
                         "--smoke (so the CI regression gate diffs "
                         "stable numbers); an explicit value is always "
                         "honoured")
    ap.add_argument("--bench-out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.n = min(args.n, 2000)
        args.queries = min(args.queries, 1024)
    if args.bench_passes is None:
        args.bench_passes = 3 if args.smoke else 1
    backend = args.backend or f"onepass-{args.sketch}"

    from repro.api import KernelKMeans
    from repro.data import blob_ring
    from repro.serve import (DEFAULT_REGISTRY, ComputePolicy,
                             ShardedExtender, assign, embed, write_bench)
    from repro.serve.bench import format_bench, run_benches
    from repro.serve.extend import _projection

    key = jax.random.PRNGKey(args.seed)
    k_fit, k_query = jax.random.split(key)
    X, labels = blob_ring(key, n=args.n)
    # gamma=0.0 is the right homogeneous-polynomial default but makes rbf a
    # degenerate constant kernel — pick the per-kernel default when unset.
    gamma = args.gamma if args.gamma is not None else \
        (0.0 if args.kernel == "polynomial" else 1.0)
    params = ({"gamma": gamma, "degree": args.degree}
              if args.kernel == "polynomial" else
              {"gamma": gamma} if args.kernel == "rbf" else {})
    backend_params = {}
    if backend.startswith("onepass-"):
        backend_params["oversampling"] = args.l
    elif backend == "nystrom" and args.nystrom_m is not None:
        backend_params["m"] = args.nystrom_m

    t0 = time.time()
    est = KernelKMeans(k=args.k, r=args.r, kernel=args.kernel,
                       kernel_params=params, backend=backend,
                       backend_params=backend_params, block=args.block)
    est.fit(X, key=k_fit)
    model = est.model_
    t_fit = time.time() - t0
    print(f"fit: n={args.n} r={args.r} backend={backend} "
          f"kernel={args.kernel} ({est!r}) in {t_fit:.2f} s")

    path = est.save(args.artifact_dir)
    served = DEFAULT_REGISTRY.load("demo", path)
    print(f"artifact saved + loaded: {path}")

    # Check 1: the extension reproduces the fitted linearization Y on the
    # training points. For the training-set backends (one-pass / exact)
    # the identity y(x_j) = Y e_j is exact only when the kernel matrix is
    # numerically rank <= r' (polynomial/linear); a full-rank kernel
    # (rbf) keeps the irreducible rank-r truncation residual, so there
    # the number is reported but not gated. The Nystrom backend's fitted
    # Y IS the landmark extension evaluated on the training columns, so
    # the identity is exact for EVERY kernel and always gated.
    Y_ext = embed(served, np.asarray(X, np.float32))
    Y_fit = est.embedding_
    rel = (float(jnp.linalg.norm(Y_ext - Y_fit)) /
           float(jnp.linalg.norm(Y_fit)))
    print(f"train-point round-trip rel err: {rel:.2e}")
    if backend == "nystrom" or args.kernel in ("polynomial", "linear"):
        assert rel <= 1e-4, f"extension inconsistent with fit: {rel:.2e}"
    else:
        print("  (full-rank kernel: residual is the rank-r truncation "
              "error, not gated)")

    # Check 2: bucketed/batched == unbatched, bit-identical labels.
    Xq = jax.random.normal(k_query, (X.shape[0], args.queries), jnp.float32)
    labels_direct, _ = assign(served, Xq)
    batcher = DEFAULT_REGISTRY.batcher("demo")
    labels_bucketed, _ = batcher.assign_batch(Xq)
    # Also through the coalescing queue, as ragged concurrent requests.
    rng = np.random.RandomState(args.seed)
    splits = np.sort(rng.choice(np.arange(1, args.queries),
                                size=min(7, args.queries - 1),
                                replace=False))
    tickets = [batcher.submit(part)
               for part in np.split(np.asarray(Xq), splits, axis=1)]
    drained = batcher.drain()
    labels_queued = np.concatenate([drained[t][0] for t in tickets])
    assert np.array_equal(np.asarray(labels_direct), labels_bucketed), \
        "bucketed assignment != unbatched assignment"
    assert np.array_equal(labels_bucketed, labels_queued), \
        "queued micro-batching changed assignments"
    print(f"bucketed == unbatched == queued on {args.queries} queries "
          f"(buckets compiled: {batcher.executables})")

    # Check 3: async futures resolve bit-identically to a sync drain.
    sched = DEFAULT_REGISTRY.scheduler("demo", max_wait_ms=args.max_wait_ms,
                                       slo_ms=args.slo_ms)
    futs = [sched.submit(part)
            for part in np.split(np.asarray(Xq), splits, axis=1)]
    sched.flush()
    labels_async = np.concatenate([f.result()[0] for f in futs])
    assert np.array_equal(labels_bucketed, labels_async), \
        "async scheduling changed assignments"
    buckets_seen = sorted(sched.latency.by_bucket)
    print(f"async == sync on {args.queries} queries "
          f"({sched.latency.requests} requests recorded; per-bucket "
          f"breakdown over buckets {buckets_seen})")

    # Check 4: the mesh-sharded one-pass fit (ComputePolicy(mesh=...))
    # is bit-identical to the single-host fit — the distributed engine's
    # core contract, checked here on a 1-device mesh (CI's distributed
    # smoke runs the multi-device variant under XLA_FLAGS).
    if backend.startswith("onepass-"):
        from jax.sharding import Mesh
        pol = ComputePolicy(mesh=Mesh(np.array(jax.devices()[:1]),
                                      ("data",)))
        est_sh = KernelKMeans(k=args.k, r=args.r, kernel=args.kernel,
                              kernel_params=params, backend=backend,
                              backend_params=backend_params,
                              block=args.block, policy=pol)
        est_sh.fit(X, key=k_fit)
        assert np.array_equal(np.asarray(est.labels_),
                              np.asarray(est_sh.labels_)), \
            "sharded fit changed training labels"
        for leaf in ("U", "eigvals", "centroids"):
            assert np.array_equal(
                np.asarray(getattr(model, leaf)),
                np.asarray(getattr(est_sh.model_, leaf))), \
                f"sharded fit changed model.{leaf}"
        print(f"sharded fit ({pol.shards} shard) bit-identical to "
              f"single-host fit")

    # Check 5 (--swap): model lifecycle — publish versions, GC, warm
    # hot-swap the live row while async requests are pending.
    if args.swap:
        from repro.serve import VersionStore
        if args.gc_keep is not None and args.gc_keep < 1:
            ap.error("--gc-keep must be >= 1")
        store = VersionStore(args.artifact_dir + "_versions",
                             keep=args.gc_keep)
        v1 = store.publish(model)
        v2 = store.publish(model)
        # A distinguishable refresh, published LAST so it survives any
        # --gc-keep >= 1: flipping the centroid rows permutes the labels,
        # so post-swap labels prove which version served.
        model_b = model._replace(centroids=model.centroids[::-1])
        v3 = store.publish(model_b)
        print(f"published v{v1}, v{v2}, v{v3} -> {store.versions()}"
              + (f" (keep={args.gc_keep})" if args.gc_keep else ""))
        if args.gc_keep:
            assert len(store.versions()) <= args.gc_keep, \
                f"GC kept {store.versions()}, wanted <= {args.gc_keep}"
        served_b = store.load(v3)                 # pinned-version read
        w = min(args.queries, 64)
        swap_splits = [w // 3, 2 * w // 3] if w >= 3 else []
        parts = np.split(np.asarray(Xq[:, :w]), swap_splits, axis=1)
        pending = [sched.submit(part) for part in parts]
        report = DEFAULT_REGISTRY.swap("demo", served_b, version=v3)
        assert all(f.done() for f in pending), \
            "swap stranded pending futures"
        old_labels = np.concatenate([f.result()[0] for f in pending])
        assert np.array_equal(old_labels,
                              np.asarray(labels_bucketed[:w])), \
            "pre-swap requests must resolve against the old version"
        sched2 = DEFAULT_REGISTRY.scheduler("demo")
        futs = [sched2.submit(part) for part in parts]
        sched2.flush()
        new_labels = np.concatenate([f.result()[0] for f in futs])
        want_new, _ = assign(served_b, Xq[:, :w])
        assert np.array_equal(new_labels, np.asarray(want_new)), \
            "post-swap requests must resolve against the new version"
        print(f"warm swap v{report.old_version} -> v{report.new_version}: "
              f"flip {report.flip_ms:.3f} ms, warm {report.warm_s:.3f} s "
              f"(buckets {report.buckets_warmed}), drained "
              f"{report.drained_requests} pending requests into the old "
              f"model; p95 before {report.p95_before_ms:.2f} ms")

    # Check 6 (--stream): the living-service loop — partial_fit on an
    # initial distribution, drifted async traffic trips the DriftMonitor,
    # RetrainWorker refits from the accumulated sketch, publishes to the
    # VersionStore and warm-swaps the registry row. Gated: exactly one
    # rollout, zero stranded futures, post-swap accuracy on the drifted
    # distribution beats the stale model.
    if args.stream:
        from repro.core.metrics import clustering_accuracy
        from repro.serve import VersionStore
        from repro.stream import DriftMonitor, RetrainWorker

        rng_s = np.random.RandomState(args.seed)

        def blobs_1d(xs, n_per=100):
            cols, labs = [], []
            for i, x0 in enumerate(xs):
                c = np.zeros((2, n_per), np.float32)
                c[0] = x0 + 0.25 * rng_s.randn(n_per)
                c[1] = 0.25 * rng_s.randn(n_per)
                cols.append(c)
                labs.append(np.full(n_per, i))
            return np.concatenate(cols, axis=1), np.concatenate(labs)

        X0, _ = blobs_1d((-2.0, 2.0))              # initial distribution
        Xd, yd = blobs_1d((3.0, 8.0))              # drifted distribution
        stream_backend = (backend if backend.startswith("onepass-")
                          else "onepass-srht")
        s_est = KernelKMeans(k=2, r=2, kernel="linear",
                             backend=stream_backend, block=64)
        s_est.partial_fit(X0, key=jax.random.fold_in(key, 7),
                          capacity=X0.shape[1] + Xd.shape[1])
        stale_acc = clustering_accuracy(yd, s_est.predict(Xd), 2)
        s_store = VersionStore(args.artifact_dir + "_stream_versions",
                               keep=args.gc_keep or 4)
        DEFAULT_REGISTRY.register("stream-demo", s_est.model_,
                                  overwrite=True,
                                  version=s_store.publish(s_est.model_))
        s_sched = DEFAULT_REGISTRY.scheduler(
            "stream-demo", max_wait_ms=args.max_wait_ms)
        mon = DriftMonitor(
            s_est.model_, ref_labels=s_est.labels_,
            approx_err_threshold=args.drift_approx_threshold,
            chi2_threshold=args.drift_chi2,
            frac_delta_threshold=args.drift_frac_delta,
            min_queries=args.drift_min_queries)
        worker = RetrainWorker(
            "stream-demo", DEFAULT_REGISTRY, s_store, mon,
            lambda rep: s_est.partial_fit(Xd).model_)

        # Healthy (shuffled) traffic first: the monitor must stay quiet.
        Xh = X0[:, rng_s.permutation(X0.shape[1])]
        chunks = [Xh[:, lo:lo + 20] for lo in range(0, 100, 20)]
        futs = [s_sched.submit(ch) for ch in chunks]
        s_sched.flush()
        for ch, f in zip(chunks, futs):
            mon.observe(ch, f.result()[0])
        assert worker.step() is None, \
            "drift monitor fired on in-distribution traffic"

        # Drifted traffic through the async front door; one request left
        # pending so the swap's drain path is exercised.
        chunks = [Xd[:, lo:lo + 20] for lo in range(0, Xd.shape[1], 20)]
        futs = [s_sched.submit(ch) for ch in chunks]
        s_sched.flush()
        for ch, f in zip(chunks, futs):
            mon.observe(ch, f.result()[0])
        pending = s_sched.submit(Xd[:, :8])
        rollout = worker.step()
        assert rollout is not None, "injected drift did not trigger"
        assert worker.step() is None and worker.retrains == 1, \
            "drift must trigger exactly one refit+swap"
        stranded = sum(not f.done() for f in futs + [pending])
        assert stranded == 0, f"{stranded} futures stranded by the swap"
        new_acc = clustering_accuracy(
            yd, KernelKMeans.from_model(
                DEFAULT_REGISTRY.get("stream-demo")).predict(Xd), 2)
        assert new_acc > stale_acc, \
            f"refit did not beat the stale model ({new_acc} vs {stale_acc})"
        print(f"stream: drift {rollout.drift.reason}; refit v"
              f"{rollout.version} detect->swap "
              f"{rollout.detect_to_swap_s:.3f} s (refit "
              f"{rollout.refit_s:.3f} s), drained "
              f"{rollout.swap.drained_requests} pending, stranded 0; "
              f"drifted-set accuracy {stale_acc:.2f} -> {new_acc:.2f}")

    # Check 7 (--fleet): the multi-worker tier — N replicas over ONE
    # shared VersionStore behind the routed/admission-controlled front
    # door. Gated: fleet labels == direct assignment, gc-under-pin,
    # canary-then-promote with zero stranded futures, probe-breached
    # rollback restoring the prior version, overload shedding.
    if args.fleet:
        from repro.fleet import Fleet, ShedError
        from repro.serve import VersionStore
        if args.fleet_workers < 1:
            ap.error("--fleet-workers must be >= 1")
        f_store = VersionStore(args.artifact_dir + "_fleet_versions")
        fv1 = f_store.publish(model)
        # rollout_budget_ms is generous on purpose: the 7c canary probe
        # pays first-flush compile spikes (cold workers, by design), and
        # this check is about the PROMOTE path; the breach path is
        # forced explicitly in 7d, machine speed must not pick for us.
        fleet = Fleet(f_store, n_workers=args.fleet_workers,
                      slo_ms=args.slo_ms, max_wait_ms=args.max_wait_ms,
                      rollout_budget_ms=60_000.0, block=args.block)
        # 7a: routing only picks the replica; results must be
        # bit-identical to direct assignment regardless of placement.
        w = min(args.queries, 64)
        f_splits = [w // 4, w // 2, 3 * w // 4] if w >= 4 else []
        parts = np.split(np.asarray(Xq[:, :w]), f_splits, axis=1)
        futs = [fleet.submit(part) for part in parts]
        fleet.flush()
        fleet_labels = np.concatenate([f.result()[0] for f in futs])
        assert np.array_equal(fleet_labels,
                              np.asarray(labels_bucketed[:w])), \
            "fleet-routed labels != direct assignment"
        assert {wk.version for wk in fleet.workers} == {fv1}
        print(f"fleet: {args.fleet_workers} workers pinned to v{fv1} "
              f"(pins: {f_store.pins(fv1)}), routed labels match "
              f"direct assignment on {w} queries")
        # 7b: GC with keep=1 would delete v1 — but every worker pins it,
        # so it must survive (the pin-refcount guard).
        model_b = model._replace(centroids=model.centroids[::-1])
        fv2 = f_store.publish(model_b)
        f_store.gc(keep=1)
        assert fv1 in f_store.versions(), \
            f"GC deleted pinned v{fv1} out from under the fleet"
        print(f"gc(keep=1) preserved pinned v{fv1} "
              f"(pins: {f_store.pins(fv1)})")
        # 7c: canary-then-promote to v2 with requests pending — every
        # worker lands on v2, the pending futures resolve (old model).
        pending = [fleet.submit(part) for part in parts]
        rollout = fleet.rollout(fv2)
        fleet.flush()
        assert rollout is not None and rollout.promoted, \
            f"canary-then-promote failed: {rollout}"
        assert all(wk.version == fv2 for wk in fleet.workers), \
            "promote left a worker on the old version"
        stranded = sum(not f.done() for f in pending)
        assert stranded == 0, f"rollout stranded {stranded} futures"
        old_roll_labels = np.concatenate([f.result()[0] for f in pending])
        assert np.array_equal(old_roll_labels,
                              np.asarray(labels_bucketed[:w])), \
            "pre-rollout requests must resolve against the old version"
        futs = [fleet.submit(part) for part in parts]
        fleet.flush()
        new_roll_labels = np.concatenate([f.result()[0] for f in futs])
        want_new, _ = assign(f_store.load(fv2), Xq[:, :w])
        assert np.array_equal(new_roll_labels, np.asarray(want_new)), \
            "post-rollout requests must resolve against the new version"
        print(f"canary-then-promote v{fv1} -> v{fv2}: "
              f"{rollout.state} in {rollout.wall_s:.3f} s "
              f"(canary {rollout.canary_id} p95 "
              f"{rollout.canary_p95_ms:.2f} ms <= budget "
              f"{rollout.budget_ms:.0f} ms), 0 stranded futures")
        # 7d: a rollout whose canary probe breaches the budget must roll
        # back — fleet stays on v2, v3 stays in the store untouched.
        fv3 = f_store.publish(model)
        bad = fleet.rollout(fv3, probe=lambda wk: float("inf"))
        assert bad is not None and bad.state == "rolled-back" \
            and not bad.promoted, f"breached canary did not roll back: {bad}"
        assert all(wk.version == fv2 for wk in fleet.workers), \
            "rollback did not restore the prior version"
        assert fv3 in f_store.versions(), "rollback deleted the target"
        print(f"breached canary rolled back: fleet stays on v{fv2}, "
              f"v{fv3} intact for a retry")
        fleet.stop()
        # 7e: overload — a flood past a tiny admission cap must shed
        # (typed ShedError), and the counters must say so.
        tiny = Fleet(f_store, n_workers=args.fleet_workers, version=fv2,
                     slo_ms=args.slo_ms, max_wait_ms=args.max_wait_ms,
                     max_queue_depth=8, block=args.block)
        shed = 0
        for i in range(32):
            try:
                tiny.submit(np.asarray(Xq[:, :4]))
            except ShedError as e:
                assert e.reason == "queue-full", e.reason
                shed += 1
        tiny.flush()
        rate = tiny.admission.shed_rate
        tiny.stop()
        assert shed > 0 and rate > 0.0, \
            f"flood past depth 8 shed nothing (shed={shed}, rate={rate})"
        print(f"overload: shed {shed}/32 requests past depth-8 caps "
              f"(shed_rate {rate:.0%}, typed ShedError)")

    # Optional: the mesh-sharded extension path against the local mesh.
    mesh = None
    if args.sharded:
        n_dev = len(jax.devices())
        if n_dev < 2:
            ap.error(f"--sharded needs >= 2 devices, have {n_dev} (set "
                     "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        mesh = jax.make_mesh((n_dev,), ("data",))
        ext = ShardedExtender(served, mesh)
        Y_sh = ext.embed(Xq[:, :256])
        Y_1d = embed(served, Xq[:, :256])
        rel_sh = (float(jnp.linalg.norm(Y_sh - Y_1d)) /
                  max(float(jnp.linalg.norm(Y_1d)), 1e-30))
        assert rel_sh <= 1e-5, f"sharded embed != single-device: {rel_sh:.2e}"
        print(f"sharded extension matches single-device over {n_dev} "
              f"devices (rel err {rel_sh:.2e})")

    # Benchmarks -> BENCH_serve.json (only the modes asked for run).
    batch_sizes = [int(b) for b in args.batch_sizes.split(",") if b.strip()]
    if not batch_sizes:
        ap.error(f"--batch-sizes {args.batch_sizes!r} parses to nothing")
    modes = (("sync", "async", "fused", "swap", "backends", "stream",
              "fit_scaling", "fleet")
             if args.bench == "all" else (args.bench,))
    embed_fused = {"auto": None, "on": True, "off": False}[args.fused_embed]
    from repro.serve import median_benches
    bench = median_benches([
        run_benches(served, modes=modes, batch_sizes=batch_sizes,
                    repeats=args.repeats, key=k_query, mesh=mesh,
                    embed_fused=embed_fused,
                    interpret=True if args.interpret else None,
                    n_requests=args.async_requests,
                    max_wait_ms=args.max_wait_ms, slo_ms=args.slo_ms,
                    data=(X, labels))
        for _ in range(max(args.bench_passes, 1))])
    write_bench(args.bench_out, bench)
    print(format_bench(bench))
    print(f"wrote {args.bench_out}")

    # Smoke also forces both Pallas serving paths (interpret mode on CPU)
    # for agreement with the jnp / two-pass paths: the fused kmeans_assign
    # argmin and the fused gram->projection extend_embed stripe.
    if args.smoke:
        small = Xq[:, :256]
        lab_jnp, _ = assign(served, small,
                            policy=ComputePolicy(assign_fused=False))
        lab_pallas, _ = assign(served, small,
                               policy=ComputePolicy(assign_fused=True,
                                                    interpret=True))
        assert np.array_equal(np.asarray(lab_jnp), np.asarray(lab_pallas)), \
            "fused Pallas assignment disagrees with jnp path"
        print("fused Pallas assignment path agrees (256 queries)")
        Y_two = embed(served, small,
                      policy=ComputePolicy(embed_fused=False))
        Y_fused = embed(served, small,
                        policy=ComputePolicy(embed_fused=True,
                                             interpret=True))
        rel_f = (float(jnp.linalg.norm(Y_fused - Y_two)) /
                 max(float(jnp.linalg.norm(Y_two)), 1e-30))
        assert rel_f <= 1e-5, \
            f"fused extend_embed stripe != two-pass: {rel_f:.2e}"
        print(f"fused extend_embed stripe agrees (rel err {rel_f:.2e})")
        # Backend-specific ground truth: the served assignment must match
        # a direct evaluation of the backend's own extension formula
        # y(x) = Sigma^{-1/2} U^T kappa(ref, x) — for --backend nystrom
        # this is the "assign parity with a direct Nystrom embedding"
        # acceptance check.
        P = _projection(served)
        Y_direct = P @ served.kernel_fn()(served.extension_ref, small)
        d2 = (jnp.sum(Y_direct.T ** 2, 1)[:, None]
              + jnp.sum(served.centroids ** 2, 1)[None, :]
              - 2.0 * Y_direct.T @ served.centroids.T)
        lab_direct = np.asarray(jnp.argmin(d2, axis=1), np.int32)
        assert np.array_equal(lab_direct, np.asarray(lab_jnp)), \
            f"served assignment != direct {backend} embedding assignment"
        print(f"served stack agrees with the direct {backend} extension "
              f"(256 queries)")
    print("serve_cluster: OK")


if __name__ == "__main__":
    main()

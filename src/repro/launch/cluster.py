"""Launcher for the paper's pipeline: one-pass randomized kernel K-means.

Single-device by default; --distributed runs the mesh pipeline
(distributed/cluster.py) over however many devices exist.

Usage:
  PYTHONPATH=src python -m repro.launch.cluster --n 4000 --k 2 --r 2 --l 10
  PYTHONPATH=src python -m repro.launch.cluster --dataset seg --k 7 --l 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="rings", choices=["rings", "seg",
                                                           "blobs"])
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--l", type=int, default=10, help="oversampling")
    ap.add_argument("--kernel", default="polynomial")
    ap.add_argument("--degree", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.0)
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument("--backend", default="onepass-srht",
                    choices=["onepass-srht", "onepass-gaussian", "nystrom",
                             "exact"],
                    help="approximation backend (single-device path; "
                         "--distributed always runs the sharded one-pass)")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.api import KernelKMeans
    from repro.core import (make_kernel, clustering_accuracy, nmi,
                            kernel_approx_error_streaming)
    from repro.data import blob_ring, segmentation_proxy, gaussian_blobs

    key = jax.random.PRNGKey(args.seed)
    if args.dataset == "rings":
        X, labels = blob_ring(key, n=args.n)
        k = 2
    elif args.dataset == "seg":
        X, labels = segmentation_proxy(key, n=args.n if args.n != 4000
                                       else 2310)
        k = 7
    else:
        X, labels = gaussian_blobs(key, n=args.n, p=16, k=args.k)
        k = args.k
    k = args.k or k
    kernel_params = ({"gamma": args.gamma, "degree": args.degree}
                     if args.kernel == "polynomial" else
                     {"gamma": args.gamma} if args.kernel == "rbf" else {})
    kern = make_kernel(args.kernel, **kernel_params)

    t0 = time.time()
    if args.distributed:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.sketch import next_pow2
        from repro.distributed.cluster import \
            distributed_one_pass_kernel_kmeans
        ndev = jax.device_count()
        mesh = jax.make_mesh((ndev,), ("data",))
        n_pad = next_pow2(X.shape[1])
        n_pad = max(n_pad, ndev * ((n_pad + ndev - 1) // ndev))
        Xp = jnp.pad(X, ((0, 0), (0, n_pad - X.shape[1])))
        Xp = jax.device_put(Xp, NamedSharding(mesh, P(None, "data")))
        res = distributed_one_pass_kernel_kmeans(
            jax.random.PRNGKey(args.seed + 1), kern, Xp, k=k, r=args.r,
            mesh=mesh, oversampling=args.l, block=args.block)
        pred = np.asarray(res.labels)[: X.shape[1]]
        Y = np.asarray(res.Y)[:, : X.shape[1]]
    else:
        backend_params = ({"oversampling": args.l}
                          if args.backend.startswith("onepass-") else {})
        est = KernelKMeans(k=k, r=args.r, kernel=args.kernel,
                           kernel_params=kernel_params,
                           backend=args.backend,
                           backend_params=backend_params, block=args.block)
        est.fit(X, key=jax.random.PRNGKey(args.seed + 1))
        pred, Y = np.asarray(est.labels_), est.embedding_
    dt = time.time() - t0

    err = kernel_approx_error_streaming(kern, X, jnp.asarray(Y),
                                        block=args.block)
    print(f"n={X.shape[1]} k={k} r={args.r} l={args.l} "
          f"kernel={args.kernel} distributed={args.distributed}")
    print(f"wall time        {dt:.2f} s")
    print(f"approx error     {err:.4f}")
    print(f"accuracy         {clustering_accuracy(labels, pred, k):.4f}")
    print(f"nmi              {nmi(labels, pred):.4f}")
    print(f"sketch memory    {X.shape[1] * (args.r + args.l) * 4 / 2**20:.1f}"
          f" MiB (O(r'n); full K would be "
          f"{X.shape[1] ** 2 * 4 / 2**30:.2f} GiB)")


if __name__ == "__main__":
    main()

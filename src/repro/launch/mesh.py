"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    # works for both Mesh and AbstractMesh
    return dict(mesh.shape)


def dp_axes(mesh):
    """Batch/FSDP axes: ('pod','data') when present, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axis(mesh):
    return "model" if "model" in mesh.axis_names else None

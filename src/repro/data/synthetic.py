"""Synthetic data sets for the paper's experiments.

- two_rings: the Fig. 1 data (n=4000, R^2, two concentric rings — not
  linearly separable; separable under the homogeneous polynomial kernel d=2).
- segmentation_proxy: a structure-matched stand-in for the UCI image
  segmentation set (n=2310, p=19, K=7, unit-l2 rows) used by Fig. 3; the UCI
  download is unavailable offline (documented in DESIGN.md §1).
- gaussian_blobs: generic well-separated clusters for unit tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def two_rings(key: jax.Array, n: int = 4000, r_inner: float = 1.0,
              r_outer: float = 2.0, noise: float = 0.1
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns X (2, n) and labels (n,). Half the points on each ring."""
    k1, k2, k3 = jax.random.split(key, 3)
    n_in = n // 2
    n_out = n - n_in
    theta = jax.random.uniform(k1, (n,), minval=0.0, maxval=2 * jnp.pi)
    radii = jnp.concatenate([jnp.full((n_in,), r_inner),
                             jnp.full((n_out,), r_outer)])
    radii = radii + noise * jax.random.normal(k2, (n,))
    X = jnp.stack([radii * jnp.cos(theta), radii * jnp.sin(theta)], axis=0)
    labels = jnp.concatenate([jnp.zeros((n_in,), jnp.int32),
                              jnp.ones((n_out,), jnp.int32)])
    perm = jax.random.permutation(k3, n)
    return X[:, perm], labels[perm]


def blob_ring(key: jax.Array, n: int = 4000, sigma: float = 0.3,
              radius: float = 2.0, rnoise: float = 0.1
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fig. 1 geometry (primary): central Gaussian blob enclosed by a ring.

    Not linearly separable; under the homogeneous polynomial kernel (d=2)
    the rank-2 linearization separates the classes (Table 1: exact/ours acc
    0.99). Returns X (2, n), labels (n,).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_blob = n // 2
    n_ring = n - n_blob
    Xb = sigma * jax.random.normal(k1, (2, n_blob))
    theta = jax.random.uniform(k2, (n_ring,), minval=0.0, maxval=2 * jnp.pi)
    rr = radius + rnoise * jax.random.normal(k3, (n_ring,))
    Xr = jnp.stack([rr * jnp.cos(theta), rr * jnp.sin(theta)], axis=0)
    X = jnp.concatenate([Xb, Xr], axis=1)
    labels = jnp.concatenate([jnp.zeros((n_blob,), jnp.int32),
                              jnp.ones((n_ring,), jnp.int32)])
    perm = jax.random.permutation(k4, n)
    return X[:, perm], labels[perm]


def gaussian_blobs(key: jax.Array, n: int, p: int, k: int,
                   spread: float = 0.1, center_scale: float = 1.0
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k isotropic Gaussian clusters. Returns X (p, n), labels (n,)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = center_scale * jax.random.normal(k1, (k, p))
    labels = jax.random.randint(k2, (n,), 0, k)
    X = centers[labels].T + spread * jax.random.normal(k3, (p, n))
    return X, labels.astype(jnp.int32)


def segmentation_proxy(key: jax.Array, n: int = 2310, p: int = 19,
                       k: int = 7, spread: float = 0.25
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """UCI-image-segmentation-like data: K=7 anisotropic clusters, rows
    normalized to unit l2 norm (as the paper preprocesses), equal class
    sizes (the UCI set has 330 per class)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    per = n // k
    centers = jax.random.normal(k1, (k, p))
    # Anisotropic, per-cluster covariance scales — mimics the heterogeneous
    # region statistics of the segmentation attributes.
    scales = 0.3 + jax.random.uniform(k2, (k, p))
    labels = jnp.repeat(jnp.arange(k), per)
    labels = jnp.concatenate(
        [labels, jax.random.randint(k3, (n - per * k,), 0, k)])
    noise = jax.random.normal(k4, (n, p))
    X = centers[labels] + spread * scales[labels] * noise   # (n, p)
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True)       # unit l2 rows
    return X.T, labels.astype(jnp.int32)

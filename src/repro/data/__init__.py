from repro.data.synthetic import (two_rings, blob_ring, gaussian_blobs,
                                  segmentation_proxy)
__all__ = ["two_rings", "blob_ring", "gaussian_blobs", "segmentation_proxy"]

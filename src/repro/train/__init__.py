from repro.train.optimizer import adamw_init, adamw_update, AdamWConfig
from repro.train.steps import (make_train_step, make_prefill_step,
                               make_decode_step, cross_entropy, TrainState)
__all__ = ["adamw_init", "adamw_update", "AdamWConfig",
           "make_train_step", "make_prefill_step", "make_decode_step",
           "cross_entropy", "TrainState"]

"""Train / prefill / decode step builders shared by launcher + dry-run.

make_train_step builds a pure (state, batch) -> (state, metrics) function:
  - microbatch gradient accumulation via lax.scan (cfg.microbatches),
  - f32 loss with label masking (-1 = ignore),
  - AdamW update (moments stay sharded like params),
  - optional sketched-gradient compression hook (distributed/compression.py)
    applied to the accumulated gradient before the optimizer.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.registry import ModelAPI
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: Dict


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Masked mean CE. logits (B,S,V) f32, labels (B,S) int32 (-1 ignored)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def init_train_state(key: jax.Array, cfg: ArchConfig, api: ModelAPI,
                     tp: int = 16) -> TrainState:
    params = api.init(key, cfg, tp)
    opt_cfg = AdamWConfig(moment_dtype=cfg.optimizer_dtype)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg))


def make_train_step(cfg: ArchConfig, api: ModelAPI, groups: int = 1,
                    grad_transform: Optional[Callable] = None,
                    opt_cfg: Optional[AdamWConfig] = None,
                    pregather_spec: Optional[Any] = None,
                    grad_spec: Optional[Any] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch: dict of (B, ...) arrays; B must divide by cfg.microbatches.
    grad_transform: optional (grads -> grads) hook, e.g. the SRHT sketched
    all-reduce with error feedback from distributed/compression.py.
    pregather_spec: PartitionSpec pytree WITHOUT the FSDP factor. When set,
    params are constrained to it once at step entry, so the ZeRO-3 weight
    all-gather happens once per step instead of once per microbatch; grads
    are reduce-scattered back to the sharded optimizer state by GSPMD.
    """
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=cfg.optimizer_dtype)
    M = cfg.microbatches

    def loss_fn(params, mb):
        logits = api.forward(params, cfg, mb, groups)
        return cross_entropy(logits, mb["labels"])

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if pregather_spec is not None:
            state = TrainState(
                params=jax.lax.with_sharding_constraint(state.params,
                                                        pregather_spec),
                opt=state.opt)
        if M > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)

            def acc(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                if grad_spec is not None:
                    # Land per-microbatch grads in the fully-sharded layout
                    # of the optimizer moments: the cross-data reduction
                    # lowers to reduce-scatter instead of all-reduce (half
                    # the bytes), and the f32 accumulator is 2D-sharded.
                    grads = jax.lax.with_sharding_constraint(grads,
                                                             grad_spec)
                return (carry[0] + loss,
                        jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                     carry[1], grads)), None

            zero_like = (jax.lax.with_sharding_constraint(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params), grad_spec)
                if grad_spec is not None else
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params))
            zero = (jnp.zeros(()), zero_like)
            (loss_sum, grads), _ = jax.lax.scan(acc, zero, mb_batch)
            loss = loss_sum / M
            grads = jax.tree.map(lambda g: g / M, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt = adamw_update(state.params, grads, state.opt,
                                           opt_cfg)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return TrainState(new_params, new_opt), {"loss": loss,
                                                 "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig, api: ModelAPI,
                      groups: int = 1) -> Callable:
    def prefill_step(params, batch, cache):
        return api.prefill(params, cfg, batch, cache, groups)
    return prefill_step


def make_decode_step(cfg: ArchConfig, api: ModelAPI,
                     groups: int = 1) -> Callable:
    def decode_step(params, tokens, cache):
        logits, cache = api.decode(params, cfg, tokens, cache, groups)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, cache
    return decode_step

"""AdamW, implemented from scratch (no optax dependency).

Moment dtype is configurable (cfg.optimizer_dtype): fp32 by default, bf16
for the 100B+ configs so fully-sharded optimizer state fits 16 GB/chip
(DESIGN.md §5). Moments inherit the sharding of their parameters (same
pytree structure -> same PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig) -> Dict:
    dt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt_state: Dict, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict]:
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:     # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return new_p, m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}

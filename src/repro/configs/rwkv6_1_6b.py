"""rwkv6-1.6b Finch [arXiv:2404.05892; unverified] — data-dependent decay."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=7168,
    vocab_size=65536, activation="relu2", attention="full",
    rwkv_head_dim=64, microbatches=2,
)

smoke_config = ArchConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=128,
    vocab_size=512, activation="relu2", rwkv_head_dim=16,
    param_dtype="float32", dtype="float32", remat=False, padded_vocab=512,
)

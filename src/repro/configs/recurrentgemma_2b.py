"""recurrentgemma-2b [arXiv:2402.19427; hf] — RG-LRU + local attn, 1:2."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, activation="geglu", attention="sliding", window=2048,
    layer_pattern=("R", "R", "A"), microbatches=4,
)

smoke_config = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab_size=512, activation="geglu", attention="sliding", window=32,
    layer_pattern=("R", "R", "A"), param_dtype="float32", dtype="float32",
    remat=False, padded_vocab=512,
)

"""phi4-mini-3.8b [arXiv:2412.08905; hf] — RoPE SwiGLU GQA."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=200064, activation="swiglu", attention="full",
    microbatches=2,
)

smoke_config = ArchConfig(
    name="phi4-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, activation="swiglu", attention="full",
    param_dtype="float32", dtype="float32", remat=False, padded_vocab=512,
)

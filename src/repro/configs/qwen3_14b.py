"""qwen3-14b [hf:Qwen/Qwen3-8B family; hf] — qk_norm, GQA."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
    vocab_size=151936, head_dim=128, activation="swiglu", attention="full",
    qk_norm=True, microbatches=2,
)

smoke_config = ArchConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, activation="swiglu", attention="full", qk_norm=True,
    param_dtype="float32", dtype="float32", remat=False, padded_vocab=512,
)

"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified] — ViT stub + nemo."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=131072, head_dim=128, activation="swiglu", attention="full",
    n_patch_tokens=1024, microbatches=2,
)

smoke_config = ArchConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, activation="swiglu", attention="full", n_patch_tokens=8,
    param_dtype="float32", dtype="float32", remat=False, padded_vocab=512,
)

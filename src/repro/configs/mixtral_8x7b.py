"""mixtral-8x7b [arXiv:2401.04088; hf] — 8 experts top-2, SWA."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, activation="swiglu",
    n_experts=8, top_k=2, attention="sliding", window=4096, microbatches=4,
)

smoke_config = ArchConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, activation="swiglu", n_experts=4, top_k=2,
    attention="sliding", window=32, param_dtype="float32", dtype="float32",
    remat=False, padded_vocab=512,
)

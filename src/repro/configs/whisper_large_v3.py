"""whisper-large-v3 [arXiv:2212.04356; unverified] — enc-dec, conv stub."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, activation="gelu", attention="full",
    n_encoder_layers=32, n_audio_frames=1500, microbatches=2,
)

smoke_config = ArchConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, activation="gelu", attention="full",
    n_encoder_layers=2, n_audio_frames=16, param_dtype="float32",
    dtype="float32", remat=False, padded_vocab=512,
)

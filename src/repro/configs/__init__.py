"""Config registry: one module per assigned architecture."""
import importlib

ARCH_IDS = [
    "recurrentgemma-2b", "mixtral-8x7b", "dbrx-132b", "phi4-mini-3.8b",
    "nemotron-4-340b", "qwen3-14b", "command-r-plus-104b",
    "whisper-large-v3", "rwkv6-1.6b", "pixtral-12b",
]

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mixtral-8x7b": "mixtral_8x7b",
    "dbrx-132b": "dbrx_132b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-14b": "qwen3_14b",
    "command-r-plus-104b": "command_r_plus_104b",
    "whisper-large-v3": "whisper_large_v3",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "pixtral-12b": "pixtral_12b",
}


def get_config(arch_id: str, smoke: bool = False):
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config if smoke else mod.config

"""dbrx-132b [hf:databricks/dbrx-base; unverified] — 16 experts top-4."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, head_dim=128, activation="swiglu",
    n_experts=16, top_k=4, attention="full", microbatches=8,
    optimizer_dtype="bfloat16",
)

smoke_config = ArchConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, activation="swiglu", n_experts=4, top_k=2,
    attention="full", param_dtype="float32", dtype="float32",
    remat=False, padded_vocab=512,
)

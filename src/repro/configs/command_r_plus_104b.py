"""command-r-plus-104b [hf:CohereForAI; unverified] — GQA, no-bias."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab_size=256000, head_dim=128, activation="swiglu", attention="full",
    microbatches=8, optimizer_dtype="bfloat16",
)

smoke_config = ArchConfig(
    name="command-r-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, activation="swiglu", attention="full",
    param_dtype="float32", dtype="float32", remat=False, padded_vocab=512,
)

"""nemotron-4-340b [arXiv:2402.16819; unverified] — GQA, squared-ReLU."""
from repro.models.config import ArchConfig

config = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, activation="relu2", attention="full",
    microbatches=16, optimizer_dtype="bfloat16",
)

smoke_config = ArchConfig(
    name="nemotron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=512, activation="relu2", attention="full",
    param_dtype="float32", dtype="float32", remat=False, padded_vocab=512,
)
